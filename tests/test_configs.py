"""Pin every assigned architecture config to its published spec
(the bracketed source in the assignment). Guards against config drift."""

import pytest

from repro.configs import get_config
from repro.models.config import (
    ATTN_CROSS,
    ATTN_FULL,
    ATTN_WINDOW,
    MIX_MAMBA,
    MIX_RWKV,
    MLP_DENSE,
    MLP_MOE,
)

# (layers, d_model, heads, kv_heads, d_ff, vocab)
SPECS = {
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
    "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
    "deepseek-moe-16b": (28, 2048, 16, 16, 10944, 102400),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
}


@pytest.mark.parametrize("arch", list(SPECS))
def test_exact_dims(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = SPECS[arch]
    assert cfg.num_layers == L, (cfg.num_layers, L)
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source  # provenance required


def test_gemma3_local_global_ratio():
    cfg = get_config("gemma3-27b")
    kinds = [s.mixer for s in cfg.layers]
    assert kinds.count(ATTN_WINDOW) == 51 and kinds.count(ATTN_FULL) == 11
    # 5:1 within each repeated super-block
    assert tuple(s.mixer for s in cfg.pattern) == (ATTN_WINDOW,) * 5 + (ATTN_FULL,)


def test_vision_cross_attn_every_5th():
    cfg = get_config("llama-3.2-vision-90b")
    kinds = [s.mixer for s in cfg.layers]
    assert kinds.count(ATTN_CROSS) == 20
    assert all(kinds[i] == ATTN_CROSS for i in range(4, 100, 5))
    assert cfg.num_image_tokens > 0


def test_qwen3_moe_routing():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.num_experts == 128 and cfg.top_k == 8
    assert cfg.num_shared_experts == 0
    assert all(s.mlp == MLP_MOE for s in cfg.layers)


def test_deepseek_fine_grained():
    cfg = get_config("deepseek-moe-16b")
    assert cfg.num_experts == 64 and cfg.top_k == 6
    assert cfg.num_shared_experts == 2
    assert cfg.layers[0].mlp == MLP_DENSE          # first layer dense
    assert all(s.mlp == MLP_MOE for s in cfg.layers[1:])


def test_jamba_interleave():
    cfg = get_config("jamba-v0.1-52b")
    kinds = [s.mixer for s in cfg.layers]
    assert kinds.count(ATTN_FULL) == 4 and kinds.count(MIX_MAMBA) == 28
    # attention at index 4 of each 8-layer block (1:7 ratio)
    assert all(kinds[b * 8 + 4] == ATTN_FULL for b in range(4))
    moes = [s.mlp == MLP_MOE for s in cfg.layers]
    assert sum(moes) == 16 and cfg.num_experts == 16 and cfg.top_k == 2


def test_rwkv_attention_free():
    cfg = get_config("rwkv6-1.6b")
    assert cfg.is_attention_free
    assert all(s.mixer == MIX_RWKV for s in cfg.layers)


def test_musicgen_codebooks():
    cfg = get_config("musicgen-large")
    assert cfg.num_codebooks == 4
    assert cfg.num_kv_heads == cfg.num_heads  # MHA


def test_qkv_bias_flags():
    assert get_config("qwen2.5-14b").qkv_bias
    assert get_config("starcoder2-7b").qkv_bias
    assert not get_config("mistral-large-123b").qkv_bias


@pytest.mark.parametrize("arch", list(SPECS))
def test_smoke_variants_reduced(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", list(SPECS))
def test_param_counts_in_family_range(arch):
    """Total parameter count should be in the ballpark the name claims."""
    expected_b = {
        "gemma3-27b": 27, "llama-3.2-vision-90b": 90,
        "mistral-large-123b": 123, "starcoder2-7b": 7,
        "qwen3-moe-235b-a22b": 235, "rwkv6-1.6b": 1.6,
        "qwen2.5-14b": 14, "deepseek-moe-16b": 16,
        "musicgen-large": 3.3, "jamba-v0.1-52b": 52,
    }[arch]
    n = get_config(arch).param_count() / 1e9
    assert 0.55 * expected_b < n < 1.6 * expected_b, n
