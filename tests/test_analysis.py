"""Tests for the repro-lint static analysis suite (`python -m repro.analysis`).

Each rule gets a fixture pair under tests/fixtures/lint/: the rule must
fire on the `bad/` tree and stay silent on the `good/` one. The suite
also covers the suppression comment syntax, baseline mechanics (including
line-number independence of fingerprints), the CLI exit-code contract,
and a self-check that the real `src/` tree is clean.
"""

from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import ALL_RULES, analyze_paths
from repro.analysis import baseline as baseline_mod
from repro.analysis.common import Project
from repro.analysis.runner import format_vmem_report, run_checks

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "fixtures" / "lint"
BAD = FIXTURES / "bad"
GOOD = FIXTURES / "good"
SUPPRESSED = FIXTURES / "suppressed"


def rules_of(findings):
    return {f.rule for f in findings}


def run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True,
    )


# ---------------------------------------------------------------------------
# fixture pairs: every rule fires on bad/, none fire on good/
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule,needle", [
    ("host-sync", "jax.device_get"),
    ("donation", "read after being donated"),
    ("sharding-spec", "missing field"),
    ("pallas", "divisibility"),
    ("recompile", "branch on traced value"),
])
def test_rule_fires_on_bad_fixture(rule, needle):
    findings = analyze_paths([BAD], root=BAD, rules=[rule])
    assert findings, f"rule {rule} found nothing in the bad fixture"
    assert all(f.rule == rule for f in findings)
    assert any(needle in f.message for f in findings), (
        f"no {rule} finding mentions {needle!r}: "
        + "; ".join(f.message for f in findings)
    )


@pytest.mark.parametrize("rule", ALL_RULES)
def test_rule_silent_on_good_fixture(rule):
    findings = analyze_paths([GOOD], root=GOOD, rules=[rule])
    assert findings == [], [f.render() for f in findings]


def test_bad_fixture_covers_every_subcheck():
    messages = [f.message for f in analyze_paths([BAD], root=BAD)]
    for needle in (
        "jax.device_get",                 # host-sync: always-sync call
        "np.asarray",                     # host-sync: converter on device value
        "read after being donated",       # donation
        "has no placement rule",          # sharding-spec: uncovered container
        "missing field",                  # sharding-spec: stale constructor
        "divisibility guard",             # pallas: grid divisibility
        "index_map closes over",          # pallas: traced index_map capture
        "VMEM footprint",                 # pallas: budget overflow
        "branch on traced value",         # recompile: python branch in jit
        "unhashable literal",             # recompile: unstable static arg
    ):
        assert any(needle in m for m in messages), f"missing sub-check: {needle!r}"


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------


def test_suppression_comment_forms():
    # the suppressed tree repeats bad host-sync sites with both the
    # trailing and the comment-above `# lint: ok(rule, reason)` forms
    findings = analyze_paths([SUPPRESSED], root=SUPPRESSED, rules=["host-sync"])
    assert findings == [], [f.render() for f in findings]


def test_suppression_is_rule_specific(tmp_path):
    tree = tmp_path / "serving"
    tree.mkdir(parents=True)
    src = (SUPPRESSED / "serving" / "engine.py").read_text()
    # annotate for the wrong rule: findings must survive
    tree.joinpath("engine.py").write_text(src.replace("host-sync", "donation"))
    findings = analyze_paths([tmp_path], root=tmp_path, rules=["host-sync"])
    assert len(findings) == 2


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def test_baseline_roundtrip(tmp_path):
    project = Project.load([BAD], BAD)
    findings = run_checks(project, ALL_RULES)
    assert findings
    path = tmp_path / "baseline.json"
    n = baseline_mod.save(path, project, findings)
    assert n == len(findings)
    fresh, matched = baseline_mod.subtract(project, findings, baseline_mod.load(path))
    assert fresh == [] and matched == len(findings)


def test_baseline_fingerprints_survive_line_shifts(tmp_path):
    project = Project.load([BAD], BAD)
    baseline_path = tmp_path / "baseline.json"
    baseline_mod.save(baseline_path, project, run_checks(project, ALL_RULES))

    shifted = tmp_path / "shifted"
    shutil.copytree(BAD, shifted)
    eng = shifted / "serving" / "engine.py"
    eng.write_text("# pushed down\n# by two comment lines\n" + eng.read_text())

    project2 = Project.load([shifted], shifted)
    findings2 = run_checks(project2, ALL_RULES)
    fresh, matched = baseline_mod.subtract(
        project2, findings2, baseline_mod.load(baseline_path)
    )
    assert fresh == [], [f.render() for f in fresh]
    assert matched == len(findings2)


def test_missing_baseline_is_empty(tmp_path):
    assert baseline_mod.load(tmp_path / "nope.json") == {}


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------


def test_cli_exit_one_on_findings():
    res = run_cli(["."], cwd=BAD)
    assert res.returncode == 1
    assert "host-sync" in res.stdout and "pallas" in res.stdout


def test_cli_exit_zero_on_clean_tree():
    res = run_cli(["."], cwd=GOOD)
    assert res.returncode == 0
    assert "0 finding(s)" in res.stderr


def test_cli_json_output():
    res = run_cli([".", "--json"], cwd=BAD)
    payload = json.loads(res.stdout)
    assert payload["checked_files"] == 4
    assert {f["rule"] for f in payload["findings"]} == set(ALL_RULES)
    for f in payload["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "message"}


def test_cli_write_baseline_then_clean(tmp_path):
    bl = tmp_path / "bl.json"
    res = run_cli([".", "--baseline", str(bl), "--write-baseline"], cwd=BAD)
    assert res.returncode == 0, res.stderr
    res = run_cli([".", "--baseline", str(bl)], cwd=BAD)
    assert res.returncode == 0, res.stdout
    assert "baselined" in res.stderr


def test_cli_rules_subset_and_unknown_rule():
    res = run_cli([".", "--rules", "donation"], cwd=BAD)
    assert res.returncode == 1
    assert "host-sync" not in res.stdout
    res = run_cli([".", "--rules", "no-such-rule"], cwd=BAD)
    assert res.returncode == 2


def test_cli_vmem_report():
    res = run_cli([".", "--vmem-report"], cwd=BAD)
    assert "bad_kernel_wrapper" in res.stdout
    assert "OVER" in res.stdout
    res = run_cli([".", "--vmem-report"], cwd=GOOD)
    assert "good_kernel_wrapper" in res.stdout
    assert "OVER" not in res.stdout


def test_vmem_report_resolves_real_kernels():
    project = Project.load([ROOT / "src"], ROOT)
    table = format_vmem_report(project)
    assert "unresolved" not in table
    assert "OVER" not in table


# ---------------------------------------------------------------------------
# self-check: the shipped tree is clean
# ---------------------------------------------------------------------------


def test_src_tree_is_clean():
    res = run_cli(["src"], cwd=ROOT)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "0 finding(s)" in res.stderr


def test_committed_baseline_is_empty():
    # repo policy: fresh sites get an inline `# lint: ok(...)` with a
    # reason, not a baseline entry; the committed baseline stays empty
    assert json.loads((ROOT / ".repro-lint-baseline.json").read_text()) == {}
