"""Tests for the hierarchical KV cache + double FP buffer lifecycle."""

import jax
import numpy as np

from repro.core import hier_kv_cache as C

B, G, H, D, NB = 2, 8, 2, 16, 6


def rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def make_kv(seed, s):
    return rand(seed, (B, s, H, D)), rand(seed + 1000, (B, s, H, D))


def logical_kv(cache, mode="target"):
    """Gather the cache back into a dense [B, S, H, D] pair for checking."""
    k, v, valid, _ = C.materialize(cache, mode)
    idx = np.where(np.asarray(valid))[0]
    return np.asarray(k)[:, idx], np.asarray(v)[:, idx]


class TestPrefill:
    def test_short_prefill_all_in_buffer(self):
        cache = C.init_cache(B, NB, G, H, D)
        k, v = make_kv(0, 5)
        cache = C.prefill(cache, k, v)
        assert int(cache.blocks) == 0
        assert int(cache.buf_len) == 5
        ck, cv = logical_kv(cache)
        np.testing.assert_allclose(ck, k, atol=1e-6)

    def test_long_prefill_splits(self):
        cache = C.init_cache(B, NB, G, H, D)
        s = 3 * G + 3  # -> 2 blocks quantized, G+3 in buffer
        k, v = make_kv(1, s)
        cache = C.prefill(cache, k, v)
        assert int(cache.blocks) == 2
        assert int(cache.buf_len) == G + 3
        assert int(cache.seq_len) == s

    def test_buffer_keeps_recent_fp_exact(self):
        cache = C.init_cache(B, NB, G, H, D)
        s = 2 * G + 1
        k, v = make_kv(2, s)
        cache = C.prefill(cache, k, v)
        ck, cv = logical_kv(cache)
        # trailing G+1 tokens must be bit-exact (FP buffer)
        np.testing.assert_allclose(ck[:, G:], k[:, G:], atol=1e-6)
        np.testing.assert_allclose(cv[:, G:], v[:, G:], atol=1e-6)
        # quantized head tokens close but not exact
        assert np.abs(ck[:, :G] - np.asarray(k)[:, :G]).max() < 0.2

    def test_exact_multiple_of_g(self):
        cache = C.init_cache(B, NB, G, H, D)
        k, v = make_kv(3, 2 * G)
        cache = C.prefill(cache, k, v)
        assert int(cache.blocks) == 1 and int(cache.buf_len) == G


class TestAppendRollbackFlush:
    def _prefilled(self, s=2 * G + 2):
        cache = C.init_cache(B, NB, G, H, D)
        k, v = make_kv(4, s)
        return C.prefill(cache, k, v), k, v

    def test_append(self):
        cache, k, v = self._prefilled()
        nk, nv = make_kv(5, 3)
        cache2 = C.append(cache, nk, nv)
        assert int(cache2.seq_len) == int(cache.seq_len) + 3
        ck, cv = logical_kv(cache2)
        np.testing.assert_allclose(ck[:, -3:], nk, atol=1e-6)

    def test_rollback_drops_tail(self):
        cache, k, v = self._prefilled()
        nk, nv = make_kv(6, 4)
        cache2 = C.rollback(C.append(cache, nk, nv), 3)
        ck, _ = logical_kv(cache2)
        ck0, _ = logical_kv(cache)
        np.testing.assert_allclose(ck[:, -1], nk[:, 0], atol=1e-6)
        assert int(cache2.seq_len) == int(cache.seq_len) + 1

    def test_flush_quantizes_cf1(self):
        cache, k, v = self._prefilled(2 * G + 2)  # buf has G+2
        nk, nv = make_kv(7, G - 3)                # buf -> 2G-1 (full for headroom 1)
        cache = C.append(cache, nk, nv)
        flushed = C.maybe_flush(cache, headroom=1)
        assert int(flushed.blocks) == int(cache.blocks) + 1
        assert int(flushed.buf_len) == int(cache.buf_len) - G
        # logical stream must be preserved (up to quant error on flushed block)
        ck, _ = logical_kv(cache)
        fk, _ = logical_kv(flushed)
        assert ck.shape == fk.shape
        n_fp = int(flushed.buf_len)  # only the remaining buffer stays FP-exact
        np.testing.assert_allclose(ck[:, -n_fp:], fk[:, -n_fp:], atol=1e-6)
        assert np.abs(ck - fk).max() < 0.25  # flushed block only quant-error off

    def test_no_flush_when_room(self):
        cache, *_ = self._prefilled()
        out = C.maybe_flush(cache, headroom=1)
        assert int(out.blocks) == int(cache.blocks)

    def test_flush_is_jittable(self):
        cache, *_ = self._prefilled()
        jitted = jax.jit(lambda c: C.maybe_flush(c, 1))
        out = jitted(cache)
        assert int(out.blocks) == int(cache.blocks)


class TestDraftVsTargetView:
    def test_draft_noisier_than_target(self):
        cache = C.init_cache(B, NB, G, H, D)
        k, v = make_kv(8, 4 * G)
        cache = C.prefill(cache, k, v)
        kd, _, valid, _ = C.materialize(cache, "draft")
        kt, _, _, _ = C.materialize(cache, "target")
        idx = np.where(np.asarray(valid))[0][: 3 * G]  # quantized region
        e_d = np.abs(np.asarray(kd)[:, idx] - np.asarray(k)[:, idx]).mean()
        e_t = np.abs(np.asarray(kt)[:, idx] - np.asarray(k)[:, idx]).mean()
        assert e_t < e_d / 8


class TestWindowCache:
    def test_sink_and_ring(self):
        cache = C.init_window_cache(B, window=8, heads=H, head_dim=D, n_sink=2)
        k, v = make_kv(9, 12)
        cache = C.window_append(cache, k, v)
        assert int(cache.pos) == 12
        # sink holds tokens 0,1
        np.testing.assert_allclose(cache.sink_k, k[:, :2], atol=1e-6)
        # ring holds last 8 of tokens 2..11 -> tokens 4..11 at slots pos%8
        np.testing.assert_allclose(cache.ring_k[:, 11 % 8], k[:, 11], atol=1e-6)
        np.testing.assert_allclose(cache.ring_k[:, 4 % 8], k[:, 4], atol=1e-6)

    def test_rollback_then_rewrite(self):
        cache = C.init_window_cache(B, window=8, heads=H, head_dim=D, n_sink=2)
        k, v = make_kv(10, 10)
        cache = C.window_append(cache, k, v)
        cache = C.window_rollback(cache, 2)
        nk, nv = make_kv(11, 2)
        cache = C.window_append(cache, nk, nv)
        np.testing.assert_allclose(cache.ring_k[:, 9 % 8], nk[:, 1], atol=1e-6)


class TestWindowFastPath:
    def test_t1_fast_equals_scatter(self, monkeypatch):
        import os
        cache_f = C.init_window_cache(B, window=8, heads=H, head_dim=D, n_sink=2)
        cache_s = cache_f
        for t in range(12):
            k, v = make_kv(100 + t, 1)
            monkeypatch.setenv("REPRO_WINDOW_FAST", "1")
            cache_f = C.window_append(cache_f, k, v)
            monkeypatch.setenv("REPRO_WINDOW_FAST", "0")
            cache_s = C.window_append(cache_s, k, v)
        for a, b in zip(cache_f, cache_s):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
