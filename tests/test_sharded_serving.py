"""Mesh-sharded serving: tensor-parallel spec rounds must change the
placement, not the math.

The mesh classes need 8 forced host-platform devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_sharded_serving.py

In a single-device session (the plain tier-1 run) they self-skip and only
the sampling / stats-clamp / mesh-arg units execute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hier_kv_cache as HC
from repro.core import paged_kv_cache as PC
from repro.core.weight_quant import Int4Weight, quantize_tree
from repro.distributed import specs as SP
from repro.distributed.sharding import axis_rules
from repro.kernels import ops as kops
from repro.launch.mesh import make_host_mesh, make_production_mesh, resolve_mesh
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine, Engine
from repro.serving.sampling import sample_token, top_p_filter

NDEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    NDEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def mesh():
    if NDEV < 8:
        pytest.skip("needs 8 host devices")
    return make_host_mesh(4, 2)


def make_prompts(cfg, lens):
    return [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (s,), 0,
        cfg.vocab_size)) for i, s in enumerate(lens)]


# ---------------------------------------------------------------------------
# sampling (no mesh needed)
# ---------------------------------------------------------------------------

class TestTopPFilter:
    def test_tie_at_cutoff_not_leaked(self):
        """`logits < cutoff` kept every entry tying the cutoff logit; the
        rank-based mask keeps exactly the nucleus."""
        probs = jnp.asarray([[0.5, 0.2, 0.2, 0.1]])
        out = top_p_filter(jnp.log(probs), 0.6)
        kept = np.asarray(out > -1e29)[0]
        # nucleus = top-1 (0.5) + one of the tied 0.2 entries, NOT both
        assert kept.sum() == 2
        assert kept[0]
        assert not kept[3]

    def test_top1_always_kept(self):
        logits = jnp.asarray([[0.0, 10.0, -3.0]])
        out = top_p_filter(logits, 1e-6)
        kept = np.asarray(out > -1e29)[0]
        assert kept.tolist() == [False, True, False]

    def test_batched_ranks_independent(self):
        logits = jnp.asarray([[3.0, 2.0, 1.0, 0.0],
                              [0.0, 1.0, 2.0, 3.0]])
        out = top_p_filter(logits, 0.85)
        kept = np.asarray(out > -1e29)
        np.testing.assert_array_equal(kept[0], kept[1][::-1])

    def test_sampling_stays_in_nucleus(self):
        probs = jnp.asarray([0.55, 0.25, 0.15, 0.05])
        logits = jnp.broadcast_to(jnp.log(probs), (64, 4))
        keys = jax.random.split(jax.random.PRNGKey(3), 64)
        toks = jax.vmap(
            lambda l, k: sample_token(l[None], k, top_p=0.7)[0]
        )(logits, keys)
        assert set(np.asarray(toks).tolist()) <= {0, 1}

    def test_top_p_one_is_identity(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (2, 16))
        np.testing.assert_array_equal(np.asarray(top_p_filter(logits, 1.0)),
                                      np.asarray(logits))


class TestTopPEngines:
    def test_static_engine_sampled_top_p(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        eng = Engine(model, params, policy="quantspec", gamma=2,
                     greedy=False, top_p=0.7, max_seq=G + 40)
        prompt = jnp.asarray(make_prompts(cfg, [G + 3])[0])[None]
        res = eng.generate(prompt, 6, key=jax.random.PRNGKey(11))
        assert res.tokens.shape == (1, 6)
        assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()

    def test_continuous_engine_sampled_top_p(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        eng = ContinuousEngine(model, params, gamma=2, greedy=False,
                               top_p=0.8, max_slots=1, max_seq=2 * G)
        (res,) = eng.generate(make_prompts(cfg, [9]), 4,
                              key=jax.random.PRNGKey(5))
        assert res.tokens.shape == (1, 4)


# ---------------------------------------------------------------------------
# continuous-engine stats clamp (no mesh needed)
# ---------------------------------------------------------------------------

class TestStatsClamp:
    def test_round_stats_arithmetic(self):
        from repro.serving.engine import round_stats
        # ordinary round, ample budget: rejections must NOT shrink proposed
        assert round_stats(3, 2, 10) == (2, 3, 1)
        assert round_stats(3, 4, 10) == (4, 3, 3)   # full acceptance
        assert round_stats(3, 1, 10) == (1, 3, 0)   # everything rejected
        # budget-truncated rounds: proposed clamps to the pre-round budget
        # and every kept token is an accepted draft (the bonus token lies
        # beyond the cut), so fully-accepting rounds stay at rate 1.0
        assert round_stats(3, 4, 2) == (2, 2, 2)
        assert round_stats(3, 4, 1) == (1, 1, 1)    # last token
        assert round_stats(3, 1, 2) == (1, 2, 0)    # budget caps proposed,
        #                                             not the round's outcome
        # AR mode (gamma=0)
        assert round_stats(0, 1, 5) == (1, 0, 0)

    def test_truncated_round_not_overcounted(self, tiny):
        """A request hitting max_new_tokens mid-round must not count the
        discarded tail: per round `take` tokens are kept, of which
        `take - 1` (untruncated) or `take` (truncated final round) are
        accepted drafts — so across a request accepted lands in
        [generated - 1 - rounds, generated - rounds], never beyond."""
        cfg, model, params = tiny
        G = cfg.group_size
        gamma = 3
        eng = ContinuousEngine(model, params, gamma=gamma, greedy=True,
                               max_slots=2, max_seq=4 * G)
        prompts = make_prompts(cfg, [9, 17, G + 3])
        reqs = [eng.submit(p, n) for p, n in zip(prompts, (2, 5, 9))]
        eng.run(jax.random.PRNGKey(7))
        for r in reqs:
            assert r.generated == r.max_new_tokens
            lo = r.generated - 1 - r.rounds
            assert lo <= r.accepted <= lo + 1, (
                r.accepted, r.generated, r.rounds)
            assert r.proposed <= gamma * r.rounds
            assert r.accepted <= r.proposed
            assert r.accepted / max(r.proposed, 1) <= 1.0


# ---------------------------------------------------------------------------
# mesh argument validation (no mesh needed)
# ---------------------------------------------------------------------------

class TestMeshValidation:
    def test_production_mesh_validates_device_count(self):
        if jax.device_count() >= 256:
            pytest.skip("enough devices for a production mesh")
        with pytest.raises(ValueError) as e:
            make_production_mesh()
        msg = str(e.value)
        assert "256" in msg and "XLA_FLAGS" in msg

    def test_unknown_spec_rejected(self):
        with pytest.raises(ValueError):
            resolve_mesh("bogus")

    def test_local_always_works(self):
        m = resolve_mesh("local")
        assert dict(m.shape) == {"data": 1, "model": 1}

    @needs_mesh
    def test_host_n_splits_data_model(self):
        m = resolve_mesh("host8")
        assert dict(m.shape) == {"data": 4, "model": 2}
        m = resolve_mesh("host2x4")
        assert dict(m.shape) == {"data": 2, "model": 4}

    @pytest.mark.skipif(NDEV >= 8, reason="clear-error path needs an "
                        "already-initialized small jax")
    def test_host_n_clear_error_when_jax_initialized(self):
        with pytest.raises(ValueError) as e:
            resolve_mesh("host8")
        assert "XLA_FLAGS" in str(e.value)


# ---------------------------------------------------------------------------
# sharded engines: placement changes, tokens don't
# ---------------------------------------------------------------------------

@needs_mesh
class TestShardedStatic:
    def test_token_identical_and_params_sharded(self, tiny, mesh):
        cfg, model, params = tiny
        G = cfg.group_size
        prompt = jnp.stack([jnp.asarray(p) for p in
                            make_prompts(cfg, [2 * G + 5, 2 * G + 5])])
        max_seq = prompt.shape[1] + 12 + 2 * G + 8
        base = Engine(model, params, policy="quantspec", gamma=3,
                      greedy=True, max_seq=max_seq)
        want = base.generate(prompt, 12, key=jax.random.PRNGKey(7)).tokens
        eng = Engine(model, params, policy="quantspec", gamma=3,
                     greedy=True, max_seq=max_seq, mesh=mesh)
        got = eng.generate(prompt, 12, key=jax.random.PRNGKey(7)).tokens
        np.testing.assert_array_equal(got, want)

        # live param placement per param_specs("serve"): stacked wq
        # [n_rep, d, Hq·hd] out-dim → model; wo in-dim → model
        wq = eng.params["blocks"][0]["attn"]["wq"]
        assert tuple(wq.sharding.spec) == (None, None, "model")
        wo = eng.params["blocks"][0]["attn"]["wo"]
        assert "model" in tuple(wo.sharding.spec)
        # Int4 draft: packed planes sharded, not replicated
        dwq = eng.draft_params["blocks"][0]["attn"]["wq"]
        assert isinstance(dwq, Int4Weight)
        assert tuple(dwq.packed.sharding.spec)[-1] == "model"
        assert not dwq.packed.sharding.is_fully_replicated


@needs_mesh
class TestShardedContinuous:
    def test_ragged_token_identical(self, tiny, mesh):
        cfg, model, params = tiny
        G = cfg.group_size
        lens = [2 * G + 5, G + 3, 17]
        max_seq = max(lens) + 8 + 2 * G + 8
        prompts = make_prompts(cfg, lens)
        base = ContinuousEngine(model, params, gamma=3, greedy=True,
                                max_slots=2, max_seq=max_seq)
        want = base.generate(prompts, 8, key=jax.random.PRNGKey(7))
        eng = ContinuousEngine(model, params, gamma=3, greedy=True,
                               max_slots=2, max_seq=max_seq, mesh=mesh)
        got = eng.generate(prompts, 8, key=jax.random.PRNGKey(7))
        for i, (a, b) in enumerate(zip(want, got)):
            np.testing.assert_array_equal(b.tokens, a.tokens,
                                          err_msg=f"request {i}")

    def test_live_pool_placement(self, tiny, mesh):
        """Acceptance criterion: the paged pool is kv-head-sharded on LIVE
        engine arrays (.sharding), not just in dry-run specs — and stays so
        after rounds with donated state."""
        cfg, model, params = tiny
        G = cfg.group_size
        eng = ContinuousEngine(model, params, gamma=3, greedy=True,
                               max_slots=4, max_seq=3 * G, mesh=mesh)
        eng.generate(make_prompts(cfg, [19, 9]), 4,
                     key=jax.random.PRNGKey(3))
        pool = eng.state["blocks"][0][0].primary
        # stacked planes [n_rep, P+1, G, H, X]: heads → model
        assert tuple(pool.k_upper.sharding.spec) == (
            None, None, None, "model")
        assert tuple(pool.v_scale.sharding.spec) == (
            None, None, None, "model")
        # per-slot fp buffers [n_rep, R, 2G, H, D]: slots → data, heads → model
        spec = tuple(pool.buf_k.sharding.spec)
        assert "data" in spec and "model" in spec
        # shared table bookkeeping replicated
        for leaf in jax.tree.leaves(eng.table):
            assert leaf.sharding.is_fully_replicated

    def test_ar_mode_token_identical(self, tiny, mesh):
        cfg, model, params = tiny
        G = cfg.group_size
        prompts = make_prompts(cfg, [11, 7])
        base = ContinuousEngine(model, params, gamma=0, greedy=True,
                                max_slots=2, max_seq=2 * G)
        want = base.generate(prompts, 4, key=jax.random.PRNGKey(7))
        eng = ContinuousEngine(model, params, gamma=0, greedy=True,
                               max_slots=2, max_seq=2 * G, mesh=mesh)
        got = eng.generate(prompts, 4, key=jax.random.PRNGKey(7))
        for a, b in zip(want, got):
            np.testing.assert_array_equal(b.tokens, a.tokens)


# ---------------------------------------------------------------------------
# spec trees
# ---------------------------------------------------------------------------

@needs_mesh
class TestStateSpecsPaged:
    def test_round_trip(self, tiny, mesh):
        """state_specs mirrors the paged state structure exactly and
        device_put lands every leaf on its spec."""
        cfg, model, params = tiny
        state = model.init_serve_state(4, max_seq=4 * cfg.group_size,
                                       policy="paged",
                                       ctx_kw={"pool_blocks": 16})
        specs = SP.state_specs(state, mesh)
        jax.tree.map(lambda a, b: None, state, specs)   # structure match
        placed = jax.device_put(state, specs)
        ok = jax.tree.map(lambda x, s: x.sharding == s, placed, specs)
        assert all(jax.tree.leaves(ok))

    def test_prefill_scratch_specs(self, tiny, mesh):
        cfg, _, _ = tiny
        scr = PC.init_prefill_scratch(256, cfg.group_size,
                                      cfg.num_kv_heads, cfg.hd)
        sp = SP.scratch_specs(scr, mesh)
        assert tuple(sp.k.spec) == (None, None, "model")
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (2,) + x.shape), scr)
        sp2 = SP.scratch_specs(stacked, mesh, stacked=True)
        assert tuple(sp2.k.spec) == (None, None, None, "model")

    def test_table_specs_replicated(self, tiny, mesh):
        table = PC.init_table(4, 8, 16)
        for s in jax.tree.leaves(SP.table_specs(table, mesh)):
            assert s.is_fully_replicated


@needs_mesh
class TestInt4ParamSpecs:
    def test_packed_planes_not_replicated(self, tiny, mesh):
        cfg, model, params = tiny
        drafts = quantize_tree(params, group=cfg.weight_quant_group)
        specs = SP.param_specs(drafts, mesh, "serve")
        placed = jax.device_put(drafts, specs)
        attn = placed["blocks"][0]["attn"]
        mlp = placed["blocks"][0]["mlp"]
        # out-dim-model matrices: packed [n_rep, ng, g/2, dout] → dout model
        for w in (attn["wq"], attn["wk"], attn["wv"], mlp["w_gate"]):
            assert tuple(w.packed.sharding.spec)[-1] == "model"
            assert tuple(w.scale.sharding.spec)[-1] == "model"
            assert not w.packed.sharding.is_fully_replicated
        # in-dim-model matrix: the group axis (d_in//group) → model
        # (w_down: 1024/128 = 8 groups, divisible by the 2-way model axis)
        wd = mlp["w_down"]
        assert tuple(wd.packed.sharding.spec)[1] == "model"
        assert not wd.packed.sharding.is_fully_replicated
        # wo has 384/128 = 3 groups — indivisible by 2, so the divisibility
        # guard falls back to replicating rather than crashing placement
        assert attn["wo"].packed.sharding.is_fully_replicated


# ---------------------------------------------------------------------------
# shard_map kernel entries: parity vs the unsharded wrappers
# ---------------------------------------------------------------------------

@needs_mesh
class TestShardMapKernelParity:
    B, H, Hq, D, G, NB = 4, 2, 4, 32, 8, 3

    def test_hier_attention(self, mesh):
        B, H, Hq, D, G, NB = self.B, self.H, self.Hq, self.D, self.G, self.NB
        key = jax.random.PRNGKey(0)
        cache = HC.init_cache(B, NB, G, H, D)
        k = jax.random.normal(key, (B, 2 * G + 5, H, D))
        v = jax.random.normal(jax.random.fold_in(key, 1), (B, 2 * G + 5, H, D))
        cache = HC.prefill(cache, k, v)
        q = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, Hq, D))
        want = kops.hier_attention(q, cache, 2 * G + 5, "target",
                                   interpret=True)
        with mesh, axis_rules(mesh, "serve"):
            got = kops.hier_attention(q, cache, 2 * G + 5, "target",
                                      interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_paged_hier_attention(self, mesh):
        R, H, Hq, D, G, P = 4, 2, 4, 32, 8, 7
        key = jax.random.PRNGKey(0)
        pool = PC.init_pool(R, P, G, H, D)
        table = PC.init_table(R, 1 + P // R, P)
        table = table._replace(active=jnp.ones((R,), bool))
        for t in range(2 * G - 1):
            table, step = PC.plan_step(table, 1, G)
            kk = jax.random.normal(jax.random.fold_in(key, 100 + t),
                                   (R, 1, H, D))
            vv = jax.random.normal(jax.random.fold_in(key, 200 + t),
                                   (R, 1, H, D))
            pool = PC.apply_step(pool, step, kk, vv)
            table = PC.commit(table, jnp.ones((R,), jnp.int32))
        q = jax.random.normal(jax.random.fold_in(key, 3), (R, 2, Hq, D))
        spos = table.pos - 2
        want = kops.paged_hier_attention(q, pool, table, spos, "draft",
                                         interpret=True)
        with mesh, axis_rules(mesh, "serve"):
            got = kops.paged_hier_attention(q, pool, table, spos, "draft",
                                            interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_prefill_attention(self, mesh):
        B, H, Hq, D = self.B, self.H, self.Hq, self.D
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (B, 16, Hq, D))
        kv = jax.random.normal(jax.random.fold_in(key, 5), (B, 32, H, D))
        want = kops.prefill_attention(q, kv, kv, 8, 24, interpret=True)
        with mesh, axis_rules(mesh, "serve"):
            got = kops.prefill_attention(q, kv, kv, 8, 24, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_indivisible_heads_fall_back(self, mesh):
        """3 kv heads don't divide the 2-way model axis → the plain (GSPMD)
        path runs; results still match."""
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (2, 4, 3, 16))
        kv = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 3, 16))
        want = kops.prefill_attention(q, kv, kv, 4, 8, interpret=True)
        with mesh, axis_rules(mesh, "serve"):
            got = kops.prefill_attention(q, kv, kv, 4, 8, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


@needs_mesh
class TestInt4MatmulShardMap:
    """The fused INT4 dequant×matmul now runs under a model-parallel mesh
    through `kernels.ops.int4_matmul_tp` (instead of the PR 4 bypass to the
    sharded dequant+dot): column-parallel for out-dim-sharded weights,
    row-parallel + psum for in-dim-sharded ones, with the dequant fallback
    kept for non-divisible shapes."""

    def _w(self, din, dout, seed=0):
        from repro.core.weight_quant import quantize_weight
        return quantize_weight(
            jax.random.normal(jax.random.PRNGKey(seed), (din, dout)),
            group=128)

    def test_col_parallel_parity(self, mesh):
        w = self._w(128, 256)                 # d_out 256 % model 2 == 0
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 128))
        want = x @ w.dequant(x.dtype)
        with mesh, axis_rules(mesh, "serve"):
            got = kops.int4_matmul_tp(x, w, "col")
        assert got is not None
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-4)

    def test_row_parallel_parity(self, mesh):
        w = self._w(512, 128)                 # 4 groups % model 2 == 0
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 512))
        want = x @ w.dequant(x.dtype)
        with mesh, axis_rules(mesh, "serve"):
            got = kops.int4_matmul_tp(x, w, "row")
        assert got is not None
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-4)

    def test_non_divisible_falls_back(self, mesh):
        w_col = self._w(128, 129)             # 129 % 2 != 0
        w_row = self._w(128, 128)             # 1 group % 2 != 0
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 128))
        with mesh, axis_rules(mesh, "serve"):
            assert kops.int4_matmul_tp(x, w_col, "col") is None
            assert kops.int4_matmul_tp(x, w_row, "row") is None

    def test_matmul_routes_tp_under_mesh(self, mesh, monkeypatch):
        """`weight_quant.matmul` with a role hint takes the shard_map entry
        under fused impl + mesh, and stays exact vs dequant+dot."""
        from repro.core import weight_quant as WQ
        monkeypatch.setenv("REPRO_QUANT_MATMUL", "fused")
        w = self._w(256, 256)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 2, 256))
        want = x @ w.dequant(x.dtype)
        with mesh, axis_rules(mesh, "serve"):
            for role in ("col", "row"):
                got = WQ.matmul(x, w, tp=role)
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           atol=2e-4, rtol=2e-4)

    def test_fused_engine_token_identical(self, tiny, mesh, monkeypatch):
        """End to end: the continuous engine under a mesh with the fused
        sharded matmul decodes greedily identical to the single-device
        dequant path."""
        cfg, model, params = tiny
        G = cfg.group_size
        prompts = make_prompts(cfg, [19, 9])
        monkeypatch.setenv("REPRO_QUANT_MATMUL", "dequant")
        base = ContinuousEngine(model, params, gamma=3, greedy=True,
                                max_slots=2, max_seq=3 * G)
        want = base.generate(prompts, 6, key=jax.random.PRNGKey(7))
        monkeypatch.setenv("REPRO_QUANT_MATMUL", "fused")
        eng = ContinuousEngine(model, params, gamma=3, greedy=True,
                               max_slots=2, max_seq=3 * G, mesh=mesh)
        got = eng.generate(prompts, 6, key=jax.random.PRNGKey(7))
        for a, b in zip(want, got):
            np.testing.assert_array_equal(b.tokens, a.tokens)
