"""Unit + property tests for hierarchical quantization (core of QuantSpec)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # hypothesis is an optional test dep
    HAS_HYPOTHESIS = False

    def given(**kw):  # noqa: D103 — placeholder so decorators parse
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(**kw):
        return lambda f: f

    class st:  # noqa: D101
        integers = floats = staticmethod(lambda *a, **k: None)

from repro.core import quantization as Q
from repro.core import weight_quant as WQ

jax.config.update("jax_enable_x64", False)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


class TestNibblePacking:
    def test_roundtrip(self):
        x = jnp.arange(64).reshape(4, 16) % 16
        assert (Q.unpack_nibbles(Q.pack_nibbles(x)) == x).all()

    def test_packed_half_size(self):
        x = jnp.zeros((2, 8), jnp.int32)
        assert Q.pack_nibbles(x).shape == (2, 4)


class TestHierarchicalQuant:
    def test_upper_is_4bit_asym(self):
        x = rand(0, (16, 8))
        hq = Q.hier_quantize(x, axis=-1)
        up = Q.unpack_nibbles(hq.upper)
        assert (up >= 0).all() and (up <= 15).all()

    def test_full_matches_int8_error_bound(self):
        """INT8 reconstruction error must be ~S8/2 = S4/32 per element."""
        x = rand(1, (64, 128))
        hq = Q.hier_quantize(x, axis=-1)
        full = Q.dequant_full(hq)
        err = jnp.abs(full - x)
        # allowed: half a lower-plane step, plus clipping slack at group edges
        bound = (hq.scale / 16.0) * 0.51 + 1e-6
        assert (err <= jnp.broadcast_to(bound, err.shape) + hq.scale / 16).all()

    def test_hier_better_than_upper(self):
        x = rand(2, (32, 128))
        hq = Q.hier_quantize(x, axis=-1)
        err_full = jnp.mean((Q.dequant_full(hq) - x) ** 2)
        err_up = jnp.mean((Q.dequant_upper(hq) - x) ** 2)
        assert err_full < err_up / 10  # 4 extra bits => ~256x MSE; 10x is safe

    def test_scale_identity(self):
        """S4 = 16 * S8 and Z4 = Z8: hierarchical INT8 ~= direct INT8."""
        x = rand(3, (8, 256))
        hq = Q.hier_quantize(x, axis=-1)
        direct8 = Q.int8_reference_quant(x, axis=-1)
        # both are 8-bit quantizers over the same range; errors same magnitude
        e_h = jnp.sqrt(jnp.mean((Q.dequant_full(hq) - x) ** 2))
        e_d = jnp.sqrt(jnp.mean((direct8 - x) ** 2))
        assert e_h < 3.0 * e_d + 1e-6

    def test_constant_group_exact(self):
        x = jnp.full((4, 16), 3.25)
        hq = Q.hier_quantize(x, axis=-1)
        np.testing.assert_allclose(Q.dequant_full(hq), x, atol=1e-5)
        np.testing.assert_allclose(Q.dequant_upper(hq), x, atol=1e-5)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           scale=st.floats(1e-3, 1e3),
           offset=st.floats(-100, 100))
    def test_property_error_shrinks_with_bits(self, seed, scale, offset):
        x = rand(seed, (8, 32), scale) + offset
        hq = Q.hier_quantize(x, axis=-1)
        e_up = float(jnp.max(jnp.abs(Q.dequant_upper(hq) - x)))
        e_full = float(jnp.max(jnp.abs(Q.dequant_full(hq) - x)))
        s4 = float(jnp.max(hq.scale))
        assert e_up <= 0.51 * s4 + 1e-5 * abs(offset) + 1e-6
        assert e_full <= e_up + 1e-6

    def test_kv_block_axes(self):
        k = rand(4, (2, 16, 4, 8))  # [B, G, H, D]
        v = rand(5, (2, 16, 4, 8))
        kq = Q.quantize_k_block(k)
        vq = Q.quantize_v_block(v)
        assert kq.scale.shape == (2, 1, 4, 8)   # per-channel
        assert vq.scale.shape == (2, 16, 4, 1)  # per-token
        assert kq.upper.shape == (2, 16, 4, 4)  # packed along D


class TestWeightQuant:
    def test_roundtrip_shape(self):
        w = rand(6, (256, 64))
        qw = WQ.quantize_weight(w, group=128)
        assert qw.shape == (256, 64)
        assert qw.dequant().shape == (256, 64)

    def test_error_bound(self):
        w = rand(7, (256, 64))
        qw = WQ.quantize_weight(w, group=128)
        err = jnp.abs(qw.dequant() - w)
        assert (err <= 0.51 * qw.scale.max() + 1e-6).all()

    def test_stacked_layers(self):
        w = rand(8, (3, 256, 64))  # layer-stacked
        qw = WQ.quantize_weight(w)
        assert qw.shape == (3, 256, 64)
        err = jnp.sqrt(jnp.mean((qw.dequant() - w) ** 2))
        # INT4, groups of 128 over N(0,1): scale ~= 6sigma/15, RMSE ~= scale/sqrt(12)
        assert err < 0.15

    def test_quantize_tree_policy(self):
        params = {"embed": rand(9, (128, 16)), "wq": rand(10, (128, 16)),
                  "norm_scale": jnp.ones((16,))}
        qt = WQ.quantize_tree(params)
        assert isinstance(qt["wq"], WQ.Int4Weight)
        assert not isinstance(qt["embed"], WQ.Int4Weight)
        assert not isinstance(qt["norm_scale"], WQ.Int4Weight)

    def test_resolve(self):
        w = rand(11, (128, 8))
        assert WQ.resolve(w).dtype == jnp.float32
        qw = WQ.quantize_weight(w)
        np.testing.assert_allclose(WQ.resolve(qw), qw.dequant(), atol=0)
