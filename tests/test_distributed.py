"""Distribution-layer tests: logical-axis rules, spec assignment, and a
sharded end-to-end step on a local (1,1) mesh with the production axis
names — the same code path the 256/512-chip dry-run exercises."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import specs as SP
from repro.distributed.sharding import axis_rules, constrain
from repro.launch.mesh import make_local_mesh
from repro.models.stack import StackModel
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step


class TestConstrain:
    def test_noop_outside_context(self):
        x = jnp.ones((4, 4))
        y = constrain(x, "batch", "model")
        assert y is x

    def test_divisibility_fallback(self):
        """36 heads can't take a 16-way axis; kv_seq should claim it."""
        mesh = make_local_mesh()
        with mesh, axis_rules(mesh, "serve"):
            x = jnp.ones((2, 36, 1, 1, 32))
            y = constrain(x, "batch", "kv_heads", None, None, "kv_seq")
            assert y.shape == x.shape  # compiles + runs on 1-device mesh


class TestParamSpecs:
    def test_shapes_respected(self):
        cfg = get_config("llama2-7b-32k", smoke=True)
        model = StackModel(cfg)
        params_sh = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        mesh = make_local_mesh()
        shardings = SP.param_specs(params_sh, mesh, "train")
        # structure mirrors params
        jax.tree.map(lambda a, b: None, params_sh, shardings)

    def test_state_specs_structure(self):
        cfg = get_config("jamba-v0.1-52b", smoke=True)
        model = StackModel(cfg)
        state_sh = jax.eval_shape(
            lambda: model.init_serve_state(2, 128, policy="quantspec"))
        mesh = make_local_mesh()
        sspec = SP.state_specs(state_sh, mesh, long_ctx=False)
        jax.tree.map(lambda a, b: None, state_sh, sspec)


class TestLocalMeshEndToEnd:
    @pytest.mark.parametrize("arch", ["llama2-7b-32k", "qwen3-moe-235b-a22b",
                                      "jamba-v0.1-52b"])
    def test_sharded_train_step(self, arch):
        cfg = get_config(arch, smoke=True)
        model = StackModel(cfg, remat=True)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        opt_state = opt.init(params)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)}
        mesh = make_local_mesh()
        with mesh, axis_rules(mesh, "train"):
            step = jax.jit(make_train_step(model, opt))
            _, _, m = step(params, opt_state, batch)
        assert np.isfinite(float(m["loss"]))

    def test_sharded_decode_step(self):
        cfg = get_config("llama2-7b-32k", smoke=True)
        model = StackModel(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = make_local_mesh()
        with mesh, axis_rules(mesh, "serve"):
            state = model.init_serve_state(2, 96, policy="quantspec")
            tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                                        cfg.vocab_size)
            _, state = model.prefill(params, tokens, state)
            dl, _, _ = jax.jit(
                lambda p, t, s: model.decode(p, t, s, 48, "target"))(
                    params, tokens[:, :1], state)
        assert np.isfinite(np.asarray(dl)).all()
