"""Good fixture spec walker: every container constructed with every field."""


def foo_spec(t):
    return FooState(table=t, scale=t)  # noqa: F821


def bar_spec(t):
    return BarState(packed=t)  # noqa: F821
