"""Good fixture: guarded grid, static index maps, small blocks — silent."""
from jax.experimental import pallas as pl


def kern(r, o):
    o[...] = r[...]


def good_kernel_wrapper(x):
    S, D = x.shape
    bq = 128 if S % 128 == 0 else 1  # guarded: bq always divides S
    grid = (S // bq,)
    spec = pl.BlockSpec((8, 128), lambda i: (i, 0))
    return pl.pallas_call(
        kern, grid=grid, in_specs=[spec], out_specs=spec, out_shape=None
    )(x)
