"""Good fixture: the same shapes done right — every rule must stay silent."""
import jax
import jax.numpy as jnp


def step(state, x):
    return state + x, x


def run_traced(x, *, cfgs):
    return jnp.where(x > 0, x, -x)


class Engine:
    def __init__(self, cfg=None):
        self.cfg = cfg
        self._step = jax.jit(step, donate_argnums=(0,))
        self._jf = jax.jit(run_traced, static_argnames=("cfgs",))

    def generate(self, state):
        for _ in range(4):
            state, y = self._step(state, 1)
        if self.cfg is not None:
            state = self._jf(state, cfgs=(1, 2, 3))
        return state
