"""Good fixture containers: both covered by the spec walker."""
from typing import NamedTuple


class FooState(NamedTuple):
    table: int
    scale: int


class BarState(NamedTuple):
    packed: int
