"""Suppression fixture: the same bad sites as `bad/`, annotated away."""
import jax
import numpy as np


class Engine:
    def generate(self, state):
        # lint: ok(host-sync, fixture exercising the comment-above form)
        mid = jax.device_get(state)
        host = np.asarray(state)  # lint: ok(host-sync, fixture exercising the trailing form)
        return host, mid
