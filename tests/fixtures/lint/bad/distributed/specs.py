"""Bad fixture spec walker: constructs FooState without its `scale` field."""


def foo_spec(t):
    return FooState(table=t)  # noqa: F821
