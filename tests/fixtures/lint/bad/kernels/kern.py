"""Bad fixture: all three pallas sub-checks should fire on this kernel."""
from jax.experimental import pallas as pl


def kern(r, o):
    o[...] = r[...]


def bad_kernel_wrapper(x):
    S, D = x.shape
    bq = 33
    grid = (S // bq,)  # divisibility: no guard that bq divides S
    big = pl.BlockSpec((4096, 4096), lambda i: (i, 0))  # VMEM: blows the budget
    spec = pl.BlockSpec((1, D), lambda i: (i, x))  # index_map closes over traced `x`
    return pl.pallas_call(
        kern, grid=grid, in_specs=[big, spec], out_specs=spec, out_shape=None
    )(x)
