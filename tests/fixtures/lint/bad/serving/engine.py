"""Bad fixture: every engine-side rule should fire on this file."""
import jax
import numpy as np


def step(state, x):
    return state + x, x


def run_traced(x, *, cfgs):
    if x > 0:  # recompile: python branch on a traced value
        return x
    return -x


class Engine:
    def __init__(self):
        self._step = jax.jit(step, donate_argnums=(0,))
        self._jf = jax.jit(run_traced, static_argnames=("cfgs",))

    def generate(self, state):
        for _ in range(4):
            new_state, y = self._step(state, 1)
            mid = jax.device_get(state)  # host-sync: readback inside the decode loop
            total = state.sum()  # donation: `state` read after being donated
            state = new_state
        host = np.asarray(state)  # host-sync: converter on a device value
        bad = self._jf(state, cfgs=[1, 2, 3])  # recompile: unhashable static arg
        return host, mid, total, bad
