"""Bad fixture containers: FooState is under-covered, BarState unmentioned."""
from typing import NamedTuple


class FooState(NamedTuple):
    table: int
    scale: int


class BarState(NamedTuple):
    packed: int
