"""Device-resident decode megastep: fusing K spec rounds into one jitted
program (with on-device budget clamping, EOS detection, and termination
masking) must change dispatch overhead, not tokens — greedy outputs and
per-request acceptance stats are bit-identical to the per-round loop for
every ``rounds_per_step``, on one device and on a host mesh.

The mesh class needs 8 forced host-platform devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_megastep.py
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec_decode import round_stats_dev
from repro.launch.mesh import make_host_mesh
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine, Engine, round_stats

NDEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    NDEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def mesh():
    if NDEV < 8:
        pytest.skip("needs 8 host devices")
    return make_host_mesh(4, 2)


def make_prompts(cfg, lens):
    return [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (s,), 0,
        cfg.vocab_size)) for i, s in enumerate(lens)]


def run_continuous(model, params, prompts, max_new, max_seq, k, **kw):
    """One continuous-engine pass; returns (requests, engine)."""
    eng = ContinuousEngine(model, params, gamma=3, greedy=True, max_slots=2,
                           max_seq=max_seq, rounds_per_step=k, **kw)
    reqs = [eng.submit(p, n) for p, n in zip(prompts, max_new)]
    eng.run(jax.random.PRNGKey(7))
    return reqs, eng


class TestRoundStatsDev:
    def test_matches_host_round_stats(self):
        """The device helper is the same accounting as engine.round_stats,
        over the whole (n_new, budget) grid a γ=3 round can produce."""
        gamma = 3
        for n_new in range(1, gamma + 2):
            for budget in range(0, gamma + 3):
                want = round_stats(gamma, n_new, budget)
                take, prop, acc, eos = round_stats_dev(
                    gamma, jnp.asarray([n_new]), jnp.asarray([budget]))
                assert (int(take[0]), int(prop[0]), int(acc[0])) == want, (
                    n_new, budget)
                assert not bool(eos[0])

    def test_eos_truncates_take(self):
        toks = jnp.asarray([[5, 9, 5, 7],    # eos at kept pos 0
                            [1, 9, 2, 9],    # eos at pos 1, inside take
                            [1, 2, 3, 9],    # eos beyond take → ignored
                            [1, 2, 3, 4]])   # no eos
        n_new = jnp.asarray([3, 4, 4, 4])
        budget = jnp.asarray([10, 10, 3, 10])
        take, _, acc, eos = round_stats_dev(3, n_new, budget, toks, eos_id=9)
        assert take.tolist() == [2, 2, 3, 4]
        assert eos.tolist() == [True, True, False, False]
        # accepted still counts kept accepted drafts only
        assert acc.tolist() == [2, 2, 3, 3]


class TestReleaseSlot:
    def test_matches_host_free_slot(self):
        """The jitted release (traced slot id, masked stack push) produces
        the same table as the host-syncing free_slot."""
        from repro.core import paged_kv_cache as PC
        table = PC.init_table(3, 4, 8)
        table, _ = PC.alloc_blocks(table, 0, 3)
        table, _ = PC.alloc_blocks(table, 1, 2)
        table = PC.admit_slot(table, 0, 24, 8)
        table = PC.admit_slot(table, 1, 16, 8)
        for slot in (0, 1, 2):               # incl. a slot owning 0 blocks
            want = PC.free_slot(table, slot)
            got = jax.jit(PC.release_slot)(table, jnp.asarray(slot))
            for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
                np.testing.assert_array_equal(np.asarray(b), np.asarray(a),
                                              err_msg=f"slot {slot}")


class TestMegastepContinuous:
    def test_token_and_stat_identity_ragged_finishes(self, tiny):
        """Ragged budgets finish mid-megastep at every K; tokens AND
        per-request (proposed, accepted, rounds) match the per-round loop
        exactly — the megastep changes dispatch, not accounting."""
        cfg, model, params = tiny
        G = cfg.group_size
        lens = [2 * G + 5, G + 3, 17]
        max_new = [8, 3, 11]                 # retire at different rounds
        max_seq = max(lens) + max(max_new) + 2 * G + 8
        prompts = make_prompts(cfg, lens)
        base, beng = run_continuous(model, params, prompts, max_new,
                                    max_seq, 0)
        for k in (1, 2, 4, 8):
            reqs, eng = run_continuous(model, params, prompts, max_new,
                                       max_seq, k)
            for i, (a, b) in enumerate(zip(base, reqs)):
                assert b.tokens == a.tokens, f"K={k} request {i}"
                assert (b.proposed, b.accepted, b.rounds) == \
                       (a.proposed, a.accepted, a.rounds), f"K={k} req {i}"
            # the whole pool drains and the slot state parks done
            assert int(eng.table.free_top) == eng.pool_blocks
            assert not eng.scheduler.has_work

    def test_budget_hit_mid_round_trims_tail(self, tiny):
        """max_new_tokens lands mid-round: the kept tail is clamped on
        device exactly as the host clamp did."""
        cfg, model, params = tiny
        G = cfg.group_size
        prompts = make_prompts(cfg, [9])
        for max_new in (2, 4, 5):            # γ=3 rounds emit up to 4
            base, _ = run_continuous(model, params, prompts, [max_new],
                                     3 * G, 0)
            reqs, _ = run_continuous(model, params, prompts, [max_new],
                                     3 * G, 4)
            assert reqs[0].tokens == base[0].tokens
            assert reqs[0].generated == max_new
            assert (reqs[0].proposed, reqs[0].accepted) == \
                   (base[0].proposed, base[0].accepted)

    def test_single_readback_per_megastep(self, tiny):
        """≤1 blocking device→host transfer per dispatched megastep (the
        acceptance criterion the benchmark asserts in CI)."""
        cfg, model, params = tiny
        G = cfg.group_size
        prompts = make_prompts(cfg, [19, 9])
        reqs, eng = run_continuous(model, params, prompts, [8, 8], 3 * G, 4)
        assert eng.decode_steps > 0
        assert eng.host_syncs <= eng.decode_steps
        _, legacy = run_continuous(model, params, prompts, [8, 8], 3 * G, 0)
        assert legacy.host_syncs >= 2 * legacy.decode_steps

    def test_max_new_edge_cases(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        prompts = make_prompts(cfg, [9, 7])
        reqs, eng = run_continuous(model, params, prompts, [0, 1], 3 * G, 4)
        assert reqs[0].tokens == []
        assert len(reqs[1].tokens) == 1
        base, _ = run_continuous(model, params, prompts, [0, 1], 3 * G, 0)
        assert reqs[1].tokens == base[1].tokens
        assert int(eng.table.free_top) == eng.pool_blocks

    def test_eos_stops_request_device_side(self, tiny):
        """EOS sampled mid-stream finishes the request on device: the kept
        tokens end at the first EOS (inclusive), later rounds are frozen,
        and the slot retires at the next harvest."""
        cfg, model, params = tiny
        G = cfg.group_size
        prompts = make_prompts(cfg, [11])
        base, _ = run_continuous(model, params, prompts, [12], 4 * G, 0)
        toks = base[0].tokens
        eos = toks[4]
        first_hit = toks.index(eos)
        reqs, eng = run_continuous(model, params, prompts, [12], 4 * G, 4,
                                   eos_id=eos)
        assert reqs[0].tokens == toks[:first_hit + 1]
        assert int(eng.table.free_top) == eng.pool_blocks
        # EOS as the very first (prefill-sampled) token
        reqs, _ = run_continuous(model, params, prompts, [12], 4 * G, 2,
                                 eos_id=toks[0])
        assert reqs[0].tokens == [toks[0]]

    def test_eos_requires_megastep(self, tiny):
        cfg, model, params = tiny
        with pytest.raises(ValueError):
            ContinuousEngine(model, params, gamma=3, max_slots=1,
                             max_seq=2 * cfg.group_size, rounds_per_step=0,
                             eos_id=3)

    def test_manual_step_then_run(self, tiny):
        """step() drains the pipeline before returning, so mixing manual
        steps with run() keeps request state consistent."""
        cfg, model, params = tiny
        G = cfg.group_size
        eng = ContinuousEngine(model, params, gamma=2, greedy=True,
                               max_slots=1, max_seq=2 * G, rounds_per_step=2)
        req = eng.submit(np.zeros(9, np.int32), 3)
        key = eng.step(jax.random.PRNGKey(0))
        assert eng._inflight is None
        done = eng.run(key)
        assert done == [req] and req.generated == 3


class TestMegastepStatic:
    def test_token_and_stat_identity(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        prompt = jnp.stack([jnp.asarray(p) for p in
                            make_prompts(cfg, [2 * G + 5, 2 * G + 5])])
        max_seq = prompt.shape[1] + 13 + 2 * G + 8
        base = Engine(model, params, policy="quantspec", gamma=3,
                      greedy=True, max_seq=max_seq, rounds_per_step=0)
        want = base.generate(prompt, 13, key=jax.random.PRNGKey(7))
        for k in (1, 2, 4, 8):
            eng = Engine(model, params, policy="quantspec", gamma=3,
                         greedy=True, max_seq=max_seq, rounds_per_step=k)
            got = eng.generate(prompt, 13, key=jax.random.PRNGKey(7))
            np.testing.assert_array_equal(got.tokens, want.tokens,
                                          err_msg=f"K={k}")
            s, w = got.stats, want.stats
            assert (s.proposed, s.accepted, s.rounds, s.generated) == \
                   (w.proposed, w.accepted, w.rounds, w.generated), f"K={k}"
            assert eng.host_syncs <= eng.decode_steps

    def test_sparse_baseline_policy_rides_megastep(self, tiny):
        """The megastep wraps spec_round generically — the StreamingLLM
        draft baseline decodes identically through it."""
        cfg, model, params = tiny
        G = cfg.group_size
        prompt = jnp.asarray(make_prompts(cfg, [G + 5])[0])[None]
        kw = dict(policy="streaming", gamma=1, greedy=True,
                  quantize_weights=False, max_seq=4 * G)
        want = Engine(model, params, rounds_per_step=0, **kw).generate(
            prompt, 7, key=jax.random.PRNGKey(7))
        got = Engine(model, params, rounds_per_step=3, **kw).generate(
            prompt, 7, key=jax.random.PRNGKey(7))
        np.testing.assert_array_equal(got.tokens, want.tokens)


@needs_mesh
class TestMegastepMesh:
    def test_continuous_token_identical_on_host8(self, tiny, mesh):
        cfg, model, params = tiny
        G = cfg.group_size
        lens = [2 * G + 5, G + 3, 17]
        max_seq = max(lens) + 8 + 2 * G + 8
        prompts = make_prompts(cfg, lens)
        base = ContinuousEngine(model, params, gamma=3, greedy=True,
                                max_slots=2, max_seq=max_seq,
                                rounds_per_step=0)
        want = base.generate(prompts, 8, key=jax.random.PRNGKey(7))
        eng = ContinuousEngine(model, params, gamma=3, greedy=True,
                               max_slots=2, max_seq=max_seq,
                               rounds_per_step=4, mesh=mesh)
        got = eng.generate(prompts, 8, key=jax.random.PRNGKey(7))
        for i, (a, b) in enumerate(zip(want, got)):
            np.testing.assert_array_equal(b.tokens, a.tokens,
                                          err_msg=f"request {i}")
        # carried state kept its serve placement through donated megasteps
        pool = eng.state["blocks"][0][0].primary
        assert tuple(pool.k_upper.sharding.spec) == (None, None, None,
                                                     "model")
        for leaf in jax.tree.leaves(eng.slots_dev):
            assert leaf.sharding.is_fully_replicated

    def test_static_token_identical_on_host8(self, tiny, mesh):
        cfg, model, params = tiny
        G = cfg.group_size
        prompt = jnp.stack([jnp.asarray(p) for p in
                            make_prompts(cfg, [2 * G + 5, 2 * G + 5])])
        max_seq = prompt.shape[1] + 12 + 2 * G + 8
        base = Engine(model, params, policy="quantspec", gamma=3,
                      greedy=True, max_seq=max_seq, rounds_per_step=0)
        want = base.generate(prompt, 12, key=jax.random.PRNGKey(7))
        eng = Engine(model, params, policy="quantspec", gamma=3,
                     greedy=True, max_seq=max_seq, rounds_per_step=4,
                     mesh=mesh)
        got = eng.generate(prompt, 12, key=jax.random.PRNGKey(7))
        np.testing.assert_array_equal(got.tokens, want.tokens)
