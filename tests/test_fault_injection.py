"""Fault-injection suite: no failure mode may crash the serve loop.

Every scenario drives ``ContinuousEngine.run()`` to completion under an
injected fault schedule (tests/fault_injection.py) and asserts (a) the
affected request lands in exactly the right terminal status, (b) every
*other* request still completes ``ok`` with greedy outputs token-identical
to an unconstrained reference, and (c) the pool is fully drained — no
leaked blocks, no exception escaping ``run()``.
"""

import jax
import numpy as np
import pytest

from fault_injection import ANY, FaultInjector
from repro.configs import get_config
from repro.core.host_tier import HostTier, SnapshotCorruptionError
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine

MAX_NEW = 8


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_prompts(cfg, lens):
    return [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (s,), 0,
        cfg.vocab_size)) for i, s in enumerate(lens)]


def setup(tiny, *, oversub=True, fault=None, max_new=MAX_NEW,
          max_slots=2, **kw):
    """Engine + prompts; ``oversub=True`` sizes the pool to ~1.5 requests'
    worth of blocks so the 4-request workload must preempt to finish."""
    cfg, model, params = tiny
    G = cfg.group_size
    lens = [2 * G + 5, G + 3, 17, 9]
    max_seq = max(lens) + max_new + 2 * G + 8
    nb = -(-(max(lens) + max_new) // G)
    eng = ContinuousEngine(
        model, params, gamma=3, greedy=True, max_slots=max_slots,
        max_seq=max_seq, pool_blocks=(nb + nb // 2) if oversub else None,
        overflow="preempt", preempt_patience=2, fault=fault, **kw)
    return eng, make_prompts(cfg, lens)


@pytest.fixture(scope="module")
def reference(tiny):
    """Unconstrained-pool greedy outputs for the shared 4-prompt workload."""
    eng, prompts = setup(tiny, oversub=False)
    reqs = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.run(jax.random.PRNGKey(7))
    assert all(r.status == "ok" for r in reqs)
    return [list(r.tokens) for r in reqs]


def check_drained(eng):
    assert int(eng.table.free_top) == eng.pool_blocks
    assert not bool(np.asarray(eng.table.active).any())
    assert eng.scheduler.reserved_blocks == 0
    assert not eng.scheduler.has_work
    if eng.host_tier is not None:
        assert len(eng.host_tier) == 0


class TestTransferFaults:
    def test_transient_failure_retried_to_success(self, tiny, reference):
        """Failures below the retry budget are absorbed: every request
        still completes ``ok``, token-identical, with retries logged."""
        fault = FaultInjector().fail_transfers("offload", count=2)
        eng, prompts = setup(tiny, fault=fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        assert [r.status for r in reqs] == ["ok"] * 4
        assert eng.host_tier.retries >= 2
        assert any(e[0] == "transfer_fail" for e in fault.events)
        for r, ref in zip(reqs, reference):
            assert list(r.tokens) == ref
        check_drained(eng)

    def test_permanent_offload_failure_fails_victim_only(self, tiny):
        """A transfer that outlives the retry budget fails *that* request
        (status ``failed``, reason recorded); the rest still finish."""
        fault = FaultInjector().fail_transfers("offload", count=10_000)
        eng, prompts = setup(tiny, fault=fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        failed = [r for r in reqs if r.status == "failed"]
        assert failed and all("offload failed" in r.reason for r in failed)
        assert all(r.status == "ok" for r in reqs if r not in failed)
        check_drained(eng)

    def test_swapin_corruption_refused(self, tiny):
        """Post-offload bitrot is caught by the restore checksum: the
        corrupted request fails with a swap-in reason, nothing else."""
        fault = FaultInjector().corrupt_snapshot(ANY)
        eng, prompts = setup(tiny, fault=fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        failed = [r for r in reqs if r.status == "failed"]
        assert failed and all(r.reason.startswith("swap-in failed")
                              for r in failed)
        assert all(r.status == "ok" for r in reqs if r not in failed)
        assert any(e[0] == "mangle" for e in fault.events)
        check_drained(eng)


class TestLifecycle:
    def test_midstream_cancel(self, tiny):
        fault = FaultInjector()
        eng, prompts = setup(tiny, oversub=False, fault=fault, max_new=64)
        reqs = [eng.submit(p, 64) for p in prompts[:2]]
        fault.cancel_after(reqs[0], 6)
        eng.run(jax.random.PRNGKey(7))
        assert reqs[0].status == "cancelled"
        assert len(reqs[0].tokens) < 64       # stopped mid-stream
        assert reqs[1].status == "ok" and len(reqs[1].tokens) == 64
        check_drained(eng)

    def test_cancel_queued_request(self, tiny):
        fault = FaultInjector()
        eng, prompts = setup(tiny, oversub=False, fault=fault,
                             max_slots=1)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts[:3]]
        fault.cancel_after(reqs[2], 1)   # still queued behind 1 slot
        eng.run(jax.random.PRNGKey(7))
        assert reqs[2].status == "cancelled" and reqs[2].tokens == []
        assert all(r.status == "ok" for r in reqs[:2])
        check_drained(eng)

    def test_deadline_timeout(self, tiny):
        eng, prompts = setup(tiny, oversub=False, max_new=256)
        slow = eng.submit(prompts[0], 256, deadline_s=1e-4)
        ok = eng.submit(prompts[1], MAX_NEW)
        eng.run(jax.random.PRNGKey(7))
        assert slow.status == "timed_out" and "deadline" in slow.reason
        assert ok.status == "ok" and len(ok.tokens) == MAX_NEW
        check_drained(eng)

    def test_preemption_storm_token_identity(self, tiny, reference):
        """Forced preemptions with no pool pressure: pure scheduling noise
        that must not change a single greedy token."""
        fault = FaultInjector().preemption_storm(3)
        eng, prompts = setup(tiny, oversub=False, fault=fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        assert eng.preempts >= 3 and eng.resumes >= 3
        assert [r.status for r in reqs] == ["ok"] * 4
        for r, ref in zip(reqs, reference):
            assert list(r.tokens) == ref
        check_drained(eng)


class TestAdmissionHardening:
    def test_submit_rejects_without_raising(self, tiny):
        eng, prompts = setup(tiny, oversub=False)
        huge = eng.submit(np.zeros(eng.max_seq, np.int32), 8)
        assert huge.status == "rejected" and "max_seq" in huge.reason
        assert not eng.scheduler.has_work   # never queued

    def test_submit_strict_raises(self, tiny):
        eng, prompts = setup(tiny, oversub=False, strict=True)
        with pytest.raises(ValueError):
            eng.submit(np.zeros(eng.max_seq, np.int32), 8)

    def test_queue_backpressure(self, tiny):
        eng, prompts = setup(tiny, oversub=False, max_pending=1)
        a = eng.submit(prompts[0], MAX_NEW)
        b = eng.submit(prompts[1], MAX_NEW)   # queue is bounded at 1
        assert a.status == "queued"
        assert b.status == "rejected" and "queue full" in b.reason
        eng.run(jax.random.PRNGKey(7))
        assert a.status == "ok"
        check_drained(eng)

    def test_watchdog_fails_unadmittable_head(self, tiny):
        """Regression: a queued request whose reservation can never fit
        (here: the pool is held by phantom index retains) used to spin
        ``run()`` forever — it must fail fast and terminate instead."""
        eng, prompts = setup(tiny, oversub=False)
        req = eng.submit(prompts[0], MAX_NEW)
        eng.scheduler.extra_reserved = eng.pool_blocks   # nothing can fit
        done = eng.run(jax.random.PRNGKey(7))
        assert req in done
        assert req.status == "failed"
        assert "reservation exceeds pool" in req.reason
        assert not eng.scheduler.has_work


class TestHostTierUnit:
    def test_bit_exact_roundtrip(self):
        import jax.numpy as jnp
        planes = [{"k_upper": np.arange(24, dtype=np.uint8).reshape(2, 3, 4),
                   "buf_k": np.linspace(0, 1, 12, dtype=np.float32)}]
        tier = HostTier()
        tier.offload(7, [{k: jnp.asarray(v) for k, v in d.items()}
                         for d in planes], n_blocks=2, buf_len=3,
                     pos=16, last_token=5)
        snap = tier.restore(7)
        assert snap.n_blocks == 2 and snap.pos == 16 and snap.last_token == 5
        np.testing.assert_array_equal(snap.planes[0]["k_upper"],
                                      planes[0]["k_upper"])
        np.testing.assert_array_equal(snap.planes[0]["buf_k"],
                                      planes[0]["buf_k"])
        assert 7 not in tier and tier.bytes_offloaded == snap.nbytes > 0

    def test_corruption_detected(self):
        tier = HostTier()
        tier.offload(3, [{"p": np.zeros(8, np.uint8)}], n_blocks=1,
                     buf_len=0, pos=8, last_token=0)
        snap = tier.materialize(3)
        snap.planes[0]["p"][0] = 1          # bitrot after checksum
        with pytest.raises(SnapshotCorruptionError):
            tier.restore(3)
        assert 3 not in tier                # refused snapshots are dropped
