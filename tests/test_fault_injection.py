"""Fault-injection suite: no failure mode may crash the serve loop.

Every scenario drives ``ContinuousEngine.run()`` to completion under an
injected fault schedule (tests/fault_injection.py) and asserts (a) the
affected request lands in exactly the right terminal status, (b) every
*other* request still completes ``ok`` with greedy outputs token-identical
to an unconstrained reference, and (c) the pool is fully drained — no
leaked blocks, no exception escaping ``run()``.
"""

import jax
import numpy as np
import pytest

from fault_injection import ANY, FaultInjector
from repro.configs import get_config
from repro.core.host_tier import HostTier, SnapshotCorruptionError
from repro.models.stack import StackModel
from repro.serving import journal as J
from repro.serving.engine import ContinuousEngine

MAX_NEW = 8


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_prompts(cfg, lens):
    return [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (s,), 0,
        cfg.vocab_size)) for i, s in enumerate(lens)]


def setup(tiny, *, oversub=True, fault=None, max_new=MAX_NEW,
          max_slots=2, **kw):
    """Engine + prompts; ``oversub=True`` sizes the pool to ~1.5 requests'
    worth of blocks so the 4-request workload must preempt to finish."""
    cfg, model, params = tiny
    G = cfg.group_size
    lens = [2 * G + 5, G + 3, 17, 9]
    max_seq = max(lens) + max_new + 2 * G + 8
    nb = -(-(max(lens) + max_new) // G)
    eng = ContinuousEngine(
        model, params, gamma=3, greedy=True, max_slots=max_slots,
        max_seq=max_seq, pool_blocks=(nb + nb // 2) if oversub else None,
        overflow="preempt", preempt_patience=2, fault=fault, **kw)
    return eng, make_prompts(cfg, lens)


@pytest.fixture(scope="module")
def reference(tiny):
    """Unconstrained-pool greedy outputs for the shared 4-prompt workload."""
    eng, prompts = setup(tiny, oversub=False)
    reqs = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.run(jax.random.PRNGKey(7))
    assert all(r.status == "ok" for r in reqs)
    return [list(r.tokens) for r in reqs]


def check_drained(eng):
    assert int(eng.table.free_top) == eng.pool_blocks
    assert not bool(np.asarray(eng.table.active).any())
    assert eng.scheduler.reserved_blocks == 0
    assert not eng.scheduler.has_work
    if eng.host_tier is not None:
        assert len(eng.host_tier) == 0


class TestTransferFaults:
    def test_transient_failure_retried_to_success(self, tiny, reference):
        """Failures below the retry budget are absorbed: every request
        still completes ``ok``, token-identical, with retries logged."""
        fault = FaultInjector().fail_transfers("offload", count=2)
        eng, prompts = setup(tiny, fault=fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        assert [r.status for r in reqs] == ["ok"] * 4
        assert eng.host_tier.retries >= 2
        assert any(e[0] == "transfer_fail" for e in fault.events)
        for r, ref in zip(reqs, reference):
            assert list(r.tokens) == ref
        check_drained(eng)

    def test_permanent_offload_failure_fails_victim_only(self, tiny):
        """A transfer that outlives the retry budget fails *that* request
        (status ``failed``, reason recorded); the rest still finish."""
        fault = FaultInjector().fail_transfers("offload", count=10_000)
        eng, prompts = setup(tiny, fault=fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        failed = [r for r in reqs if r.status == "failed"]
        assert failed and all("offload failed" in r.reason for r in failed)
        assert all(r.status == "ok" for r in reqs if r not in failed)
        check_drained(eng)

    def test_swapin_corruption_refused(self, tiny):
        """Post-offload bitrot is caught by the restore checksum: the
        corrupted request fails with a swap-in reason, nothing else."""
        fault = FaultInjector().corrupt_snapshot(ANY)
        eng, prompts = setup(tiny, fault=fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        failed = [r for r in reqs if r.status == "failed"]
        assert failed and all(r.reason.startswith("swap-in failed")
                              for r in failed)
        assert all(r.status == "ok" for r in reqs if r not in failed)
        assert any(e[0] == "mangle" for e in fault.events)
        check_drained(eng)


class TestLifecycle:
    def test_midstream_cancel(self, tiny):
        fault = FaultInjector()
        eng, prompts = setup(tiny, oversub=False, fault=fault, max_new=64)
        reqs = [eng.submit(p, 64) for p in prompts[:2]]
        fault.cancel_after(reqs[0], 6)
        eng.run(jax.random.PRNGKey(7))
        assert reqs[0].status == "cancelled"
        assert len(reqs[0].tokens) < 64       # stopped mid-stream
        assert reqs[1].status == "ok" and len(reqs[1].tokens) == 64
        check_drained(eng)

    def test_cancel_queued_request(self, tiny):
        fault = FaultInjector()
        eng, prompts = setup(tiny, oversub=False, fault=fault,
                             max_slots=1)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts[:3]]
        fault.cancel_after(reqs[2], 1)   # still queued behind 1 slot
        eng.run(jax.random.PRNGKey(7))
        assert reqs[2].status == "cancelled" and reqs[2].tokens == []
        assert all(r.status == "ok" for r in reqs[:2])
        check_drained(eng)

    def test_deadline_timeout(self, tiny):
        eng, prompts = setup(tiny, oversub=False, max_new=256)
        slow = eng.submit(prompts[0], 256, deadline_s=1e-4)
        ok = eng.submit(prompts[1], MAX_NEW)
        eng.run(jax.random.PRNGKey(7))
        assert slow.status == "timed_out" and "deadline" in slow.reason
        assert ok.status == "ok" and len(ok.tokens) == MAX_NEW
        check_drained(eng)

    def test_queued_deadline_times_out_unadmitted(self, tiny):
        """Regression: a request whose deadline lapses while it waits
        behind a long wave must retire ``timed_out`` from the *queue* —
        the lifecycle sweep covers pending requests, not only running
        slots, so it never consumes a slot or a prefill chunk."""
        eng, prompts = setup(tiny, oversub=False, max_slots=1, max_new=64)
        long_req = eng.submit(prompts[0], 64)
        waiting = eng.submit(prompts[1], MAX_NEW, deadline_s=1e-4)
        eng.run(jax.random.PRNGKey(7))
        assert waiting.status == "timed_out" and "deadline" in waiting.reason
        assert waiting.admit_seq == -1, "timed-out request was admitted"
        assert waiting.prefill_chunks == 0 and waiting.tokens == []
        assert long_req.status == "ok" and len(long_req.tokens) == 64
        check_drained(eng)

    def test_preemption_storm_token_identity(self, tiny, reference):
        """Forced preemptions with no pool pressure: pure scheduling noise
        that must not change a single greedy token."""
        fault = FaultInjector().preemption_storm(3)
        eng, prompts = setup(tiny, oversub=False, fault=fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        assert eng.preempts >= 3 and eng.resumes >= 3
        assert [r.status for r in reqs] == ["ok"] * 4
        for r, ref in zip(reqs, reference):
            assert list(r.tokens) == ref
        check_drained(eng)


class TestAdmissionHardening:
    def test_submit_rejects_without_raising(self, tiny):
        eng, prompts = setup(tiny, oversub=False)
        huge = eng.submit(np.zeros(eng.max_seq, np.int32), 8)
        assert huge.status == "rejected" and "max_seq" in huge.reason
        assert not eng.scheduler.has_work   # never queued

    def test_submit_strict_raises(self, tiny):
        eng, prompts = setup(tiny, oversub=False, strict=True)
        with pytest.raises(ValueError):
            eng.submit(np.zeros(eng.max_seq, np.int32), 8)

    def test_queue_backpressure(self, tiny):
        eng, prompts = setup(tiny, oversub=False, max_pending=1)
        a = eng.submit(prompts[0], MAX_NEW)
        b = eng.submit(prompts[1], MAX_NEW)   # queue is bounded at 1
        assert a.status == "queued"
        assert b.status == "rejected" and "queue full" in b.reason
        eng.run(jax.random.PRNGKey(7))
        assert a.status == "ok"
        check_drained(eng)

    def test_watchdog_fails_unadmittable_head(self, tiny):
        """Regression: a queued request whose reservation can never fit
        (here: the pool is held by phantom index retains) used to spin
        ``run()`` forever — it must fail fast and terminate instead."""
        eng, prompts = setup(tiny, oversub=False)
        req = eng.submit(prompts[0], MAX_NEW)
        eng.scheduler.extra_reserved = eng.pool_blocks   # nothing can fit
        done = eng.run(jax.random.PRNGKey(7))
        assert req in done
        assert req.status == "failed"
        assert "reservation exceeds pool" in req.reason
        assert not eng.scheduler.has_work


class TestDiskFaults:
    """Three-tier (device → host → disk) failure modes: every disk fault
    must degrade to a single request's ``failed`` status — never an engine
    wedge, never a leaked pool block.

    ``host_capacity_bytes=1`` forces any *second* concurrent host snapshot
    to spill its LRU sibling to disk, and ``preemption_storm(burst=2)``
    creates exactly that concurrency (a lone victim is readmitted before a
    second snapshot joins it)."""

    def three_tier(self, tiny, tmp_path, fault, **kw):
        return setup(tiny, oversub=False, fault=fault,
                     disk_dir=str(tmp_path / "kv"),
                     host_capacity_bytes=1, **kw)

    def test_spill_and_disk_restore_token_identity(self, tiny, tmp_path,
                                                   reference):
        """No faults, just pressure: snapshots spill host → disk and
        stream back bit-exact — greedy outputs are token-identical and
        both tiers drain."""
        fault = FaultInjector().preemption_storm(2, burst=2)
        eng, prompts = self.three_tier(tiny, tmp_path, fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        assert [r.status for r in reqs] == ["ok"] * 4
        assert eng.host_tier.spills >= 1, "host capacity never spilled"
        assert eng.host_tier.disk_restores >= 1, "disk never restored"
        for r, ref in zip(reqs, reference):
            assert list(r.tokens) == ref
        check_drained(eng)
        assert len(eng.disk_tier) == 0 and eng.disk_tier.used_bytes == 0

    def test_disk_eviction_restarts_from_prompt(self, tiny, tmp_path,
                                                reference):
        """The graceful end of the hierarchy: a snapshot the disk tier
        capacity-evicted is *not* a failure — the engine replays that
        request from its prompt and greedy decoding regenerates identical
        tokens.  ``disk_capacity_bytes=1`` makes every spill evict its
        predecessors, so a burst of three concurrent victims leaves the
        first one with no tier holding its snapshot."""
        fault = FaultInjector().preemption_storm(3, burst=3)
        eng, prompts = self.three_tier(tiny, tmp_path, fault, max_slots=3,
                                       disk_capacity_bytes=1)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        assert [r.status for r in reqs] == ["ok"] * 4
        assert eng.host_tier.spills >= 2, "burst never spilled twice"
        assert eng.disk_tier.evictions >= 1, "disk watermark never evicted"
        assert sum(r.restarts for r in reqs) >= 1, \
            "evicted snapshot should have forced a replay-from-prompt"
        for r, ref in zip(reqs, reference):
            assert list(r.tokens) == ref
        check_drained(eng)
        assert len(eng.disk_tier) == 0

    def test_enospc_spill_fails_only_victim(self, tiny, tmp_path, reference):
        """ENOSPC during a host→disk spill: the offload that needed the
        spill fails *its* victim; the spilled-for snapshot stays host-
        resident and every other request completes token-identical."""
        fault = (FaultInjector().preemption_storm(2, burst=2)
                 .fail_disk("put", count=10_000))
        eng, prompts = self.three_tier(tiny, tmp_path, fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        failed = [r for r in reqs if r.status == "failed"]
        assert len(failed) == 1 and "offload failed" in failed[0].reason
        assert all(r.status == "ok" for r in reqs if r not in failed)
        for r, ref in zip(reqs, reference):
            if r.status == "ok":
                assert list(r.tokens) == ref
        assert any(e[0] == "disk_fail" for e in fault.events)
        check_drained(eng)

    @pytest.mark.parametrize("mode", ["torn", "bitrot", "io"])
    def test_disk_readback_fault_fails_only_victim(self, tiny, tmp_path,
                                                   reference, mode):
        """A spilled record that comes back torn (truncated payload),
        bit-flipped (plane CRC mismatch), or unreadable (EIO) fails the
        swap-in of *that* request only."""
        fault = FaultInjector().preemption_storm(2, burst=2)
        if mode == "torn":
            fault.truncate_disk(ANY)
        elif mode == "bitrot":
            fault.corrupt_disk(ANY)
        else:
            import errno
            fault.fail_disk("load", count=10_000, err=errno.EIO)
        eng, prompts = self.three_tier(tiny, tmp_path, fault)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        failed = [r for r in reqs if r.status == "failed"]
        assert len(failed) == 1, \
            f"disk {mode} fault must fail exactly the spilled request"
        assert "swap-in failed" in failed[0].reason
        for r, ref in zip(reqs, reference):
            if r.status == "ok":
                assert list(r.tokens) == ref
        check_drained(eng)
        assert len(eng.disk_tier) == 0

    def test_checkpoint_persist_failure_degrades(self, tiny, tmp_path):
        """ENOSPC while a checkpoint persists host snapshots to disk:
        the skip is journaled, the engine keeps serving, and every request
        still completes (at worst that one replays after a real crash)."""
        fault = (FaultInjector().preemption_storm(2, burst=2)
                 .fail_disk("put", count=10_000))
        eng, prompts = setup(tiny, oversub=False, fault=fault,
                             journal_dir=str(tmp_path / "j"),
                             checkpoint_every=1, prefetch=False)
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        assert [r.status for r in reqs] == ["ok"] * 4
        assert eng.checkpoints >= 1
        events, _ = J.read_events(str(tmp_path / "j"))
        assert any(e["ev"] == "checkpoint_skip" for e in events), \
            "failed persist was not journaled"
        check_drained(eng)


class TestHostTierUnit:
    def test_bit_exact_roundtrip(self):
        import jax.numpy as jnp
        planes = [{"k_upper": np.arange(24, dtype=np.uint8).reshape(2, 3, 4),
                   "buf_k": np.linspace(0, 1, 12, dtype=np.float32)}]
        tier = HostTier()
        tier.offload(7, [{k: jnp.asarray(v) for k, v in d.items()}
                         for d in planes], n_blocks=2, buf_len=3,
                     pos=16, last_token=5)
        snap = tier.restore(7)
        assert snap.n_blocks == 2 and snap.pos == 16 and snap.last_token == 5
        np.testing.assert_array_equal(snap.planes[0]["k_upper"],
                                      planes[0]["k_upper"])
        np.testing.assert_array_equal(snap.planes[0]["buf_k"],
                                      planes[0]["buf_k"])
        assert 7 not in tier and tier.bytes_offloaded == snap.nbytes > 0

    def test_corruption_detected(self):
        tier = HostTier()
        tier.offload(3, [{"p": np.zeros(8, np.uint8)}], n_blocks=1,
                     buf_len=0, pos=8, last_token=0)
        snap = tier.materialize(3)
        snap.planes[0]["p"][0] = 1          # bitrot after checksum
        with pytest.raises(SnapshotCorruptionError):
            tier.restore(3)
        assert 3 not in tier                # refused snapshots are dropped

    def test_backoff_schedule_routes_through_harness(self, monkeypatch):
        """Retry backoff sleeps go through ``fault.sleep``: the schedule
        is asserted deterministically, with zero wall-clock spent."""
        import repro.core.host_tier as HT
        monkeypatch.setattr(
            HT.time, "sleep",
            lambda s: pytest.fail("backoff hit the real time.sleep"))
        fault = FaultInjector().fail_transfers("offload", count=3)
        tier = HostTier(fault=fault, max_retries=3, backoff_s=0.01)
        tier.offload(1, [{"p": np.zeros(8, np.uint8)}], n_blocks=1,
                     buf_len=0, pos=8, last_token=0)
        # three transient failures → exponential schedule, then success
        assert fault.sleeps == [0.01, 0.02, 0.04]
        assert tier.retries == 3
        assert 1 in tier                    # the offload still succeeded
