"""Training substrate tests: loss goes down, optimizer sane, checkpoint
round-trips, remat preserves gradients, per-arch one train step."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data.pipeline import SyntheticCorpus
from repro.models.stack import StackModel
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamW
from repro.training.train_step import lm_loss, make_train_step


def test_loss_decreases():
    cfg = get_config("tiny-lm", smoke=True).replace(vocab_size=64)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3, warmup_steps=5, total_steps=100)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0, bigram_temp=0.25)
    it = corpus.batches(batch=8, seq=64)
    losses = []
    for i in range(30):
        params, opt_state, metrics = step(params, opt_state, next(it))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[::6]
    assert np.isfinite(losses).all()


def test_copy_structure_learnable():
    corpus = SyntheticCorpus(64, seed=0)
    toks = corpus.sample(jax.random.PRNGKey(1), 4, 256)
    assert toks.shape == (4, 256)
    assert corpus.entropy_floor() < np.log(64) * 0.9


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "tiny-lm"])
def test_one_train_step_per_arch(arch):
    cfg = get_config(arch, smoke=True)
    model = StackModel(cfg, remat=True)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    batch = {"tokens": corpus.sample(jax.random.PRNGKey(2), 2, 32)}
    if cfg.num_codebooks:
        batch = {"tokens": jnp.stack(
            [corpus.sample(jax.random.fold_in(jax.random.PRNGKey(2), k), 2, 32)
             for k in range(cfg.num_codebooks)], axis=-1)}
    if cfg.num_image_tokens:
        batch["memory"] = jax.random.normal(
            jax.random.PRNGKey(3), (2, cfg.num_image_tokens, cfg.d_model)) * 0.02
    step = jax.jit(make_train_step(model, opt))
    new_params, _, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     params, new_params))
    assert delta > 0


def test_remat_matches_no_remat():
    cfg = get_config("tiny-lm", smoke=True)
    batch = {"tokens": SyntheticCorpus(cfg.vocab_size).sample(
        jax.random.PRNGKey(1), 2, 32)}
    params = StackModel(cfg).init(jax.random.PRNGKey(0))
    g1 = jax.grad(lambda p: lm_loss(StackModel(cfg, remat=False), p, batch)[0])(params)
    g2 = jax.grad(lambda p: lm_loss(StackModel(cfg, remat=True), p, batch)[0])(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5), g1, g2)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW()
    opt_state = opt.init(params)
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, opt_state, step=7)
    p2, o2, step = load_checkpoint(path, params, opt_state)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, p2)
