"""Unit tests for the disk KV tier (core/disk_tier.py) and the host-tier
spill integration (core/host_tier.py) — pure numpy, no engine, no jax
compilation: the snapshot plane trees are synthetic.

Engine-level three-tier behavior (spill under real preemption pressure,
disk faults degrading to single-request failures) lives in
tests/test_fault_injection.py; crash recovery in tests/test_recovery.py.
"""

from __future__ import annotations

import os

import numpy as np
import pytest

from fault_injection import ANY, FaultInjector
from repro.core.disk_tier import DiskTier, DiskTierError
from repro.core.host_tier import (
    HostTier,
    HostTierError,
    SlotSnapshot,
    SnapshotCorruptionError,
    SnapshotMissError,
    _crc,
)


def make_snap(req_id: int, *, scale: int = 4, seed: int | None = None,
              ) -> SlotSnapshot:
    """A materialized snapshot with the production plane layout in
    miniature: two layers of packed-INT4 planes + fp32 scales + the fp
    double buffer.  ``scale`` multiplies every plane's size."""
    rng = np.random.default_rng(seed if seed is not None else req_id)
    planes = []
    for _ in range(2):
        planes.append({
            "k_upper": rng.integers(0, 256, (2, scale, 4), dtype=np.uint8),
            "k_scale": rng.standard_normal((2, scale)).astype(np.float32),
            "v_upper": rng.integers(0, 256, (2, scale, 4), dtype=np.uint8),
            "buf_k": rng.standard_normal((scale, 4)).astype(np.float32),
        })
    snap = SlotSnapshot(req_id=req_id, n_blocks=2, buf_len=3,
                        pos=17 + req_id, last_token=42, planes=planes)
    snap.checksum = _crc(planes)
    snap.nbytes = sum(leaf.nbytes for layer in planes
                      for leaf in layer.values())
    return snap


def assert_snap_equal(a: SlotSnapshot, b: SlotSnapshot) -> None:
    assert (a.req_id, a.n_blocks, a.buf_len, a.pos, a.last_token) == \
           (b.req_id, b.n_blocks, b.buf_len, b.pos, b.last_token)
    assert len(a.planes) == len(b.planes)
    for la, lb in zip(a.planes, b.planes):
        assert sorted(la) == sorted(lb)
        for key in la:
            assert la[key].dtype == lb[key].dtype
            np.testing.assert_array_equal(la[key], lb[key])


class TestDiskTierUnit:
    def test_roundtrip_bit_exact(self, tmp_path):
        tier = DiskTier(str(tmp_path))
        snap = make_snap(3)
        nbytes = tier.put(snap)
        assert nbytes > snap.nbytes          # payload + header + magic
        assert 3 in tier and len(tier) == 1
        assert tier.used_bytes == nbytes

        back = tier.load(3, pop=False)
        assert_snap_equal(back, snap)
        assert back.materialized and back.checksum == snap.checksum
        assert 3 in tier                     # pop=False keeps the record

        back2 = tier.load(3)                 # default pop=True
        assert_snap_equal(back2, snap)
        assert 3 not in tier and len(tier) == 0
        assert not os.path.exists(os.path.join(str(tmp_path), "req_3.kvsnap"))
        assert tier.stats["puts"] == 1 and tier.stats["loads"] == 2

    def test_put_is_idempotent_per_request(self, tmp_path):
        tier = DiskTier(str(tmp_path))
        tier.put(make_snap(1, seed=0))
        newer = make_snap(1, seed=99)
        tier.put(newer)
        assert len(tier) == 1
        assert_snap_equal(tier.load(1), newer)

    def test_no_tmp_files_survive(self, tmp_path, monkeypatch):
        tier = DiskTier(str(tmp_path))
        tier.put(make_snap(1))
        assert not [n for n in os.listdir(str(tmp_path))
                    if n.endswith(".tmp")]
        # a write that dies at the rename must clean up its temp file and
        # leave the live name untouched (atomicity: old record or none)
        real_replace = os.replace

        def boom(src, dst):
            raise OSError("injected rename failure")

        monkeypatch.setattr(os, "replace", boom)
        with pytest.raises(DiskTierError):
            tier.put(make_snap(2))
        monkeypatch.setattr(os, "replace", real_replace)
        assert 2 not in tier
        names = sorted(os.listdir(str(tmp_path)))
        assert names == ["req_1.kvsnap"], names

    def test_load_missing_raises_keyerror(self, tmp_path):
        tier = DiskTier(str(tmp_path))
        with pytest.raises(KeyError):
            tier.load(7)

    def test_unmaterialized_put_refused(self, tmp_path):
        snap = make_snap(1)
        snap.checksum = None                 # still device-resident
        with pytest.raises(AssertionError):
            DiskTier(str(tmp_path)).put(snap)

    def test_torn_write_refused_and_discarded(self, tmp_path):
        fault = FaultInjector().truncate_disk(ANY)
        tier = DiskTier(str(tmp_path), fault=fault)
        tier.put(make_snap(1))
        with pytest.raises(SnapshotCorruptionError, match="torn|magic|header"):
            tier.load(1)
        assert 1 not in tier                 # refused records are dropped
        assert not os.listdir(str(tmp_path))

    def test_bitrot_refused_by_plane_crc(self, tmp_path):
        fault = FaultInjector().corrupt_disk(ANY)
        tier = DiskTier(str(tmp_path), fault=fault)
        tier.put(make_snap(1))
        with pytest.raises(SnapshotCorruptionError, match="CRC"):
            tier.load(1)
        assert 1 not in tier

    def test_enospc_put_raises_and_registers_nothing(self, tmp_path):
        fault = FaultInjector().fail_disk("put", count=1)
        tier = DiskTier(str(tmp_path), fault=fault)
        with pytest.raises(DiskTierError, match="No space left"):
            tier.put(make_snap(1))
        assert len(tier) == 0 and not os.listdir(str(tmp_path))
        tier.put(make_snap(1))               # fault consumed: next put lands
        assert 1 in tier

    def test_lru_watermark_eviction_exempts_new_record(self, tmp_path):
        tier = DiskTier(str(tmp_path), capacity_bytes=1,
                        high_watermark=1.0, low_watermark=0.8)
        tier.put(make_snap(1))
        tier.put(make_snap(2))               # over watermark: evicts 1
        assert 2 in tier and 1 not in tier, \
            "eviction must spare the record being written"
        assert tier.evictions == 1

    def test_lru_order_is_touch_order(self, tmp_path):
        snaps = {i: make_snap(i) for i in (1, 2, 3)}
        tier = DiskTier(str(tmp_path), low_watermark=1.0)
        one = tier.put(snaps[1])             # actual record size on disk
        tier.put(snaps[2])
        # room for ~2.5 equal-size records: the third put must evict one
        tier.capacity_bytes = int(2.5 * one)
        tier.load(1, pop=False)              # touch 1: now 2 is the LRU
        tier.put(snaps[3])                   # must evict 2, not 1
        assert 2 not in tier
        assert 1 in tier and 3 in tier

    def test_scan_existing_adopts_prior_records(self, tmp_path):
        first = DiskTier(str(tmp_path))
        snaps = [make_snap(5), make_snap(9)]
        for s in snaps:
            first.put(s)
        (tmp_path / "not_a_snapshot.txt").write_text("junk")
        (tmp_path / "req_zz.kvsnap").write_text("unparseable id")

        adopted = DiskTier(str(tmp_path))    # fresh process, same root
        assert sorted([5, 9]) == sorted(
            rid for rid in (5, 9) if rid in adopted)
        assert len(adopted) == 2             # junk names ignored
        for s in snaps:
            assert_snap_equal(adopted.load(s.req_id), s)


class TestHostTierSpill:
    """HostTier + DiskTier integration on synthetic numpy planes."""

    def tiers(self, tmp_path, *, host_cap=1, disk_cap=None, fault=None):
        disk = DiskTier(str(tmp_path), capacity_bytes=disk_cap, fault=fault)
        host = HostTier(fault=fault, capacity_bytes=host_cap, disk=disk)
        return host, disk

    def offload(self, host, snap):
        return host.offload(snap.req_id, snap.planes,
                            n_blocks=snap.n_blocks, buf_len=snap.buf_len,
                            pos=snap.pos, last_token=snap.last_token)

    def test_spill_then_disk_fallback_restore(self, tmp_path):
        host, disk = self.tiers(tmp_path)
        a, b = make_snap(1), make_snap(2)
        self.offload(host, a)
        assert host.spills == 0              # lone snapshot is exempt
        self.offload(host, b)                # over capacity: spills a
        assert host.spills == 1 and 1 not in host and 1 in disk
        assert host.holds(1) and host.holds(2)

        back_a = host.restore(1)             # host miss → disk fallback
        assert host.disk_restores == 1
        assert_snap_equal(back_a, a)
        assert 1 not in disk                 # popped on restore

        back_b = host.restore(2)             # host hit
        assert host.disk_restores == 1
        assert_snap_equal(back_b, b)
        assert len(host) == 0 and len(disk) == 0

    def test_disk_eviction_surfaces_as_miss(self, tmp_path):
        host, disk = self.tiers(tmp_path, disk_cap=1)
        for rid in (1, 2, 3):
            self.offload(host, make_snap(rid))
        # spills: 1 (at 2's offload), then 2 (at 3's) which evicts 1's
        # record under the 1-byte disk watermark
        assert host.spills == 2 and disk.evictions >= 1
        assert not host.holds(1)
        with pytest.raises(SnapshotMissError):
            host.restore(1)                  # caller replays from prompt

    def test_spill_failure_fails_only_new_offload(self, tmp_path):
        fault = FaultInjector().fail_disk("put", count=10_000)
        host, disk = self.tiers(tmp_path, fault=fault)
        a = make_snap(1)
        self.offload(host, a)
        with pytest.raises(HostTierError, match="spill failed"):
            self.offload(host, make_snap(2))
        assert 2 not in host and not host.holds(2)
        assert 1 in host                     # older snapshot stays intact
        assert_snap_equal(host.restore(1), a)

    def test_persist_keeps_host_copy_and_restore_drops_it(self, tmp_path):
        host, disk = self.tiers(tmp_path, host_cap=None)
        a = make_snap(1)
        self.offload(host, a)
        assert host.persist(1) is True       # checkpoint path
        assert 1 in host and 1 in disk
        assert host.persist(99) is False     # unknown request

        back = host.restore(1)               # host hit…
        assert_snap_equal(back, a)
        assert 1 not in disk, "restore must drop the stale persisted copy"

    def test_corrupted_host_snapshot_refused(self, tmp_path):
        fault = FaultInjector().corrupt_snapshot(1)
        host, _ = self.tiers(tmp_path, host_cap=None, fault=fault)
        self.offload(host, make_snap(1))
        with pytest.raises(SnapshotCorruptionError):
            host.restore(1)
        assert 1 not in host
