"""Speculative-decoding correctness tests.

The gold invariant: with greedy sampling and an *identical* draft (FP
weights, FP cache), speculative decoding must produce exactly the same
token stream as plain autoregressive greedy decoding, with 100% acceptance.
With the QuantSpec draft (INT4 weights + upper-4-bit cache) the stream must
still match — the target verifies every token — but acceptance < 100%.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import acceptance
from repro.models.stack import StackModel
from repro.serving.engine import Engine

B, S_PROMPT, MAX_NEW = 2, 40, 24


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama2-7b-32k", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S_PROMPT), 0,
                                cfg.vocab_size)
    return cfg, model, params, prompt


class TestVerifyUnit:
    def _probs(self, key, b, t, v):
        return jax.nn.softmax(jax.random.normal(key, (b, t, v)), -1)

    def test_greedy_all_match(self):
        p = self._probs(jax.random.PRNGKey(0), 2, 5, 11)
        g = jnp.argmax(p[:, :4], -1)
        q = p[:, :4]
        res = acceptance.verify(g, q, p, jax.random.PRNGKey(1), greedy=True)
        assert int(res.n_accepted) == 4
        assert int(res.n_new) == 5
        # bonus token is target argmax at position 4
        np.testing.assert_array_equal(np.asarray(res.tokens[:, 4]),
                                      np.asarray(jnp.argmax(p[:, 4], -1)))

    def test_greedy_first_reject(self):
        p = self._probs(jax.random.PRNGKey(2), 1, 4, 7)
        g = jnp.argmax(p[:, :3], -1)
        g = g.at[0, 1].set((g[0, 1] + 1) % 7)  # break token 1
        res = acceptance.verify(g, p[:, :3], p, jax.random.PRNGKey(3),
                                greedy=True)
        assert int(res.n_accepted) == 1
        # correction token = argmax at rejected position
        assert int(res.tokens[0, 1]) == int(jnp.argmax(p[0, 1], -1))

    def test_stochastic_identical_always_accepts(self):
        p = self._probs(jax.random.PRNGKey(4), 2, 6, 13)
        q = p[:, :5]
        g = jax.random.categorical(jax.random.PRNGKey(5), jnp.log(q), -1)
        res = acceptance.verify(g, q, p, jax.random.PRNGKey(6), greedy=False)
        assert int(res.n_accepted) == 5  # p/q = 1 -> accept surely

    def test_stochastic_preserves_distribution(self):
        """Empirical check of the residual-resampling correctness for a
        single position: histogram of outputs ~ target distribution."""
        v = 5
        p = jnp.array([[0.5, 0.2, 0.1, 0.1, 0.1]])
        q = jnp.array([[0.1, 0.5, 0.2, 0.1, 0.1]])
        n = 4000
        counts = np.zeros(v)
        for i in range(n):
            key = jax.random.PRNGKey(i)
            k1, k2 = jax.random.split(key)
            g = jax.random.categorical(k1, jnp.log(q), -1)
            res = acceptance.verify(
                g[:, None], q[:, None], jnp.stack([p, p], 1), k2)
            counts[int(res.tokens[0, 0])] += 1
        freq = counts / n
        np.testing.assert_allclose(freq, np.asarray(p[0]), atol=0.03)


class TestEngineEquivalence:
    def test_fp_spec_greedy_matches_ar(self, setup):
        cfg, model, params, prompt = setup
        ar = Engine(model, params, policy="fp", gamma=0, greedy=True,
                    max_seq=S_PROMPT + MAX_NEW + 8)
        # identical draft: fp cache policy but speculative rounds
        sp = Engine(model, params, policy="fp", gamma=3, greedy=True,
                    quantize_weights=False,
                    max_seq=S_PROMPT + MAX_NEW + 8)
        r_ar = ar.generate(prompt, MAX_NEW, speculative=False)
        r_sp = sp.generate(prompt, MAX_NEW, speculative=True)
        np.testing.assert_array_equal(r_ar.tokens, r_sp.tokens)
        assert r_sp.stats.acceptance_rate == 1.0

    def test_quantspec_greedy_matches_ar(self, setup):
        """The verified stream equals target-greedy decoding: with greedy
        verification every emitted token is the target's argmax."""
        cfg, model, params, prompt = setup
        qs = Engine(model, params, policy="quantspec", gamma=3, greedy=True,
                    max_seq=S_PROMPT + MAX_NEW + 8)
        # AR with the quantspec cache policy = target view throughout
        ar = Engine(model, params, policy="quantspec", gamma=0, greedy=True,
                    max_seq=S_PROMPT + MAX_NEW + 8)
        r_qs = qs.generate(prompt, MAX_NEW, speculative=True)
        r_ar = ar.generate(prompt, MAX_NEW, speculative=False)
        np.testing.assert_array_equal(r_qs.tokens, r_ar.tokens)
        assert 0.0 < r_qs.stats.acceptance_rate <= 1.0

    def test_baselines_run(self, setup):
        cfg, model, params, prompt = setup
        for policy in ("streaming", "snapkv"):
            eng = Engine(model, params, policy=policy, gamma=2, greedy=True,
                         quantize_weights=False,
                         max_seq=S_PROMPT + MAX_NEW + 8,
                         ctx_kw=dict(draft_window=16, draft_budget=16,
                                     obs_window=8))
            res = eng.generate(prompt, MAX_NEW)
            assert res.tokens.shape == (B, MAX_NEW)
            assert res.stats.rounds > 0

    def test_musicgen_frame_spec(self):
        cfg = get_config("musicgen-large", smoke=True)
        model = StackModel(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (1, 16, cfg.num_codebooks), 0,
                                    cfg.vocab_size)
        eng = Engine(model, params, policy="quantspec", gamma=2, greedy=True,
                     max_seq=64)
        res = eng.generate(prompt, 8)
        assert res.tokens.shape == (1, 8, cfg.num_codebooks)
