"""Deterministic fault-injection harness for the serving engine.

The engine exposes three hook points, all driven by a single
:class:`FaultInjector` instance passed as ``ContinuousEngine(fault=...)``:

* ``tick(engine)`` — called at every lifecycle sweep, which (because a
  non-None ``fault`` forces ``_needs_lifecycle`` True) means every driver
  iteration, always at a harvest boundary with the megastep pipeline
  drained.  The injector can mutate engine state safely here: schedule
  cancellations, force preemption storms, flip counters.
* ``transfer(op, req_id)`` — called by :class:`~repro.core.host_tier
  .HostTier` before every offload/restore transfer; raising
  :class:`~repro.core.host_tier.TransferError` simulates a failed DMA.
  The tier retries with exponential backoff, so an injector that fails
  fewer than ``max_retries`` times exercises the retry path and one that
  always fails exercises the permanent-failure → ``failed`` status path.
* ``mangle(req_id, planes)`` — called on the materialized (host, numpy)
  snapshot *after* its checksum is recorded; corrupting bytes here
  simulates bitrot between offload and restore and must be caught by the
  restore-time checksum verification.
* ``mangle_draft(...)`` (schedule builder) — arms the engine's per-slot
  draft-corruption switches (``ContinuousEngine.set_mangle``) from the
  tick hook: the megastep then deterministically corrupts the armed
  slots' draft token stream *before* verification, collapsing their
  acceptance rate to ~0 without ever touching the target model — the
  stimulus for the precision governor's degradation ladder.  Mode 2
  corrupts only INT4-rung draft samples, so a slot "heals" the moment
  the governor escalates its draft KV read to INT8.
* ``sleep(seconds)`` — replaces the host tier's real backoff sleep:
  retry-storm tests assert the exponential schedule from ``.sleeps``
  instead of paying wall-clock time.
* ``disk(op, req_id)`` — called by :class:`~repro.core.disk_tier
  .DiskTier` before every put/load; raising :class:`OSError` (ENOSPC and
  friends) simulates a full or failing disk, surfaced as ``DiskTierError``.
* ``disk_mangle(req_id, path)`` — called after a successful disk put;
  truncating the file simulates a torn write, flipping payload bytes
  simulates bitrot — both must be caught by the load-time length/CRC
  checks and degrade to that one request.

Everything is deterministic: failures are scheduled by count/req-id, not
sampled, and the event log records exactly what fired in what order so
tests can assert on the sequence.
"""

from __future__ import annotations

import errno
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.host_tier import TransferError

#: wildcard request id for schedule keys
ANY = None


class FaultInjector:
    """Scriptable failure schedule + event log (see module docstring)."""

    def __init__(self):
        self.events: List[tuple] = []
        self.ticks = 0
        # (op, req_id|ANY) -> remaining injected failures for that key
        self._transfer_failures: Dict[Tuple[str, Optional[int]], int] = {}
        self._corrupt: set = set()          # req ids (or ANY) to mangle
        self._cancel_at: List[Tuple[int, object]] = []   # (tick, request)
        self._storm = 0                     # forced preemptions remaining
        self._burst = 1                     # max preemptions per sweep
        self.sleeps: List[float] = []       # absorbed backoff sleeps
        # (op, req_id|ANY) -> (remaining disk failures, errno)
        self._disk_failures: Dict[Tuple[str, Optional[int]],
                                  Tuple[int, int]] = {}
        self._truncate: set = set()         # req ids (or ANY): torn writes
        self._disk_corrupt: set = set()     # req ids (or ANY): bitrot
        # req_id|ANY -> (mode, first tick, stop tick|None): draft mangling
        self._draft_mangle: Dict[Optional[int],
                                 Tuple[int, int, Optional[int]]] = {}

    # ---- schedule builders (chainable) --------------------------------
    def fail_transfers(self, op: str = "offload", req_id: Optional[int] = ANY,
                       count: int = 1) -> "FaultInjector":
        """Fail the next ``count`` ``op`` transfers (for ``req_id``, or any
        request).  ``count`` <= HostTier.max_retries → transient (the retry
        loop absorbs it); larger → permanent failure for that transfer."""
        key = (op, req_id)
        self._transfer_failures[key] = \
            self._transfer_failures.get(key, 0) + count
        return self

    def corrupt_snapshot(self, req_id: Optional[int] = ANY) -> "FaultInjector":
        """Mangle ``req_id``'s (or every) materialized snapshot so its
        restore-time checksum verification must refuse the swap-in."""
        self._corrupt.add(req_id)
        return self

    def cancel_after(self, req, ticks: int) -> "FaultInjector":
        """Request mid-stream cancellation ``ticks`` lifecycle sweeps from
        now (tick 0 = the next sweep)."""
        self._cancel_at.append((self.ticks + ticks, req))
        return self

    def preemption_storm(self, count: int, burst: int = 1) -> "FaultInjector":
        """Force the next ``count`` preemptions, up to ``burst`` eligible
        slots per sweep, regardless of pool pressure.  ``burst > 1`` piles
        snapshots up in the host tier *concurrently* — the only way to
        drive the host-capacity spill (and disk read-back) paths, since a
        lone preempted victim sits at the queue front and is readmitted
        before a second snapshot ever joins it."""
        self._storm += count
        self._burst = max(self._burst, burst)
        return self

    def fail_disk(self, op: str = "put", req_id: Optional[int] = ANY,
                  count: int = 1,
                  err: int = errno.ENOSPC) -> "FaultInjector":
        """Fail the next ``count`` disk ``op``\\ s ("put"/"load") with
        ``OSError(err)`` — ENOSPC by default.  A failed *put* during a
        spill or checkpoint degrades gracefully (the snapshot stays in the
        host store, or the over-capacity offload fails that one request);
        a failed *load* fails the swap-in."""
        key = (op, req_id)
        have = self._disk_failures.get(key, (0, err))[0]
        self._disk_failures[key] = (have + count, err)
        return self

    def truncate_disk(self, req_id: Optional[int] = ANY) -> "FaultInjector":
        """Truncate ``req_id``'s (or every) record after its put — a torn
        write the load-time payload-length check must refuse."""
        self._truncate.add(req_id)
        return self

    def corrupt_disk(self, req_id: Optional[int] = ANY) -> "FaultInjector":
        """Flip a payload byte of ``req_id``'s (or every) record after its
        put — bitrot the load-time plane CRCs must refuse."""
        self._disk_corrupt.add(req_id)
        return self

    def mangle_draft(self, req_id: Optional[int] = ANY, mode: int = 1,
                     after: int = 0,
                     until: Optional[int] = None) -> "FaultInjector":
        """Corrupt ``req_id``'s (or every request's) draft samples from
        ``after`` ticks from now until ``until`` ticks from now (forever
        when None).  ``mode`` 1 corrupts every draft sample; mode 2 only
        INT4-rung samples (healed by the governor's INT8 escalation).
        Greedy outputs are unaffected — rejected drafts are corrected by
        the verify pass — only acceptance collapses, deterministically."""
        self._draft_mangle[req_id] = (
            mode, self.ticks + after,
            None if until is None else self.ticks + until)
        return self

    @property
    def needs_drain(self) -> bool:
        """True when the armed schedules require the engine to drain the
        megastep pipeline every iteration (cancellations and preemption
        storms mutate carried device state at the tick boundary, and the
        transfer/disk/snapshot schedules are asserted against drained
        event orderings).  A draft-mangle-only schedule returns False:
        arming a slot's corruption switch only touches the host-side
        mangle vector read at the *next* dispatch, so the engine keeps
        its dispatch/readback overlap — the governor's collapse stimulus
        doesn't artificially slow the very path it is measuring.

        Subclasses always drain: an overridden ``tick`` can mutate engine
        state (crash injectors preempt and kill mid-run) in ways this
        base-class schedule inspection cannot see, and an undrained
        preemption snapshots in-flight unharvested rounds — the journal's
        stream-position invariant then (correctly) refuses the resume."""
        if type(self) is not FaultInjector:
            return True
        return bool(self._cancel_at or self._storm
                    or self._transfer_failures or self._corrupt
                    or self._disk_failures or self._truncate
                    or self._disk_corrupt)

    # ---- engine hooks --------------------------------------------------
    def tick(self, engine) -> None:
        self.ticks += 1
        if self._draft_mangle:
            for slot, req in engine.scheduler.active.items():
                ent = self._draft_mangle.get(
                    req.req_id, self._draft_mangle.get(ANY))
                mode = 0
                if ent is not None:
                    m, start, stop = ent
                    if self.ticks >= start and (stop is None
                                                or self.ticks < stop):
                        mode = m
                if engine._mangle_host[slot] != mode:
                    engine.set_mangle(slot, mode)
                    self.events.append(
                        ("draft_mangle", req.req_id, slot, mode))
        due = [(t, r) for t, r in self._cancel_at if self.ticks >= t]
        for item in due:
            self._cancel_at.remove(item)
            engine.cancel(item[1])
            self.events.append(("cancel", item[1].req_id, self.ticks))
        if self._storm > 0:
            busy = engine._prefilling.slot if engine._prefilling else None
            want = min(self._storm, self._burst)
            # Select the whole burst up front and only fire once *all* of
            # it is eligible: preempting one victim early would see it
            # readmitted (and its snapshot drained) before a second victim
            # ever joins it in the host tier.
            excl = set() if busy is None else {busy}
            victims = []
            while len(victims) < want:
                victim = engine.scheduler.preemption_victim(
                    exclude=tuple(excl))
                if victim is None:
                    break
                victims.append(victim)
                excl.add(victim)
            if len(victims) == want:
                for victim in victims:
                    self._storm -= 1
                    req_id = engine.scheduler.active[victim].req_id
                    engine._do_preempt(victim)
                    self.events.append(("preempt", req_id, self.ticks))

    def transfer(self, op: str, req_id: int) -> None:
        for key in ((op, req_id), (op, ANY)):
            if self._transfer_failures.get(key, 0) > 0:
                self._transfer_failures[key] -= 1
                self.events.append(("transfer_fail", op, req_id))
                raise TransferError(
                    f"injected {op} failure for request {req_id}")

    def sleep(self, seconds: float) -> None:
        """Injected in place of ``time.sleep`` for retry backoff — record
        the schedule, don't wait it out."""
        self.sleeps.append(seconds)
        self.events.append(("sleep", seconds))

    def disk(self, op: str, req_id: int) -> None:
        for key in ((op, req_id), (op, ANY)):
            left, err = self._disk_failures.get(key, (0, 0))
            if left > 0:
                self._disk_failures[key] = (left - 1, err)
                self.events.append(("disk_fail", op, req_id))
                raise OSError(err, os.strerror(err))

    def disk_mangle(self, req_id: int, path: str) -> None:
        if req_id in self._truncate or ANY in self._truncate:
            size = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.truncate(max(size // 2, 8))   # keep magic+len: torn tail
            self.events.append(("disk_torn", req_id))
        if req_id in self._disk_corrupt or ANY in self._disk_corrupt:
            with open(path, "r+b") as f:
                f.seek(-1, os.SEEK_END)
                byte = f.read(1)
                f.seek(-1, os.SEEK_END)
                f.write(bytes([byte[0] ^ 0xFF]))
            self.events.append(("disk_corrupt", req_id))

    def mangle(self, req_id: int, planes):
        if req_id not in self._corrupt and ANY not in self._corrupt:
            return planes
        # device_get hands back read-only (zero-copy) arrays: rebuild the
        # tree with the first leaf's first byte flipped in a writable copy
        done = [False]

        def rec(x):
            if isinstance(x, dict):
                return {k: rec(v) for k, v in x.items()}
            if isinstance(x, (list, tuple)):
                out = [rec(v) for v in x]
                return out if isinstance(x, list) else tuple(out)
            if not done[0]:
                done[0] = True
                arr = np.array(x)
                arr.reshape(-1).view(np.uint8)[0] ^= 0xFF
                return arr
            return x

        out = rec(planes)
        self.events.append(("mangle", req_id))
        return out
