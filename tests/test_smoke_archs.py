"""Per-architecture smoke tests: reduced same-family configs, one forward
(train) pass + one prefill + one decode step on CPU; asserts shapes and
finiteness. The FULL configs are only exercised via the dry-run."""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.stack import StackModel

SMOKE_ARCHS = [a for a in ARCHS if a not in ("tiny-lm",)]

B, S, T_DEC = 2, 48, 3


def make_inputs(cfg, key, seq=S):
    kt, km = jax.random.split(key)
    if cfg.num_codebooks:
        tokens = jax.random.randint(kt, (B, seq, cfg.num_codebooks), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(kt, (B, seq), 0, cfg.vocab_size)
    memory = None
    if cfg.num_image_tokens:
        memory = jax.random.normal(
            km, (B, cfg.num_image_tokens, cfg.d_model)) * 0.02
    return tokens, memory


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, smoke=True)
            model = StackModel(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_forward_shapes_finite(arch, built):
    cfg, model, params = built(arch)
    tokens, memory = make_inputs(cfg, jax.random.PRNGKey(1))
    logits, aux = model.train_logits(params, tokens, memory=memory)
    if cfg.num_codebooks:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_prefill_then_decode(arch, built):
    cfg, model, params = built(arch)
    tokens, memory = make_inputs(cfg, jax.random.PRNGKey(2))
    state = model.init_serve_state(B, max_seq=S + 16, policy="quantspec")
    logits, state = model.prefill(params, tokens, state, memory=memory)
    assert np.isfinite(np.asarray(logits)).all()

    ntok, _ = make_inputs(cfg, jax.random.PRNGKey(3), seq=T_DEC)
    for kv_mode in ("draft", "target"):
        dl, _, _ = model.decode(params, ntok, state, stream_pos=S,
                                kv_mode=kv_mode)
        assert dl.shape[1] == T_DEC
        assert np.isfinite(np.asarray(dl)).all()


@pytest.mark.parametrize("arch", ["llama2-7b-32k", "jamba-v0.1-52b",
                                  "rwkv6-1.6b"])
def test_decode_consistency_with_forward(arch, built):
    """Greedy decode logits (target view, FP buffer region) must match the
    full-sequence forward logits for positions still in the FP buffer."""
    cfg, model, params = built(arch)
    tokens, memory = make_inputs(cfg, jax.random.PRNGKey(4))
    full_logits, _ = model.train_logits(params, tokens, memory=memory)

    n_ctx = S - 1
    state = model.init_serve_state(B, max_seq=S + 8, policy="quantspec")
    _, state = model.prefill(params, tokens[:, :n_ctx], state, memory=memory)
    dl, _, _ = model.decode(params, tokens[:, n_ctx:], state,
                            stream_pos=n_ctx, kv_mode="target")
    np.testing.assert_allclose(np.asarray(dl[:, 0]),
                               np.asarray(full_logits[:, n_ctx]),
                               atol=0.2, rtol=0.1)
