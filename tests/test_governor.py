"""Acceptance-aware precision governor (ISSUE 10): per-slot γ adaptation
and the INT4 → INT8 → AR degradation ladder with a guaranteed
autoregressive floor.

The invariants under test:

* **Token identity.** Greedy speculative decoding is exact, so NO ladder
  state — forced rungs, governor-driven walks, even deterministically
  corrupted drafts — may change a single output token relative to plain
  target-only AR decode.  The ladder trades *throughput*, never content.
* **Zero recompiles.** Every transition is masking inside the one
  compiled megastep program: the jit cache must hold exactly one entry
  after a full INT4→INT8→AR→probe→recover walk.
* **The walk itself.** Under injected draft corruption
  (`FaultInjector.mangle_draft`) a slot demotes rung by rung to the AR
  floor, probes on schedule, and re-escalates when the corruption lifts
  — while a healthy co-batched slot never leaves the speculative rungs.
* **Acceptance-informed preemption.** Among eligible victims the slot
  with the lowest rolling acceptance goes first, and only slots that
  made forward progress since (re)admission are eligible.

The mesh class needs 8 forced host-platform devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_governor.py
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from fault_injection import FaultInjector
from repro.configs import get_config
from repro.core.spec_decode import (
    RUNG_AR,
    RUNG_INT4,
    RUNG_INT4_LOW,
    RUNG_INT8,
    GovernorConfig,
    governor_plan,
    governor_update,
    round_stats_dev,
)
from repro.launch.mesh import make_host_mesh
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine, Engine, GenStats
from repro.serving.scheduler import Scheduler, init_slot_state

NDEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    NDEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

GOV_KW = dict(governor=True, accept_window=8, accept_floor=0.15,
              accept_ceiling=0.25, probe_every=2)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def mesh():
    if NDEV < 8:
        pytest.skip("needs 8 host devices")
    return make_host_mesh(4, 2)


def make_prompts(cfg, lens):
    return [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (s,), 0,
        cfg.vocab_size)) for i, s in enumerate(lens)]


def run_continuous(model, params, prompts, max_new, max_seq, mangle=None,
                   **kw):
    fault = None
    if mangle is not None:
        fault = FaultInjector().mangle_draft(**mangle)
    eng = ContinuousEngine(model, params, gamma=3, greedy=True, max_slots=2,
                           max_seq=max_seq, rounds_per_step=2, fault=fault,
                           **kw)
    reqs = [eng.submit(p, n) for p, n in zip(prompts, max_new)]
    eng.run(jax.random.PRNGKey(7))
    return reqs, eng


@pytest.fixture(scope="module")
def traffic(tiny):
    """Shared no-governor baseline: (prompts, max_new, max_seq, requests)."""
    cfg, model, params = tiny
    G = cfg.group_size
    lens = [2 * G + 5, G + 3]
    prompts = make_prompts(cfg, lens)
    max_new = [48, 48]
    max_seq = max(lens) + max(max_new) + 2 * G + 8
    base, _ = run_continuous(model, params, prompts, max_new, max_seq)
    return prompts, max_new, max_seq, base


class TestGovernorCore:
    """Pure-function ladder mechanics on synthetic acceptance streams."""

    GOV = GovernorConfig(window=4, floor=0.5, ceiling=0.75, probe_every=3,
                         gamma_lo=0)
    GAMMA = 4

    def _step(self, slots, prop, acc, live=True):
        gamma_eff, draft_bits, probing = governor_plan(
            self.GOV, self.GAMMA, slots)
        slots = governor_update(
            self.GOV, slots, jnp.asarray([live]),
            jnp.asarray([prop], jnp.int32), jnp.asarray([acc], jnp.int32),
            probing)
        return slots, (int(gamma_eff[0]), bool(draft_bits[0]),
                       bool(probing[0]))

    def test_plan_decodes_each_rung(self):
        slots = init_slot_state(4)._replace(
            rung=jnp.asarray([RUNG_INT4, RUNG_INT4_LOW, RUNG_INT8, RUNG_AR]),
            probe=jnp.asarray([0, 0, 0, 2]))
        gamma_eff, draft_bits, probing = governor_plan(
            self.GOV, self.GAMMA, slots)
        assert gamma_eff.tolist() == [4, 2, 4, 0]   # gamma_lo=0 → γ//2
        assert draft_bits.tolist() == [False, False, True, False]
        assert probing.tolist() == [False, False, False, False]

    def test_probe_round_runs_full_gamma_int8(self):
        slots = init_slot_state(1)._replace(
            rung=jnp.asarray([RUNG_AR]), probe=jnp.asarray([0]))
        gamma_eff, draft_bits, probing = governor_plan(
            self.GOV, self.GAMMA, slots)
        assert (int(gamma_eff[0]), bool(draft_bits[0]),
                bool(probing[0])) == (4, True, True)

    def test_full_walk_collapse_probe_recover(self):
        """Collapsed acceptance walks 0→1→2→3; the floor probes on its
        cadence; a clean probe re-escalates to INT8; sustained recovery
        promotes back to INT4 — all in one carried SlotState."""
        slots = init_slot_state(1)
        walk = []
        for _ in range(3):                     # three collapsed windows
            slots, (ge, _b, pr) = self._step(slots, 4, 0)
            assert not pr
            walk.append(int(slots.rung[0]))
        assert walk == [RUNG_INT4_LOW, RUNG_INT8, RUNG_AR]
        assert int(slots.probe[0]) == self.GOV.probe_every
        # AR rounds: no proposals, probe counts down
        for want in (2, 1, 0):
            slots, (ge, _b, pr) = self._step(slots, 0, 0)
            assert (ge, pr) == (0, False)
            assert int(slots.rung[0]) == RUNG_AR
            assert int(slots.probe[0]) == want
        # countdown expired → the next round is a full-γ INT8 probe
        slots2, (ge, bits, pr) = self._step(slots, 4, 4)   # probe accepts
        assert (ge, bits, pr) == (4, True, True)
        assert int(slots2.rung[0]) == RUNG_INT8
        assert int(slots2.win_prop[0]) == 0    # fresh window on the rung
        # a failed probe stays on the floor and re-arms the countdown
        slots3, _ = self._step(slots, 4, 1)
        assert int(slots3.rung[0]) == RUNG_AR
        assert int(slots3.probe[0]) == self.GOV.probe_every
        # sustained recovery climbs the rest of the ladder
        for want in (RUNG_INT4_LOW, RUNG_INT4):
            slots2, _ = self._step(slots2, 4, 4)
            assert int(slots2.rung[0]) == want
        # and a healthy top rung holds steady
        slots2, _ = self._step(slots2, 4, 4)
        assert int(slots2.rung[0]) == RUNG_INT4

    def test_hysteresis_band_holds_rung(self):
        """Rates inside (floor, ceiling) neither demote nor promote, and
        an un-moved evaluated window halves instead of resetting."""
        slots = init_slot_state(1)._replace(rung=jnp.asarray([RUNG_INT8]))
        slots, _ = self._step(slots, 4, 3)     # 0.75 > floor, == ceiling…
        slots = slots._replace(rung=jnp.asarray([RUNG_INT8]))  # (promoted)
        slots, _ = self._step(slots, 4, 2)     # 0.5..0.75 band: hold
        assert int(slots.rung[0]) == RUNG_INT8
        assert int(slots.win_prop[0]) == 2     # 4 // 2: decayed, not reset
        assert int(slots.win_acc[0]) == 1

    def test_dead_slot_frozen(self):
        slots = init_slot_state(1)._replace(rung=jnp.asarray([RUNG_INT8]))
        out, _ = self._step(slots, 4, 0, live=False)
        assert jax.tree.all(jax.tree.map(
            lambda a, b: bool(jnp.all(a == b)), out, slots))


class TestZeroProposedStats:
    """Satellite: AR-floor rounds propose nothing; every acceptance-rate
    reduction must survive proposed == 0 without NaN or div-by-zero."""

    def test_round_stats_dev_zero_gamma(self):
        take, prop, acc, eos = round_stats_dev(
            jnp.asarray([0, 3]), jnp.asarray([1, 4]), jnp.asarray([5, 5]))
        assert prop.tolist() == [0, 3]
        assert take.tolist() == [1, 4]
        assert acc.tolist() == [0, 3]
        assert not any(eos.tolist())

    def test_genstats_rate_zero_proposed(self):
        s = GenStats(proposed=0, accepted=0, rounds=3, generated=3)
        assert s.acceptance_rate == 0.0
        assert np.isfinite(s.acceptance_rate)
        assert s.tokens_per_round == 1.0

    def test_request_rolling_acceptance_fresh(self):
        sched = Scheduler(2, 16, 8)
        req = sched.submit(np.zeros(4, np.int32), 4)
        assert req.rolling_acceptance == 1.0   # optimistic, not NaN
        req.observe_acceptance(0, 0)
        assert req.rolling_acceptance == 1.0


class TestForcedRungStatic:
    """The static engine pins the whole batch to one rung — the identity
    oracle: every rung's greedy output equals target-only AR decode."""

    def test_each_rung_token_identical_to_ar(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        prompt = jnp.stack([jnp.asarray(p) for p in
                            make_prompts(cfg, [G + 5, G + 5])])
        max_seq = prompt.shape[1] + 12 + 2 * G + 8
        kw = dict(policy="quantspec", gamma=3, greedy=True, max_seq=max_seq,
                  rounds_per_step=2)
        ref = Engine(model, params, **kw)
        want = ref.generate(prompt, 12, key=jax.random.PRNGKey(7),
                            speculative=False)
        for rung in (RUNG_INT4_LOW, RUNG_INT8, RUNG_AR):
            eng = Engine(model, params, force_rung=rung, **kw)
            got = eng.generate(prompt, 12, key=jax.random.PRNGKey(7))
            np.testing.assert_array_equal(got.tokens, want.tokens,
                                          err_msg=f"rung {rung}")
            assert eng._mega._cache_size() == 1, f"rung {rung}"
            if rung == RUNG_AR:
                # the floor proposes no drafts at all — and its rate
                # reduction must stay finite
                assert got.stats.proposed == 0
                assert got.stats.acceptance_rate == 0.0
            assert got.stats.generated == 12

    def test_bad_rung_rejected(self, tiny):
        cfg, model, params = tiny
        with pytest.raises(ValueError):
            Engine(model, params, policy="quantspec", force_rung=7,
                   max_seq=2 * cfg.group_size)


class TestGovernorContinuous:
    def test_requires_megastep(self, tiny):
        cfg, model, params = tiny
        for kw in (dict(gamma=0), dict(rounds_per_step=0)):
            with pytest.raises(ValueError):
                ContinuousEngine(model, params, greedy=True, max_slots=1,
                                 max_seq=2 * cfg.group_size, governor=True,
                                 **{**dict(gamma=3, rounds_per_step=2),
                                    **kw})

    def test_clean_traffic_token_identity(self, tiny, traffic):
        """Governor on, no faults: whatever rungs it picks, tokens match
        the no-governor run and the program compiles exactly once."""
        cfg, model, params = tiny
        prompts, max_new, max_seq, base = traffic
        reqs, eng = run_continuous(model, params, prompts, max_new, max_seq,
                                   **GOV_KW)
        for a, b in zip(base, reqs):
            assert b.tokens == a.tokens, f"request {a.req_id}"
        assert eng._mega._cache_size() == 1

    def test_collapse_walks_ladder_and_recovers(self, tiny, traffic):
        """The ISSUE acceptance test: inject total draft corruption into
        one slot for a fixed span.  Its governor must demote it rung by
        rung to the AR floor, keep decoding there (forward progress),
        probe, and re-escalate once the corruption lifts — with zero
        recompiles after warmup and greedy tokens identical to the
        uninterrupted run.  The healthy co-batched slot never visits the
        floor."""
        cfg, model, params = tiny
        prompts, max_new, max_seq, base = traffic
        reqs, eng = run_continuous(
            model, params, prompts, max_new, max_seq,
            mangle=dict(req_id=0, mode=1, after=1, until=11), **GOV_KW)
        for a, b in zip(base, reqs):
            assert b.tokens == a.tokens, f"request {a.req_id}"
        victim, healthy = reqs
        assert victim.demotions >= 3          # walked 0→1→2→3
        assert victim.ar_rounds > 0           # decoded on the floor
        assert victim.int8_rounds > 0         # escalated draft KV reads
        assert victim.promotions >= 1         # probe re-escalated
        assert victim.rung < RUNG_AR          # …and ended off the floor
        assert victim.generated == max_new[0]  # the floor still finishes
        assert healthy.ar_rounds == 0
        # every transition was masking inside the one compiled megastep
        assert eng._mega._cache_size() == 1

    def test_int4_only_corruption_heals_at_int8(self, tiny, traffic):
        """mode=2 corrupts only INT4-rung draft samples: the slot must
        spend recovery time at the INT8 rung (where its drafts are clean
        again) instead of pinning to the AR floor."""
        cfg, model, params = tiny
        prompts, max_new, max_seq, base = traffic
        reqs, _ = run_continuous(
            model, params, prompts, max_new, max_seq,
            mangle=dict(req_id=0, mode=2, after=1), **GOV_KW)
        for a, b in zip(base, reqs):
            assert b.tokens == a.tokens, f"request {a.req_id}"
        victim = reqs[0]
        assert victim.demotions >= 2
        assert victim.int8_rounds > 0


class TestVictimSelection:
    """Satellite: acceptance-informed preemption victim selection."""

    def _sched(self, n=3):
        sched = Scheduler(n, 64, 8)
        reqs = []
        for _ in range(n):
            sched.submit(np.zeros(8, np.int32), 4)
            req = sched.next_admission()
            req.megasteps = 1
            reqs.append(req)
        return sched, reqs

    def test_lowest_rolling_acceptance_goes_first(self):
        sched, (r0, r1, r2) = self._sched()
        r0.win_prop, r0.win_acc = 10, 9
        r1.win_prop, r1.win_acc = 10, 2        # collapsed speculator
        r2.win_prop, r2.win_acc = 10, 5
        assert sched.preemption_victim() == r1.slot
        assert sched.preemption_victim(exclude=(r1.slot,)) == r2.slot

    def test_priority_dominates_acceptance(self):
        sched, (r0, r1, r2) = self._sched()
        r0.win_prop, r0.win_acc = 10, 9
        r1.win_prop, r1.win_acc = 10, 0
        r2.win_prop, r2.win_acc = 10, 5
        r1.priority = 1                        # protected despite collapse
        assert sched.preemption_victim() == r2.slot

    def test_fresh_window_is_optimistic(self):
        """A request with no proposals yet reads 1.0 — it must not be
        mistaken for a collapse victim over a measured-but-mediocre one."""
        sched, (r0, r1, r2) = self._sched()
        r1.win_prop, r1.win_acc = 10, 6        # 0.6 measured
        assert r0.rolling_acceptance == 1.0
        assert sched.preemption_victim() == r1.slot

    def test_forward_progress_eligibility(self):
        """A just-(re)admitted slot (no megastep since) is ineligible, so
        preempt→resume cycles always net progress (no livelock)."""
        sched, (r0, r1, r2) = self._sched()
        r1.win_prop, r1.win_acc = 10, 0
        victim = sched.preemption_victim()
        assert victim == r1.slot
        sched.preempt(victim)
        sched.next_admission()                 # r1 back in, megasteps=0
        assert r1.megasteps == 0
        assert sched.preemption_victim() in (r0.slot, r2.slot)
        r0.megasteps = r2.megasteps = 0
        assert sched.preemption_victim() is None


@needs_mesh
class TestGovernorMesh:
    def test_collapse_token_identical_on_host8(self, tiny, traffic, mesh):
        """The full ladder walk under a 4×2 host mesh: per-slot rung lanes
        shard with the megastep (mangle + rung buffers replicated) and
        greedy tokens still match the single-device no-governor run."""
        cfg, model, params = tiny
        prompts, max_new, max_seq, base = traffic
        reqs, eng = run_continuous(
            model, params, prompts, max_new, max_seq,
            mangle=dict(req_id=0, mode=1, after=1, until=11),
            mesh=mesh, **GOV_KW)
        for a, b in zip(base, reqs):
            assert b.tokens == a.tokens, f"request {a.req_id}"
        assert reqs[0].demotions >= 3 and reqs[0].ar_rounds > 0
        assert eng._mega._cache_size() == 1
        for leaf in jax.tree.leaves(eng.slots_dev):
            assert leaf.sharding.is_fully_replicated
