"""Continuous-batching engine: ragged requests joining and retiring
mid-stream must produce greedy outputs token-identical to the static
engine (continuous batching changes the schedule, not the math)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine, Engine
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_prompts(cfg, lens):
    return [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (s,), 0,
        cfg.vocab_size)) for i, s in enumerate(lens)]


class TestContinuousVsStatic:
    def test_ragged_join_retire_token_identical(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        lens = [2 * G + 5, G + 3, 17]          # ragged; flushes mid-stream
        max_new = 8
        max_seq = max(lens) + max_new + 2 * G + 8
        prompts = make_prompts(cfg, lens)

        static = []
        for p in prompts:
            eng = Engine(model, params, policy="quantspec", gamma=3,
                         greedy=True, max_seq=max_seq)
            res = eng.generate(jax.numpy.asarray(p)[None], max_new,
                               key=jax.random.PRNGKey(7))
            static.append(res.tokens[0])

        # 2 slots for 3 requests → the third joins when a slot retires
        ceng = ContinuousEngine(model, params, gamma=3, greedy=True,
                                max_slots=2, max_seq=max_seq)
        results = ceng.generate(prompts, max_new, key=jax.random.PRNGKey(7))
        for i, r in enumerate(results):
            np.testing.assert_array_equal(r.tokens[0], static[i],
                                          err_msg=f"request {i}")
            assert r.stats.generated == max_new
            assert r.stats.rounds >= 1

    def test_ar_mode(self, tiny):
        """gamma=0 runs plain AR steps on the paged cache."""
        cfg, model, params = tiny
        G = cfg.group_size
        max_seq = 64 + 2 * G
        prompts = make_prompts(cfg, [11, 7])
        static = []
        for p in prompts:
            eng = Engine(model, params, policy="quantspec", gamma=0,
                         greedy=True, max_seq=max_seq)
            res = eng.generate(jax.numpy.asarray(p)[None], 5,
                               key=jax.random.PRNGKey(7), speculative=False)
            static.append(res.tokens[0])
        ceng = ContinuousEngine(model, params, gamma=0, greedy=True,
                                max_slots=2, max_seq=max_seq)
        results = ceng.generate(prompts, 5, key=jax.random.PRNGKey(7))
        for i, r in enumerate(results):
            np.testing.assert_array_equal(r.tokens[0], static[i])

    def test_run_returns_requests_finished_in_manual_steps(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        ceng = ContinuousEngine(model, params, gamma=2, greedy=True,
                                max_slots=1, max_seq=2 * G)
        req = ceng.submit(np.zeros(9, np.int32), 3)
        key = ceng.step(jax.random.PRNGKey(0))   # may finish req entirely
        done = ceng.run(key)
        assert done == [req] and req.generated == 3

    def test_max_new_zero_emits_nothing(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        ceng = ContinuousEngine(model, params, gamma=2, greedy=True,
                                max_slots=1, max_seq=2 * G)
        (res,) = ceng.generate(make_prompts(cfg, [9]), 0)
        assert res.tokens.shape[1] == 0
        assert not ceng.scheduler.has_work

    def test_pool_fully_freed_after_run(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        ceng = ContinuousEngine(model, params, gamma=2, greedy=True,
                                max_slots=2, max_seq=64 + 2 * G)
        ceng.generate(make_prompts(cfg, [19, 23, 9]), 4,
                      key=jax.random.PRNGKey(7))
        assert int(ceng.table.free_top) == ceng.pool_blocks
        assert not bool(np.asarray(ceng.table.active).any())
        assert ceng.scheduler.reserved_blocks == 0


class TestScheduler:
    def test_fcfs_and_capacity(self):
        sched = Scheduler(num_slots=2, pool_blocks=4, group=8)
        a = sched.submit(np.zeros(16, np.int32), 8)   # bound = 3
        b = sched.submit(np.zeros(8, np.int32), 8)    # bound = 2
        assert sched.next_admission() is a
        # b would need 2 more blocks; only 1 unreserved → blocked (FCFS)
        assert sched.next_admission() is None
        sched.retire(a.slot)
        got = sched.next_admission()
        assert got is b and b.slot == 0
        assert sched.reserved_blocks == 2

    def test_no_overtaking(self):
        sched = Scheduler(num_slots=3, pool_blocks=4, group=8)
        a = sched.submit(np.zeros(16, np.int32), 8)      # bound 3
        big = sched.submit(np.zeros(24, np.int32), 8)    # bound 4 — fits an
        small = sched.submit(np.zeros(8, np.int32), 0)   # empty pool; 1 blk
        assert sched.next_admission() is a
        assert sched.next_admission() is None            # head blocks queue
        assert sched.pending[0] is big and small in sched.pending

    def test_impossible_request_rejected_at_submit(self):
        sched = Scheduler(num_slots=2, pool_blocks=3, group=8)
        req = sched.submit(np.zeros(24, np.int32), 8)    # bound 4 > pool 3
        assert req.status == "rejected" and req.done
        assert "pool" in req.reason and not sched.pending

    def test_impossible_request_raises_when_strict(self):
        sched = Scheduler(num_slots=2, pool_blocks=3, group=8, strict=True)
        with pytest.raises(ValueError):                  # bound 4 > pool 3
            sched.submit(np.zeros(24, np.int32), 8)

    def test_gamma_exceeding_group_rejected(self, tiny):
        cfg, model, params = tiny
        with pytest.raises(ValueError):
            ContinuousEngine(model, params, gamma=cfg.group_size,
                             max_slots=1, max_seq=4 * cfg.group_size)
        with pytest.raises(ValueError):
            Engine(model, params, policy="quantspec", gamma=cfg.group_size)

    def test_oversized_request_rejected_by_engine(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        eng = ContinuousEngine(model, params, gamma=2, greedy=True,
                               max_slots=1, max_seq=2 * G)
        req = eng.submit(np.zeros(2 * G, np.int32), 8)
        assert req.status == "rejected" and "max_seq" in req.reason
        assert not eng.scheduler.has_work
        strict = ContinuousEngine(model, params, gamma=2, greedy=True,
                                  max_slots=1, max_seq=2 * G, strict=True)
        with pytest.raises(ValueError):
            strict.submit(np.zeros(2 * G, np.int32), 8)
