"""Fused INT4 dequant×matmul kernel (interpret mode) vs the jnp reference
``Int4Weight.dequant() @ x``, plus the weight_quant dispatch/bookkeeping
satellites (nbytes, compression_ratio)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import weight_quant as WQ
from repro.kernels import quant_matmul as QM

# fp32 accumulation over per-group tiles vs one flat dot: summation-order
# noise only. Documented tolerance for all parity checks in this file.
ATOL = 1e-4
RTOL = 1e-4


@pytest.mark.parametrize("shape", [
    # (M, K, N, group)
    (1, 64, 48, 16),       # decode: single token, narrow out, TN = N
    (4, 256, 128, 128),    # aligned tiles, TN = 128
    (7, 96, 33, 32),       # odd rows / non-128 out dim
    (2, 32, 256, 8),       # many tiny groups, multiple N tiles
    (8, 512, 384, 64),     # multi-tile both axes
])
def test_int4_matmul_vs_dequant(shape):
    M, K, N, g = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, K))
    q = WQ.quantize_weight(w, group=g)
    ref = x @ q.dequant()
    got = QM.int4_matmul(x, q.packed, q.scale, q.zero)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=ATOL, rtol=RTOL)


def test_fused_matmul_leading_dims_and_dtype():
    key = jax.random.PRNGKey(5)
    q = WQ.quantize_weight(jax.random.normal(key, (256, 64)), group=64)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 3, 256))
    got = QM.fused_matmul(x, q)
    assert got.shape == (2, 3, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ q.dequant()),
                               atol=ATOL, rtol=RTOL)

    xb = x.astype(jnp.bfloat16)
    got_b = QM.fused_matmul(xb, q)
    assert got_b.dtype == jnp.bfloat16
    ref_b = (xb @ q.dequant(jnp.bfloat16)).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got_b, np.float32), np.asarray(ref_b),
                               atol=0.15, rtol=0.1)


def test_matmul_dispatch_fused_equals_dequant(monkeypatch):
    """weight_quant.matmul: forced-fused == default dequant path == plain
    fp matmul handling."""
    key = jax.random.PRNGKey(9)
    w = jax.random.normal(key, (128, 96))
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, 128))
    q = WQ.quantize_weight(w, group=32)

    monkeypatch.setenv("REPRO_QUANT_MATMUL", "dequant")
    ref = WQ.matmul(x, q)
    monkeypatch.setenv("REPRO_QUANT_MATMUL", "fused")
    got = WQ.matmul(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=ATOL, rtol=RTOL)
    # unquantized weights bypass the kernel entirely
    np.testing.assert_allclose(np.asarray(WQ.matmul(x, w)), np.asarray(x @ w),
                               atol=1e-6)


def test_matmul_fused_falls_back_on_lead_dims(monkeypatch):
    """3-D (stacked-expert) weights aren't fused — dequant fallback, same
    numbers."""
    key = jax.random.PRNGKey(11)
    w = jax.random.normal(key, (2, 128, 32))
    q = WQ.quantize_weight(w, group=64)
    assert not QM.supports(jnp.zeros((1, 128)), q)
    monkeypatch.setenv("REPRO_QUANT_MATMUL", "fused")
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 128))
    np.testing.assert_allclose(np.asarray(WQ.matmul(x, q)),
                               np.asarray(x @ q.dequant()), atol=1e-6)


def test_nbytes_uses_actual_dtypes():
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
    q = WQ.quantize_weight(w, group=64)
    expected = (q.packed.size * 1
                + q.scale.size * q.scale.dtype.itemsize
                + q.zero.size * q.zero.dtype.itemsize)
    assert q.nbytes == expected
    # scale dtype changes must be reflected, not hard-coded as 4 bytes
    q16 = WQ.Int4Weight(q.packed, q.scale.astype(jnp.bfloat16),
                        q.zero.astype(jnp.bfloat16), q.group)
    assert q16.nbytes == q.packed.size + 2 * 2 * q.scale.size
    assert q16.nbytes < q.nbytes


def test_compression_ratio():
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 128))
    q = WQ.quantize_weight(w, group=128)
    # vs fp16: 16 bits -> 4 bits + scale overhead => between 3x and 4x
    r = float(q.compression_ratio(jnp.float16))
    assert 3.0 < r < 4.0
    assert float(q.compression_ratio(jnp.float32)) == pytest.approx(2 * r)

    params = {"a": q, "b": jnp.zeros((4, 4), jnp.float32)}
    qb, fb, ratio = WQ.tree_compression(params, jnp.float16)
    assert qb == q.nbytes + 64
    assert fb == 512 * 128 * 2 + 64
    assert ratio == pytest.approx(fb / qb)
