"""Preempt-to-host-tier + resume: graceful degradation must be invisible.

Under ``overflow="preempt"`` a pool sized for ~1.5 requests still has to
complete a 4-request workload: the engine swaps a victim's quantized
blocks to host memory (core/host_tier.py), serves the queue head, and
swaps the victim back into freshly popped blocks.  The swap is bit-exact,
so greedy outputs must be **token-identical** to an unconstrained-pool
run — on one device and on the host8 mesh, with and without the prefix
cache — and a resumed request must skip prefill entirely.

The mesh classes need 8 forced host-platform devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine

NDEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    NDEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

MAX_NEW = 8


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def mesh():
    if NDEV < 8:
        pytest.skip("needs 8 host devices")
    return make_host_mesh(4, 2)


def workload(cfg):
    G = cfg.group_size
    lens = [2 * G + 5, G + 3, 17, 9]
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (s,), 0,
        cfg.vocab_size)) for i, s in enumerate(lens)]
    return prompts, max(lens) + MAX_NEW + 2 * G + 8


def make_engine(tiny, *, oversub, **kw):
    cfg, model, params = tiny
    prompts, max_seq = workload(cfg)
    nb = -(-(max(len(p) for p in prompts) + MAX_NEW) // cfg.group_size)
    eng = ContinuousEngine(
        model, params, gamma=3, greedy=True, max_slots=2, max_seq=max_seq,
        pool_blocks=(nb + nb // 2) if oversub else None,
        overflow="preempt", preempt_patience=2, **kw)
    return eng, prompts


@pytest.fixture(scope="module")
def reference(tiny):
    eng, prompts = make_engine(tiny, oversub=False)
    reqs = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.run(jax.random.PRNGKey(7))
    assert all(r.status == "ok" for r in reqs)
    return reqs


def run_oversubscribed(tiny, reference, **kw):
    eng, prompts = make_engine(tiny, oversub=True, **kw)
    reqs = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.run(jax.random.PRNGKey(7))
    assert eng.preempts >= 1 and eng.resumes >= 1
    assert all(r.status == "ok" for r in reqs)
    for i, (r, ref) in enumerate(zip(reqs, reference)):
        np.testing.assert_array_equal(r.tokens, ref.tokens,
                                      err_msg=f"request {i}")
    # drained: every block back on the free stack, host tier empty
    assert int(eng.table.free_top) == eng.pool_blocks
    assert not bool(np.asarray(eng.table.active).any())
    assert len(eng.host_tier) == 0
    return eng, reqs


class TestSingleDevice:
    def test_token_identity_under_oversubscription(self, tiny, reference):
        run_oversubscribed(tiny, reference)

    def test_token_identity_with_prefix_cache(self, tiny, reference):
        eng, _ = run_oversubscribed(tiny, reference, prefix_cache=True)
        assert eng.prefix is not None

    def test_resume_skips_prefill(self, tiny, reference):
        """A resumed request re-enters decode directly: its chunked-prefill
        counter never moves past the original admission."""
        eng, reqs = run_oversubscribed(tiny, reference)
        preempted = [r for r in reqs if r.preemptions > 0]
        assert preempted
        for r, ref in zip(reqs, reference):
            assert r.prefill_chunks == ref.prefill_chunks, \
                f"request {r.req_id} re-ran prefill after resume"

    def test_wait_mode_is_legacy_fcfs(self, tiny, reference):
        """overflow='wait' must still finish (head waits for retirements)
        without ever preempting."""
        cfg, model, params = tiny
        prompts, max_seq = workload(cfg)
        nb = -(-(max(len(p) for p in prompts) + MAX_NEW) // cfg.group_size)
        eng = ContinuousEngine(
            model, params, gamma=3, greedy=True, max_slots=2,
            max_seq=max_seq, pool_blocks=nb + nb // 2, overflow="wait")
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        assert eng.preempts == 0 and eng.host_tier is None
        assert all(r.status == "ok" for r in reqs)
        for r, ref in zip(reqs, reference):
            np.testing.assert_array_equal(r.tokens, ref.tokens)


class TestHost8Mesh:
    @needs_mesh
    def test_token_identity_under_oversubscription(self, tiny, reference,
                                                   mesh):
        run_oversubscribed(tiny, reference, mesh=mesh)

    @needs_mesh
    def test_token_identity_with_prefix_cache(self, tiny, reference, mesh):
        run_oversubscribed(tiny, reference, mesh=mesh, prefix_cache=True)
