"""Preempt-to-host-tier + resume: graceful degradation must be invisible.

Under ``overflow="preempt"`` a pool sized for ~1.5 requests still has to
complete a 4-request workload: the engine swaps a victim's quantized
blocks to host memory (core/host_tier.py), serves the queue head, and
swaps the victim back into freshly popped blocks.  The swap is bit-exact,
so greedy outputs must be **token-identical** to an unconstrained-pool
run — on one device and on the host8 mesh, with and without the prefix
cache — and a resumed request must skip prefill entirely.

The mesh classes need 8 forced host-platform devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from fault_injection import FaultInjector
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine

NDEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    NDEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

MAX_NEW = 8


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def mesh():
    if NDEV < 8:
        pytest.skip("needs 8 host devices")
    return make_host_mesh(4, 2)


def workload(cfg):
    G = cfg.group_size
    lens = [2 * G + 5, G + 3, 17, 9]
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (s,), 0,
        cfg.vocab_size)) for i, s in enumerate(lens)]
    return prompts, max(lens) + MAX_NEW + 2 * G + 8


def make_engine(tiny, *, oversub, **kw):
    cfg, model, params = tiny
    prompts, max_seq = workload(cfg)
    nb = -(-(max(len(p) for p in prompts) + MAX_NEW) // cfg.group_size)
    eng = ContinuousEngine(
        model, params, gamma=3, greedy=True, max_slots=2, max_seq=max_seq,
        pool_blocks=(nb + nb // 2) if oversub else None,
        overflow="preempt", preempt_patience=2, **kw)
    return eng, prompts


@pytest.fixture(scope="module")
def reference(tiny):
    eng, prompts = make_engine(tiny, oversub=False)
    reqs = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.run(jax.random.PRNGKey(7))
    assert all(r.status == "ok" for r in reqs)
    return reqs


def run_oversubscribed(tiny, reference, **kw):
    eng, prompts = make_engine(tiny, oversub=True, **kw)
    reqs = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.run(jax.random.PRNGKey(7))
    assert eng.preempts >= 1 and eng.resumes >= 1
    assert all(r.status == "ok" for r in reqs)
    for i, (r, ref) in enumerate(zip(reqs, reference)):
        np.testing.assert_array_equal(r.tokens, ref.tokens,
                                      err_msg=f"request {i}")
    # drained: every block back on the free stack, host tier empty
    assert int(eng.table.free_top) == eng.pool_blocks
    assert not bool(np.asarray(eng.table.active).any())
    assert len(eng.host_tier) == 0
    return eng, reqs


class TestSingleDevice:
    def test_token_identity_under_oversubscription(self, tiny, reference):
        run_oversubscribed(tiny, reference)

    def test_token_identity_with_prefix_cache(self, tiny, reference):
        eng, _ = run_oversubscribed(tiny, reference, prefix_cache=True)
        assert eng.prefix is not None

    def test_resume_skips_prefill(self, tiny, reference):
        """A resumed request re-enters decode directly: its chunked-prefill
        counter never moves past the original admission."""
        eng, reqs = run_oversubscribed(tiny, reference)
        preempted = [r for r in reqs if r.preemptions > 0]
        assert preempted
        for r, ref in zip(reqs, reference):
            assert r.prefill_chunks == ref.prefill_chunks, \
                f"request {r.req_id} re-ran prefill after resume"

    def test_wait_mode_is_legacy_fcfs(self, tiny, reference):
        """overflow='wait' must still finish (head waits for retirements)
        without ever preempting."""
        cfg, model, params = tiny
        prompts, max_seq = workload(cfg)
        nb = -(-(max(len(p) for p in prompts) + MAX_NEW) // cfg.group_size)
        eng = ContinuousEngine(
            model, params, gamma=3, greedy=True, max_slots=2,
            max_seq=max_seq, pool_blocks=nb + nb // 2, overflow="wait")
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run(jax.random.PRNGKey(7))
        assert eng.preempts == 0 and eng.host_tier is None
        assert all(r.status == "ok" for r in reqs)
        for r, ref in zip(reqs, reference):
            np.testing.assert_array_equal(r.tokens, ref.tokens)


class TestHost8Mesh:
    @needs_mesh
    def test_token_identity_under_oversubscription(self, tiny, reference,
                                                   mesh):
        run_oversubscribed(tiny, reference, mesh=mesh)

    @needs_mesh
    def test_token_identity_with_prefix_cache(self, tiny, reference, mesh):
        run_oversubscribed(tiny, reference, mesh=mesh, prefix_cache=True)


# ---------------------------------------------------------------------------
# preemption × prefix cache: copy-on-preempt must not disturb aliased blocks
# ---------------------------------------------------------------------------

def _toks(seed, n, vocab):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, vocab), np.int32)


def _plane_snapshot(engine, ids):
    """Host copies of every quantized pool plane for ``ids``' rows."""
    ids = jnp.asarray(ids, jnp.int32)
    snap = []

    def fn(mix, _stacked):
        for f in ("k_upper", "k_lower", "k_scale", "k_zero",
                  "v_upper", "v_lower", "v_scale", "v_zero"):
            snap.append(np.asarray(jnp.take(getattr(mix.primary, f), ids,
                                            axis=-4)))
        return mix

    ContinuousEngine._map_attn(engine.state, fn)
    return snap


class _AliasProbe(FaultInjector):
    """Storm injector that records the aliased blocks' refcounts at each
    sweep while the storm is pending — the capture at the firing sweep is
    the at-preemption ground truth."""

    def __init__(self, blocks):
        super().__init__()
        self.blocks = np.asarray(blocks)
        self.seen = []

    def tick(self, engine):
        if self._storm > 0:
            self.seen.append(
                np.asarray(engine.table.refcount)[self.blocks].copy())
        super().tick(engine)


class TestPreemptPrefixAlias:
    """Preempting a request whose page-table row aliases index-retained
    blocks (refcount > 1): the byte-copy snapshot is alias-agnostic and
    the refcount-aware release keeps the shared blocks in place, so the
    indexed planes stay bit-identical, the resumed stream stays
    token-identical, and the drain leaves exactly the index's blocks off
    the free stack."""

    def _run(self, tiny, mesh=None):
        cfg, model, params = tiny
        G = cfg.group_size
        p1 = _toks(31, 3 * G + 8, cfg.vocab_size)               # producer
        p2 = np.concatenate([p1, _toks(32, G, cfg.vocab_size)])  # aliases p1

        def make(prefix, fault=None, with_mesh=False):
            kw = {"mesh": mesh} if (with_mesh and mesh is not None) else {}
            return ContinuousEngine(
                model, params, gamma=2, greedy=True, max_slots=2,
                max_seq=512, rounds_per_step=2, prefill_chunk=G,
                prefix_cache=prefix, overflow="preempt",
                preempt_patience=2, fault=fault, **kw)

        cold = make(prefix=False)
        ref1 = cold.generate([p1], MAX_NEW)[0].tokens[0]
        ref2 = cold.generate([p2], MAX_NEW)[0].tokens[0]

        warm = make(prefix=True, fault=FaultInjector(), with_mesh=True)
        np.testing.assert_array_equal(
            warm.generate([p1], MAX_NEW)[0].tokens[0], ref1)
        shared = sorted(nd.block_id for nd in warm.prefix._iter_nodes())
        # the ragged tail group stays private: 3G+8 tokens index 2 groups
        assert len(shared) == 2
        before = _plane_snapshot(warm, shared)

        # re-arm with a probing storm: p2 admits through the cache, then
        # gets preempted mid-decode while it aliases the indexed blocks
        probe = _AliasProbe(shared).preemption_storm(1)
        warm.fault = probe
        req2 = warm.submit(p2, MAX_NEW)
        warm.run(jax.random.PRNGKey(3))
        assert req2.status == "ok" and req2.preemptions >= 1
        assert warm.prefix.stats["hits"] >= 1, "p2 never aliased the index"
        # only chain[:-1] is aliased into the slot row (the last matched
        # group is re-packed privately as the COW tail), so exactly the
        # shared interior carries refcount > 1 at preemption
        assert probe.seen and (probe.seen[-1] >= 2).any(), \
            "no aliased block was refcount>1 at preemption"
        np.testing.assert_array_equal(req2.tokens, ref2)

        after = _plane_snapshot(warm, shared)
        for b, a in zip(before, after):
            np.testing.assert_array_equal(b, a)
        # drain: only the index's blocks stay off the free stack, each
        # held by exactly its index reference
        held = warm.prefix.blocks
        assert int(warm.table.free_top) == warm.pool_blocks - held
        assert (np.asarray(warm.table.refcount)[shared] == 1).all()
        assert len(warm.host_tier) == 0

    def test_single_device(self, tiny):
        self._run(tiny)

    @needs_mesh
    def test_host8(self, tiny, mesh):
        self._run(tiny, mesh=mesh)
