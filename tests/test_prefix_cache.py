"""Cross-request prefix caching over the paged/hierarchical KV cache.

The contract under test is **exactness**: admitting a request through the
prefix cache (aliased pool blocks + fp-seeded suffix prefill) must produce
greedy outputs token-identical to a cold prefill of the full prompt — in
both engines.  The static `Engine`'s dense path is the oracle; the
`ContinuousEngine` additionally aliases index-owned pool blocks into the
new request's page-table row and re-packs only the ragged tail group
(copy-on-write), which the pool-plane tests pin down directly.

The mesh classes need 8 forced host-platform devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_prefix_cache.py

In a single-device session they self-skip and the identity / COW /
scheduler units still run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import paged_kv_cache as PC
from repro.core.prefix_index import PrefixIndex
from repro.launch.mesh import make_host_mesh
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine, Engine
from repro.serving.scheduler import Scheduler

NDEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    NDEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def toks(seed: int, n: int, vocab: int) -> np.ndarray:
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, vocab), np.int32)


def shared_prompts(cfg, n_req: int = 3):
    """`n_req` prompts sharing a system prefix of 2G+8 tokens (two full
    quant groups plus a partial-block tail) with distinct ~G-token user
    suffixes."""
    G = cfg.group_size
    sys_p = toks(0, 2 * G + 8, cfg.vocab_size)
    return [np.concatenate([sys_p, toks(100 + i, G, cfg.vocab_size)])
            for i in range(n_req)]


def make_static(tiny, prefix: bool) -> Engine:
    _, model, params = tiny
    return Engine(model, params, policy="quantspec", gamma=2, greedy=True,
                  max_seq=512, rounds_per_step=2, prefix_cache=prefix)


def make_continuous(tiny, prefix: bool, **kw) -> ContinuousEngine:
    cfg, model, params = tiny
    kw.setdefault("prefill_chunk", cfg.group_size)
    return ContinuousEngine(model, params, gamma=2, greedy=True, max_slots=2,
                            max_seq=512, rounds_per_step=2,
                            prefix_cache=prefix, **kw)


# ---------------------------------------------------------------------------
# index unit behaviour (no model)
# ---------------------------------------------------------------------------

class TestPrefixIndexUnit:
    def _fp(self, g):
        return [(np.full((1, 4, 1, 2), g, np.float32),
                 np.full((1, 4, 1, 2), -g, np.float32))]

    def _insert(self, idx, tokens, ids):
        return idx.insert(tokens, ids, [self._fp(g) for g in ids])

    def test_match_whole_groups_only(self):
        idx = PrefixIndex(4)
        self._insert(idx, list(range(12)), [7, 8])
        chain = idx.match(list(range(12)))
        assert [nd.block_id for nd in chain] == [7, 8]
        # partial-block tail overlap: only whole matching groups count
        assert [nd.block_id for nd in idx.match(list(range(6)))] == [7]
        assert idx.match([99, 98, 97, 96]) == []
        assert idx.stats["hits"] == 2 and idx.stats["misses"] == 1
        assert idx.stats["hit_tokens"] == 12

    def test_insert_first_producer_wins(self):
        idx = PrefixIndex(4)
        self._insert(idx, list(range(8)), [3, 4])
        created = self._insert(idx, list(range(8)), [5, 6])
        assert created == []                       # duplicates not indexed
        assert [nd.block_id for nd in idx.match(list(range(8)))] == [3, 4]
        assert idx.blocks == 2

    def test_evict_lru_leaves_only_and_shield(self):
        idx = PrefixIndex(4)
        self._insert(idx, list(range(12)), [1, 2])     # chain 1 -> 2
        self._insert(idx, [9, 9, 9, 9], [5])
        idx.match(list(range(12)))                     # bump chain's clock
        # the LRU leaf is 5; 2 is a leaf; 1 is interior (never first out)
        assert idx.evict(1) == [5]
        assert idx.evict(2, shield=frozenset({2})) == []
        assert idx.evict(2) == [2, 1]                  # leaf-first order
        assert len(idx) == 0 and idx.blocks == 0


# ---------------------------------------------------------------------------
# static engine: the dense token-identity oracle
# ---------------------------------------------------------------------------

class TestStaticEnginePrefix:
    def test_shared_system_prompt_identity(self, tiny):
        cfg = tiny[0]
        cold = make_static(tiny, prefix=False)
        warm = make_static(tiny, prefix=True)
        for p in shared_prompts(cfg):
            rc = cold.generate(p[None, :], 10).tokens
            rw = warm.generate(p[None, :], 10).tokens
            np.testing.assert_array_equal(rc, rw)
        st = warm.prefix.stats
        assert st["hits"] >= 2 and st["hit_tokens"] > 0

    def test_partial_block_tail_overlap(self, tiny):
        """Prompts diverging mid-group: the cache may only reuse whole
        groups, and the divergent suffix must still be exact."""
        cfg = tiny[0]
        G = cfg.group_size
        base = toks(7, 3 * G + G // 2, cfg.vocab_size)
        p1 = np.concatenate([base, toks(8, G, cfg.vocab_size)])
        p2 = base.copy()
        p2[2 * G + G // 2] ^= 1          # diverge inside group 2
        p2 = np.concatenate([p2, toks(9, G, cfg.vocab_size)])
        cold = make_static(tiny, prefix=False)
        warm = make_static(tiny, prefix=True)
        np.testing.assert_array_equal(cold.generate(p1[None, :], 8).tokens,
                                      warm.generate(p1[None, :], 8).tokens)
        # p2 shares exactly groups 0..1 with p1's indexed prefix (the
        # divergence point lies inside group 2)
        assert len(warm.prefix.match(p2)) == 2
        np.testing.assert_array_equal(cold.generate(p2[None, :], 8).tokens,
                                      warm.generate(p2[None, :], 8).tokens)

    def test_multi_turn_resubmission(self, tiny):
        """Turn 2 resubmits turn 1's prompt + its own output + a new user
        turn; the whole turn-1 conversation comes out of the cache."""
        cfg = tiny[0]
        cold = make_static(tiny, prefix=False)
        warm = make_static(tiny, prefix=True)
        p1 = toks(11, 3 * cfg.group_size, cfg.vocab_size)
        out_c = cold.generate(p1[None, :], 10).tokens
        out_w = warm.generate(p1[None, :], 10).tokens
        np.testing.assert_array_equal(out_c, out_w)
        p2 = np.concatenate([p1, out_w[0].astype(np.int32),
                             toks(12, cfg.group_size, cfg.vocab_size)])
        hit0 = warm.prefix.stats["hit_tokens"]
        np.testing.assert_array_equal(cold.generate(p2[None, :], 10).tokens,
                                      warm.generate(p2[None, :], 10).tokens)
        assert warm.prefix.stats["hit_tokens"] > hit0


# ---------------------------------------------------------------------------
# continuous engine: aliased pool blocks + COW tail
# ---------------------------------------------------------------------------

def _plane_snapshot(engine: ContinuousEngine, ids) -> list:
    """Host copies of every layer's quantized planes at pool blocks
    ``ids`` (block axis is -4 on every plane)."""
    ids = jnp.asarray(ids, jnp.int32)
    snap = []

    def fn(mix, _stacked):
        for f in ("k_upper", "k_lower", "k_scale", "k_zero",
                  "v_upper", "v_lower", "v_scale", "v_zero"):
            snap.append(np.asarray(jnp.take(getattr(mix.primary, f), ids,
                                            axis=-4)))
        return mix

    ContinuousEngine._map_attn(engine.state, fn)
    return snap


class TestContinuousEnginePrefix:
    def test_shared_prompt_identity_and_fewer_chunks(self, tiny):
        cfg = tiny[0]
        prompts = shared_prompts(cfg)
        cold = make_continuous(tiny, prefix=False)
        warm = make_continuous(tiny, prefix=True)
        res_c = cold.generate(prompts, 10)
        reqs = [warm.submit(p, 10) for p in prompts]
        warm.run(jax.random.PRNGKey(0))
        for rc, rw in zip(res_c, reqs):
            np.testing.assert_array_equal(rc.tokens[0], rw.tokens)
        assert warm.prefix.stats["hits"] >= 2
        # cached admissions prefill only the uncached suffix: strictly
        # fewer chunks than the cold producer
        assert reqs[1].prefill_chunks < reqs[0].prefill_chunks
        assert reqs[2].prefill_chunks < reqs[0].prefill_chunks
        assert warm.cache_syncs == len(prompts)

    def test_multi_turn_resubmission(self, tiny):
        cfg = tiny[0]
        cold = make_continuous(tiny, prefix=False)
        warm = make_continuous(tiny, prefix=True)
        p1 = toks(21, 3 * cfg.group_size + 5, cfg.vocab_size)
        t1_c = cold.generate([p1], 10)[0].tokens[0]
        t1_w = warm.generate([p1], 10)[0].tokens[0]
        np.testing.assert_array_equal(t1_c, t1_w)
        p2 = np.concatenate([p1, np.asarray(t1_w, np.int32),
                             toks(22, cfg.group_size, cfg.vocab_size)])
        np.testing.assert_array_equal(cold.generate([p2], 10)[0].tokens[0],
                                      warm.generate([p2], 10)[0].tokens[0])
        assert warm.prefix.stats["hits"] >= 1

    def test_cow_isolation_pool_planes(self, tiny):
        """A request aliasing shared blocks must never write them: its
        ragged-tail re-pack and decode flushes go to privately popped
        blocks, so the indexed planes are bit-identical before/after."""
        cfg = tiny[0]
        warm = make_continuous(tiny, prefix=True)
        p1 = toks(31, 3 * cfg.group_size + 8, cfg.vocab_size)
        warm.generate([p1], 8)
        shared = sorted(nd.block_id for nd in warm.prefix._iter_nodes())
        assert len(shared) == 2
        before = _plane_snapshot(warm, shared)
        # aliases both indexed groups, then decodes well past a flush
        p2 = np.concatenate([p1, toks(32, cfg.group_size, cfg.vocab_size)])
        warm.generate([p2], 2 * cfg.group_size + 8)
        after = _plane_snapshot(warm, shared)
        for b, a in zip(before, after):
            np.testing.assert_array_equal(b, a)

    def test_block_accounting_and_drain(self, tiny):
        """Retired requests return everything except the index's blocks;
        evicting the whole index restores the full free stack."""
        cfg = tiny[0]
        warm = make_continuous(tiny, prefix=True)
        warm.generate(shared_prompts(cfg), 8)
        held = warm.prefix.blocks
        assert held > 0
        assert warm.scheduler.extra_reserved == held
        assert int(warm.table.free_top) == warm.pool_blocks - held
        evicted = warm.prefix.evict(held)
        warm.table = PC.evict_blocks(warm.table, evicted)
        assert int(warm.table.free_top) == warm.pool_blocks
        ref = np.asarray(warm.table.refcount)
        assert (ref == 0).all()


# ---------------------------------------------------------------------------
# scheduler capacity with shared blocks (regression)
# ---------------------------------------------------------------------------

class TestSchedulerPrefixCapacity:
    def test_full_pool_admits_fully_cached_request(self):
        """With the pool nearly full of index-held blocks, a request whose
        prefix is cached must still admit: aliased blocks never pop the
        free stack, so the hint discounts them from the reservation.
        (Regression — the unhinted bound used to livelock the queue.)"""
        sch = Scheduler(num_slots=1, pool_blocks=5, group=4)
        sch.extra_reserved = 3                      # index holds 3 blocks
        req = sch.submit(np.zeros(14, np.int32), max_new_tokens=2)
        assert sch.block_bound(req) == 4            # ceil(16/4), no hint
        assert sch.next_admission() is None         # 0 + 4 + 3 > 5
        sch.set_shared_hint(req, 2)                 # 2 of them aliased
        assert sch.block_bound(req) == 2
        admitted = sch.next_admission()             # 0 + 2 + 3 <= 5
        assert admitted is req and req.reserved == 2
        assert sch.reserved_blocks == 2

    def test_retire_releases_frozen_reservation(self):
        """The admission-time reservation is released verbatim even if the
        hint is mutated afterwards — accounting can never drift."""
        sch = Scheduler(num_slots=1, pool_blocks=8, group=4)
        req = sch.submit(np.zeros(8, np.int32), max_new_tokens=4)
        sch.set_shared_hint(req, 1)
        sch.next_admission()
        assert sch.reserved_blocks == req.reserved == 2
        req.shared_hint = 0                         # stale hint mutation
        sch.retire(req.slot)
        assert sch.reserved_blocks == 0

    def test_hint_never_negative_bound(self):
        sch = Scheduler(num_slots=1, pool_blocks=8, group=4)
        req = sch.submit(np.zeros(4, np.int32), max_new_tokens=1)
        sch.set_shared_hint(req, 99)
        assert sch.block_bound(req) == 0


# ---------------------------------------------------------------------------
# sharded serving (host8 mesh)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh():
    if NDEV < 8:
        pytest.skip("needs 8 host devices")
    return make_host_mesh(4, 2)


class TestShardedPrefix:
    @needs_mesh
    def test_host8_identity_with_prefix_cache(self, tiny, mesh):
        """Prefix caching composes with tensor-parallel serving: aliasing
        and eviction act on the replicated page table, scratch seeding
        happens before placement — outputs stay token-identical to the
        single-device cold engine."""
        cfg = tiny[0]
        prompts = shared_prompts(cfg)
        cold = make_continuous(tiny, prefix=False)
        warm = make_continuous(tiny, prefix=True, mesh=mesh)
        res_c = cold.generate(prompts, 10)
        res_w = warm.generate(prompts, 10)
        for rc, rw in zip(res_c, res_w):
            np.testing.assert_array_equal(rc.tokens, rw.tokens)
        assert warm.prefix.stats["hits"] >= 2
