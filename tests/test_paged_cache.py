"""Paged hierarchical KV cache: pool/block-table lifecycle + paged kernels.

The dense `HierKVCache` is the oracle throughout: a slot that went through
alloc → adopt → (plan/apply/commit)* → rollback must materialize to the
same logical K/V stream a dense batch-1 cache produces under the same
token schedule.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hier_kv_cache as HC
from repro.core import paged_kv_cache as PC
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.quant_attention import paged_quant_region_attention
from repro.models import common as L

R, P, NBmax, G, H, D = 3, 10, 5, 8, 2, 16


def rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def fresh():
    return PC.init_table(R, NBmax, P), PC.init_pool(R, P, G, H, D)


def admit(table, pool, slot, s, seed):
    """Prefill a length-s request through the dense path into `slot`."""
    k = rand(seed, (1, s, H, D))
    v = rand(seed + 500, (1, s, H, D))
    hier = HC.prefill(HC.init_cache(1, NBmax, G, H, D), k, v)
    table, ids = PC.alloc_blocks(table, slot, int(hier.blocks))
    pool = PC.adopt_hier(pool, slot, ids, hier)
    table = PC.admit_slot(table, slot, s, int(hier.buf_len))
    return table, pool, hier, (k, v)


def slot_kv(pool, table, slot, mode="target"):
    """Dense logical [S, H, D] view of one slot."""
    k, v, valid, _ = PC.materialize_slots(pool, table, mode)
    idx = np.where(np.asarray(valid[slot]))[0]
    return np.asarray(k)[slot, idx], np.asarray(v)[slot, idx]


class TestAdoption:
    def test_adopt_matches_dense(self):
        table, pool = fresh()
        table, pool, hier, _ = admit(table, pool, 1, 2 * G + 5, seed=0)
        pk, pv = slot_kv(pool, table, 1)
        dk, dv, dvalid, _ = HC.materialize(hier, "target")
        idx = np.where(np.asarray(dvalid))[0]
        np.testing.assert_allclose(pk, np.asarray(dk)[0, idx], atol=1e-6)
        np.testing.assert_allclose(pv, np.asarray(dv)[0, idx], atol=1e-6)

    def test_alloc_respects_capacity(self):
        table, _ = fresh()
        with pytest.raises(RuntimeError):
            PC.alloc_blocks(table, 0, P + 1)

    def test_nbmax_bound(self):
        table, _ = fresh()
        with pytest.raises(RuntimeError):
            PC.alloc_blocks(table, 0, NBmax + 1)


class TestRaggedLifecycle:
    def _dual(self, slots_lens, seeds):
        """A paged table/pool + per-slot dense caches under one schedule."""
        table, pool = fresh()
        dense = {}
        for (slot, s), seed in zip(slots_lens, seeds):
            table, pool, hier, _ = admit(table, pool, slot, s, seed)
            dense[slot] = hier
        return table, pool, dense

    def test_ragged_append_rollback_roundtrip(self):
        slots = [(0, 2 * G + 2), (2, G + 5)]
        table, pool, dense = self._dual(slots, seeds=[1, 2])
        # append 3 tokens to every active slot, roll 2 back on slot 0 only
        k = rand(10, (R, 3, H, D))
        v = rand(11, (R, 3, H, D))
        table, step = PC.plan_step(table, 3, G)
        pool = PC.apply_step(pool, step, k, v)
        table = PC.rollback(table, jnp.array([2, 0, 0]))
        table = PC.commit(table, jnp.array([1, 3, 3]))  # net committed
        for slot in (0, 2):
            d = HC.maybe_flush(dense[slot], headroom=3)  # same flush rule
            d = HC.append(d, k[slot:slot + 1], v[slot:slot + 1])
            if slot == 0:
                d = HC.rollback(d, 2)
            pk, pv = slot_kv(pool, table, slot)
            dk, dv, dvalid, _ = HC.materialize(d, "target")
            idx = np.where(np.asarray(dvalid))[0]
            np.testing.assert_allclose(pk, np.asarray(dk)[0, idx], atol=1e-6,
                                       err_msg=f"slot {slot}")
            np.testing.assert_allclose(pv, np.asarray(dv)[0, idx], atol=1e-6)

    def test_ragged_flush_matches_dense(self):
        """Slots flush on different steps; each must match its own dense
        cache driven by the same appends."""
        slots = [(0, 2 * G - 2), (1, G + 1)]
        table, pool, dense = self._dual(slots, seeds=[3, 4])
        for t in range(G + 3):
            k = rand(100 + t, (R, 1, H, D))
            v = rand(200 + t, (R, 1, H, D))
            table, step = PC.plan_step(table, 1, G)
            pool = PC.apply_step(pool, step, k, v)
            table = PC.commit(table, jnp.ones((R,), jnp.int32))
            for slot in (0, 1):
                d = HC.maybe_flush(dense[slot], headroom=1)
                dense[slot] = HC.append(d, k[slot:slot + 1], v[slot:slot + 1])
        for slot in (0, 1):
            assert int(table.blocks[slot]) == int(dense[slot].blocks)
            pk, _ = slot_kv(pool, table, slot)
            dk, _, dvalid, _ = HC.materialize(dense[slot], "target")
            idx = np.where(np.asarray(dvalid))[0]
            np.testing.assert_allclose(pk, np.asarray(dk)[0, idx], atol=1e-6,
                                       err_msg=f"slot {slot}")

    def test_free_returns_blocks_and_slot_reusable(self):
        table, pool = fresh()
        table, pool, _, _ = admit(table, pool, 0, 3 * G + 1, seed=5)
        used = P - int(table.free_top)
        assert used == int(table.blocks[0]) == 2
        table = PC.free_slot(table, 0)
        assert int(table.free_top) == P
        assert not bool(table.active[0])
        # re-admit a different request into the same slot
        table, pool, hier, _ = admit(table, pool, 0, G + 3, seed=6)
        pk, _ = slot_kv(pool, table, 0)
        dk, _, dvalid, _ = HC.materialize(hier, "target")
        idx = np.where(np.asarray(dvalid))[0]
        np.testing.assert_allclose(pk, np.asarray(dk)[0, idx], atol=1e-6)

    def test_inactive_slots_untouched(self):
        table, pool = fresh()
        table, pool, _, _ = admit(table, pool, 1, G + 2, seed=7)
        before = (int(table.blocks[0]), int(table.buf_len[0]))
        for t in range(2 * G):
            k = rand(300 + t, (R, 1, H, D))
            table, step = PC.plan_step(table, 1, G)
            pool = PC.apply_step(pool, step, k, k)
            table = PC.commit(table, jnp.ones((R,), jnp.int32))
        assert (int(table.blocks[0]), int(table.buf_len[0])) == before
        assert int(table.pos[0]) == 0

    def test_plan_step_jits(self):
        table, pool = fresh()
        table, pool, _, _ = admit(table, pool, 0, 2 * G, seed=8)
        f = jax.jit(lambda t: PC.plan_step(t, 1, G))
        t2, step = f(table)
        assert int(t2.buf_len[0]) == int(table.buf_len[0]) + 1


class TestPagedKernel:
    def _pool_setup(self, lens, seeds):
        table, pool = fresh()
        for slot, (s, seed) in enumerate(zip(lens, seeds)):
            table, pool, _, _ = admit(table, pool, slot, s, seed)
        return table, pool

    @pytest.mark.parametrize("mode", ["draft", "target"])
    def test_kernel_vs_ref(self, mode):
        table, pool = self._pool_setup(
            [3 * G + 2, 5, 2 * G + 1], seeds=[20, 21, 22])
        planes = tuple(kops._pool_bh(x) for x in
                       (pool.k_upper, pool.k_lower, pool.k_scale, pool.k_zero,
                        pool.v_upper, pool.v_lower, pool.v_scale, pool.v_zero))
        q = rand(30, (R * H, 4, D))
        ok, ol = paged_quant_region_attention(
            q, *planes, table.block_table, table.blocks, H, mode)
        rk, rl = kref.paged_quant_region_attention_ref(
            q, *planes, table.block_table, table.blocks, H, mode)
        np.testing.assert_allclose(np.asarray(ok), np.asarray(rk),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(ol), np.asarray(rl),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("Hq,T", [(H, 1), (2 * H, 4)])
    def test_paged_attention_matches_flat(self, Hq, T):
        """pallas paged path == flat jnp paged path on a real pool."""
        table, pool = self._pool_setup([3 * G + 2, G + 4], seeds=[23, 24])
        k = rand(31, (R, T, H, D))
        v = rand(32, (R, T, H, D))
        table, step = PC.plan_step(table, T, G)
        pool = PC.apply_step(pool, step, k, v)
        q = rand(33, (R, T, Hq, D))
        for mode in ("draft", "target"):
            flat = L.attend_hier_paged(q, pool, table, table.pos, mode,
                                       impl="flat")
            pallas = L.attend_hier_paged(q, pool, table, table.pos, mode,
                                         impl="pallas")
            # inactive slots (slot 2 here) are garbage by contract
            np.testing.assert_allclose(np.asarray(pallas)[:2],
                                       np.asarray(flat)[:2],
                                       atol=3e-5, rtol=3e-5,
                                       err_msg=f"mode={mode}")

    def test_paged_flat_matches_dense_flat(self):
        """One slot's paged attention == dense attention on the same data."""
        table, pool = fresh()
        s = 2 * G + 6
        table, pool, hier, _ = admit(table, pool, 2, s, seed=25)
        T = 2
        k = rand(34, (1, T, H, D))
        v = rand(35, (1, T, H, D))
        kR = jnp.zeros((R, T, H, D)).at[2].set(k[0])
        table, step = PC.plan_step(table, T, G)
        pool = PC.apply_step(pool, step, kR,
                             jnp.zeros((R, T, H, D)).at[2].set(v[0]))
        dense = HC.append(HC.maybe_flush(hier, headroom=T), k, v)
        q = rand(36, (1, T, H, D))
        qR = jnp.zeros((R, T, H, D)).at[2].set(q[0])
        for mode in ("draft", "target"):
            got = L.attend_hier_paged(qR, pool, table, table.pos, mode)[2]
            want = L.attend_hier(q, dense, s, mode)[0]
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5, rtol=1e-5,
                                       err_msg=f"mode={mode}")
