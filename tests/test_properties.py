"""Property-based tests (hypothesis) for system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis is an optional test dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import acceptance
from repro.core import hier_kv_cache as HC
from repro.core.quantization import simulate_cache_quant


def _rand_probs(key, shape):
    return jax.nn.softmax(jax.random.normal(key, shape) * 2.0, axis=-1)


class TestVerifyInvariants:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), gamma=st.integers(1, 6),
           vocab=st.integers(2, 32), greedy=st.booleans())
    def test_bounds_and_prefix(self, seed, gamma, vocab, greedy):
        """0 <= n_accepted <= γ; emitted tokens are a prefix of the draft up
        to the acceptance point; all emitted ids are valid."""
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
        B = 2
        q = _rand_probs(k1, (B, gamma, vocab))
        p = _rand_probs(k2, (B, gamma + 1, vocab))
        g = jax.random.categorical(k3, jnp.log(q), axis=-1)
        res = acceptance.verify(g, q, p, k4, greedy=greedy)
        n = int(res.n_accepted)
        assert 0 <= n <= gamma
        assert int(res.n_new) == n + 1
        toks = np.asarray(res.tokens)
        assert ((0 <= toks) & (toks < vocab)).all()
        np.testing.assert_array_equal(toks[:, :n], np.asarray(g)[:, :n])

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), gamma=st.integers(1, 4))
    def test_identical_dists_accept_all(self, seed, gamma):
        k1, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 3)
        p = _rand_probs(k1, (1, gamma + 1, 16))
        q = p[:, :gamma]
        g = jax.random.categorical(k3, jnp.log(q), axis=-1)
        res = acceptance.verify(g, q, p, k4, greedy=False)
        assert int(res.n_accepted) == gamma

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_disjoint_dists_reject_all(self, seed):
        """Draft samples from mass the target assigns ~0 → rejection at 0,
        and the correction token comes from the target's support."""
        V = 8
        q = jnp.zeros((1, 2, V)).at[:, :, 0].set(1.0)
        p = jnp.zeros((1, 3, V)).at[:, :, 1].set(1.0)
        g = jnp.zeros((1, 2), jnp.int32)  # always token 0
        res = acceptance.verify(g, q, p, jax.random.PRNGKey(seed))
        assert int(res.n_accepted) == 0
        assert int(res.tokens[0, 0]) == 1


class TestCacheInvariants:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           s=st.integers(1, 40), n_new=st.integers(0, 6))
    def test_seq_len_accounting(self, seed, s, n_new):
        G, H, D = 8, 2, 16
        cache = HC.init_cache(1, 8, G, H, D)
        key = jax.random.PRNGKey(seed)
        k = jax.random.normal(key, (1, s, H, D))
        cache = HC.prefill(cache, k, k)
        assert int(cache.seq_len) == s
        if n_new:
            cache = HC.maybe_flush(cache, headroom=n_new)
            nk = jax.random.normal(jax.random.fold_in(key, 1), (1, n_new, H, D))
            cache = HC.append(cache, nk, nk)
            assert int(cache.seq_len) == s + n_new
            cache = HC.rollback(cache, min(n_new, 3))
            assert int(cache.seq_len) == s + n_new - min(n_new, 3)
        # invariant: buffer never overflows and C_F1 stays populated
        assert 0 <= int(cache.buf_len) <= 2 * G

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8, 16]))
    def test_sim_quant_preserves_residual(self, seed, bits):
        """The FP-buffer residual must be bit-exact for any precision."""
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (2, 64, 2, 8))
        out = simulate_cache_quant(x, group=16, residual=16,
                                   axis="channel", bits=bits)
        np.testing.assert_array_equal(np.asarray(out[:, -16:]),
                                      np.asarray(x[:, -16:]))
        if bits >= 16:
            np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
        else:
            err = float(jnp.abs(out - x).max())
            assert err < (0.6 if bits == 4 else 0.05)


class TestPrefixShareLifecycle:
    """Refcounted block lifecycle under random interleavings of the engine's
    primitives: admission pops (`alloc_blocks` / `plan_prefill_chunk`),
    prefix aliasing (`share_blocks`), index retention (`retain_blocks`),
    retirement (`free_slot` and the jitted `release_slot`), index
    eviction (`evict_blocks`), and preempt-to-host-tier offload/resume
    (the refcount-aware release at preemption followed by
    `adopt_blocks` popping fresh private blocks at swap-in).

    Invariants checked after every op against a pure-python ownership model:

    * ``refcount[b]`` equals the number of live references (owning/aliasing
      slots + the index) for every block;
    * no block is simultaneously on the free stack and referenced, and the
      stack never holds duplicates;
    * conservation: every block is exactly-one-of free or referenced;
    * eviction never frees a block a slot still references (it only drops
      the index's count — the push is masked to blocks reaching zero).
    """

    R, NBmax, P, G, C = 3, 5, 12, 4, 8

    def _check(self, table, owners):
        from repro.core import paged_kv_cache as PC  # noqa: F401
        ref = np.asarray(table.refcount)
        top = int(table.free_top)
        stack = [int(b) for b in np.asarray(table.free_stack)[:top]]
        live = {b for b, o in owners.items() if o}
        for b in range(self.P):
            assert ref[b] == len(owners[b]), (b, ref[b], owners[b])
        assert len(set(stack)) == len(stack)
        assert live.isdisjoint(stack)
        assert len(stack) + len(live) == self.P

    @settings(max_examples=30, deadline=None)
    @given(data=st.data())
    def test_random_interleavings(self, data):
        from repro.core import paged_kv_cache as PC
        R, NBmax, P, G, C = self.R, self.NBmax, self.P, self.G, self.C
        release = jax.jit(PC.release_slot)
        table = PC.init_table(R, NBmax, P)
        owners = {b: set() for b in range(P)}
        slots = {}            # slot -> dict(pos=<host tokens>, chunked=bool)
        indexed = []          # block ids the index references (insert order)
        suspended = []        # host-tier snapshots: block counts to re-adopt

        for _ in range(data.draw(st.integers(1, 25), label="n_ops")):
            op = data.draw(st.sampled_from(
                ["alloc", "share", "chunk", "index", "retire", "evict",
                 "preempt", "resume"]),
                label="op")
            idle = [s for s in range(R) if s not in slots]
            free = int(table.free_top)

            if op == "alloc" and idle:
                n = data.draw(st.integers(0, min(NBmax, free)), label="n")
                slot = idle[0]
                table, ids = PC.alloc_blocks(table, slot, n)
                for b in np.asarray(ids):
                    owners[int(b)].add(("slot", slot))
                slots[slot] = dict(pos=None, chunked=False)
            elif op == "share" and idle:
                k = data.draw(st.integers(0, min(len(indexed), NBmax - 1)),
                              label="k")
                slot, ids = idle[0], indexed[:k]
                table = PC.share_blocks(table, slot, ids, (k + 1) * G, G)
                for b in ids:
                    owners[b].add(("slot", slot))
                slots[slot] = dict(pos=(k + 1) * G, chunked=True)
            elif op == "chunk":
                grow = [s for s, st_ in slots.items() if st_["chunked"]
                        and st_["pos"] + C <= NBmax * G]
                if not grow:
                    continue
                slot = grow[0]
                pos, new_pos = slots[slot]["pos"], slots[slot]["pos"] + C
                n_flush = max(0, (new_pos - G) // G) - max(0, (pos - G) // G)
                if n_flush > free:
                    continue
                prev = int(table.blocks[slot])
                table, _step = PC.plan_prefill_chunk(table, slot, C, C, G)
                row = np.asarray(table.block_table[slot])
                for b in row[prev:prev + n_flush]:
                    owners[int(b)].add(("slot", slot))
                slots[slot]["pos"] = new_pos
            elif op == "index":
                cands = [b for b, o in owners.items()
                         if o and "index" not in o]
                if not cands:
                    continue
                k = data.draw(st.integers(1, len(cands)), label="k_idx")
                table = PC.retain_blocks(table, cands[:k])
                for b in cands[:k]:
                    owners[b].add("index")
                    indexed.append(b)
            elif op == "retire" and slots:
                slot = sorted(slots)[0]
                if data.draw(st.booleans(), label="jitted"):
                    table = release(table, jnp.asarray(slot, jnp.int32))
                else:
                    table = PC.free_slot(table, slot)
                for o in owners.values():
                    o.discard(("slot", slot))
                del slots[slot]
            elif op == "evict" and indexed:
                k = data.draw(st.integers(1, len(indexed)), label="k_ev")
                victims = indexed[-k:]
                table = PC.evict_blocks(table, victims)
                for b in victims:
                    owners[b].discard("index")
                indexed = indexed[:-k]
            elif op == "preempt" and slots:
                # engine preemption: snapshot the byte planes (no table
                # effect), then the refcount-aware release — blocks the
                # index retains survive, the rest return to the stack
                slot = sorted(slots)[0]
                suspended.append(int(table.blocks[slot]))
                table = release(table, jnp.asarray(slot, jnp.int32))
                for o in owners.values():
                    o.discard(("slot", slot))
                del slots[slot]
            elif op == "resume" and suspended and idle:
                n = suspended[0]
                if n > free:
                    continue        # head stays blocked; snapshot kept
                suspended.pop(0)
                slot = idle[0]
                table, ids = PC.adopt_blocks(table, slot, n, n * G, n * G)
                for b in np.asarray(ids)[:n]:
                    owners[int(b)].add(("slot", slot))
                slots[slot] = dict(pos=None, chunked=False)
            self._check(table, owners)

        # full drain: retire every slot, evict the whole index
        for slot in sorted(slots):
            table = PC.free_slot(table, slot)
            for o in owners.values():
                o.discard(("slot", slot))
            self._check(table, owners)
        if indexed:
            table = PC.evict_blocks(table, indexed)
            for b in indexed:
                owners[b].discard("index")
        self._check(table, owners)
        assert int(table.free_top) == P
