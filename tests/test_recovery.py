"""Crash-safe serving: write-ahead journal, checkpoints, and
kill-and-recover.

The journal/replay fold is unit-tested without JAX; the engine-level
suite simulates a SIGKILL *in process* by raising a sentinel out of the
fault injector's ``tick`` — the crashed engine object is abandoned with
only ``journal_dir`` surviving, exactly the state a dead process leaves —
and asserts that a fresh engine's ``recover()`` + ``run()`` produces
token-identical greedy streams (bit-exact resume when a checkpoint
persisted the preempted snapshot, replay-from-prompt otherwise).  A real
``SIGKILL`` against a subprocess rides in the ``slow``-marked smoke test.
"""

from __future__ import annotations

import os
import signal
import subprocess
import sys
import time

import jax
import pytest

from fault_injection import FaultInjector
from repro.configs import get_config
from repro.models.stack import StackModel
from repro.serving import journal as J
from test_fault_injection import check_drained, make_prompts, setup

MAX_NEW = 8


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def reference(tiny):
    eng, prompts = setup(tiny, oversub=False)
    reqs = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.run(jax.random.PRNGKey(7))
    assert all(r.status == "ok" for r in reqs)
    return [list(r.tokens) for r in reqs]


# ---------------------------------------------------------------------------
class TestJournalUnit:
    def events(self, root):
        return J.read_events(str(root))

    def test_append_read_roundtrip(self, tmp_path):
        with J.Journal(str(tmp_path)) as j:
            assert j.append("submit", req=0, prompt=[1, 2], max_new=4) == 0
            assert j.append("admit", req=0) == 1
            assert j.append("tokens", req=0, toks=[7, 8]) == 2
        events, truncated = self.events(tmp_path)
        assert truncated == 0
        assert [e["ev"] for e in events] == ["submit", "admit", "tokens"]
        assert [e["seq"] for e in events] == [0, 1, 2]
        assert events[0]["prompt"] == [1, 2]

    def test_torn_tail_dropped(self, tmp_path):
        with J.Journal(str(tmp_path)) as j:
            for i in range(3):
                j.append("tokens", req=0, toks=[i])
        with open(os.path.join(str(tmp_path), "journal.jsonl"), "ab") as f:
            f.write(b'00000000 {"seq": 3, "ev": "tokens"')   # torn mid-write
        events, truncated = self.events(tmp_path)
        assert len(events) == 3 and truncated == 1

    def test_bad_line_truncates_everything_after(self, tmp_path):
        """Replay stops at the first corrupt line even when later lines
        verify — they may depend on the lost event."""
        with J.Journal(str(tmp_path)) as j:
            j.append("submit", req=0, prompt=[1])
        path = os.path.join(str(tmp_path), "journal.jsonl")
        with open(path, "ab") as f:
            f.write(b"garbage line\n")
            f.write(J._enc({"seq": 2, "ev": "admit", "req": 0}))
        events, truncated = self.events(tmp_path)
        assert len(events) == 1 and truncated == 2

    def test_reopen_continues_seq_and_excises_torn_tail(self, tmp_path):
        with J.Journal(str(tmp_path)) as j:
            j.append("submit", req=0, prompt=[1])
            j.append("admit", req=0)
        path = os.path.join(str(tmp_path), "journal.jsonl")
        with open(path, "ab") as f:
            f.write(b'deadbeef {"torn":')
        # reopening must (a) continue the sequence from the valid prefix
        # and (b) excise the torn tail — otherwise every event appended
        # below would sit behind a bad line and be invisible to replay
        with J.Journal(str(tmp_path)) as j2:
            assert j2.dropped_tail == 1
            assert j2.seq == 2
            j2.append("finish", req=0, status="ok")
        events, truncated = self.events(tmp_path)
        assert truncated == 0
        assert [e["ev"] for e in events] == ["submit", "admit", "finish"]
        assert events[-1]["seq"] == 2

    def test_checkpoint_roundtrip(self, tmp_path):
        with J.Journal(str(tmp_path)) as j:
            j.append("submit", req=0, prompt=[1])
            j.checkpoint({"persisted": [0]})
        ck = J.read_checkpoint(str(tmp_path))
        assert ck == {"persisted": [0], "seq": 1}
        assert not [n for n in os.listdir(str(tmp_path))
                    if n.endswith(".tmp")]

    def test_read_checkpoint_tolerates_missing_or_corrupt(self, tmp_path):
        assert J.read_checkpoint(str(tmp_path)) is None
        (tmp_path / "checkpoint.json").write_text("{not json")
        assert J.read_checkpoint(str(tmp_path)) is None

    def test_replay_fold(self):
        events = [
            {"ev": "submit", "req": 0, "prompt": [1, 2], "max_new": 4},
            {"ev": "submit", "req": 1, "prompt": [3], "max_new": 4},
            {"ev": "submit", "req": 2, "prompt": [5], "max_new": 4},
            {"ev": "admit", "req": 0},
            {"ev": "tokens", "req": 0, "toks": [10]},
            {"ev": "tokens", "req": 0, "toks": [11, 12]},
            # preempt carries the authoritative stream (overwrites deltas)
            {"ev": "preempt", "req": 0, "tokens": [10, 11, 12, 13]},
            {"ev": "admit", "req": 1},
            {"ev": "tokens", "req": 1, "toks": [20]},
            {"ev": "finish", "req": 1, "status": "ok"},
            {"ev": "admit", "req": 2},
            {"ev": "tokens", "req": 2, "toks": [30]},
            {"ev": "restart", "req": 2},      # snapshot lost → from prompt
            {"ev": "tokens", "req": 9, "toks": [1]},   # torn submit: ignored
        ]
        recs = J.replay(events)
        assert sorted(recs) == [0, 1, 2]
        assert recs[0].tokens == [10, 11, 12, 13]
        assert recs[0].swapped_out and not recs[0].done
        assert recs[1].done and recs[1].tokens == [20]
        assert recs[2].tokens == [] and not recs[2].swapped_out
        # resume clears swapped_out; a replay-mode recover clears tokens
        recs2 = J.replay(events + [{"ev": "resume", "req": 0},
                                   {"ev": "recover", "req": 2,
                                    "mode": "replay"}])
        assert not recs2[0].swapped_out and recs2[0].tokens == recs[0].tokens
        assert recs2[2].status == "queued"


# ---------------------------------------------------------------------------
class _Crash(RuntimeError):
    """Sentinel standing in for SIGKILL in in-process crash tests."""


class CrashInjector(FaultInjector):
    """Abandon the engine mid-run: after ``after`` lifecycle sweeps,
    optionally preempt one victim (and optionally checkpoint so its
    snapshot reaches the disk tier), then raise :class:`_Crash` out of
    ``run()``.  Only ``journal_dir`` survives — like a dead process."""

    def __init__(self, *, after: int = 3, preempt: bool = False,
                 checkpoint: bool = False):
        super().__init__()
        self._after = after
        self._preempt = preempt
        self._ckpt = checkpoint
        self.fired = False

    def tick(self, engine):
        super().tick(engine)
        if self.fired or self.ticks < self._after:
            return
        if self._preempt:
            busy = engine._prefilling.slot if engine._prefilling else None
            victim = engine.scheduler.preemption_victim(
                exclude=() if busy is None else (busy,))
            if victim is None:
                return              # wait for an eligible victim
            engine._do_preempt(victim)
            if self._ckpt:
                engine._checkpoint()
        self.fired = True
        raise _Crash("injected crash")


class TestCrashRecovery:
    def crash_then_recover(self, tiny, jdir, fault, **kw):
        """Run to the injected crash, then recover on a fresh engine."""
        eng, prompts = setup(tiny, fault=fault, journal_dir=jdir, **kw)
        for p in prompts:
            eng.submit(p, MAX_NEW)
        with pytest.raises(_Crash):
            eng.run(jax.random.PRNGKey(7))
        del eng                     # the crashed process is gone
        fresh, _ = setup(tiny, journal_dir=jdir, **kw)
        recovered = fresh.recover()
        return fresh, recovered, prompts

    def finish_and_check(self, eng, recovered, reference, jdir):
        eng.run(jax.random.PRNGKey(7))
        assert all(r.status == "ok" for r in recovered), \
            [(r.req_id, r.status, r.reason) for r in recovered]
        events, _ = J.read_events(jdir)
        recs = J.replay(events)
        # journal ⊕ recovery covers every request: finished-before-crash
        # streams come from the folded WAL, recovered ones from the run
        assert sorted(recs) == [0, 1, 2, 3]
        for rid, rec in recs.items():
            assert rec.status == "ok"
            assert rec.tokens == reference[rid], f"req {rid} diverged"
        check_drained(eng)

    def test_replay_recovery_token_identity(self, tiny, tmp_path, reference):
        """Kill with no checkpointed snapshots: every in-flight request
        replays from its prompt and regenerates identical greedy tokens."""
        jdir = str(tmp_path / "j")
        eng, recovered, _ = self.crash_then_recover(
            tiny, jdir, CrashInjector(after=4))
        assert recovered, "crash after 4 sweeps left nothing in flight"
        assert all(not r.resume for r in recovered)
        events, _ = J.read_events(jdir)
        assert [e for e in events if e["ev"] == "recover"
                and e["mode"] == "replay"]
        self.finish_and_check(eng, recovered, reference, jdir)

    def test_resume_from_checkpoint_bit_exact(self, tiny, tmp_path,
                                              reference):
        """A checkpoint persisted the preempted snapshot before the kill:
        recovery swaps it back in bit-exact (mode ``resume``) instead of
        recomputing, and the stream continues token-identical."""
        jdir = str(tmp_path / "j")
        eng, recovered, _ = self.crash_then_recover(
            tiny, jdir, CrashInjector(after=2, preempt=True, checkpoint=True),
            oversub=False, prefetch=False)
        resumed = [r for r in recovered if r.resume]
        assert len(resumed) == 1, "checkpointed victim must resume"
        assert resumed[0].tokens, "resume carries the journaled stream"
        events, _ = J.read_events(jdir)
        assert [e for e in events if e["ev"] == "recover"
                and e["mode"] == "resume"]
        self.finish_and_check(eng, recovered, reference, jdir)
        assert resumed[0].restarts == 0, "resume must not replay"

    def test_kill_between_preempt_and_checkpoint_replays(self, tiny,
                                                         tmp_path,
                                                         reference):
        """The WAL recorded the preemption but the snapshot never reached
        disk (killed before the checkpoint): recovery degrades that
        request to replay-from-prompt — correctness never depends on the
        checkpoint having run."""
        jdir = str(tmp_path / "j")
        eng, recovered, _ = self.crash_then_recover(
            tiny, jdir, CrashInjector(after=2, preempt=True,
                                      checkpoint=False),
            oversub=False)
        assert recovered and all(not r.resume for r in recovered)
        events, _ = J.read_events(jdir)
        assert [e for e in events if e["ev"] == "preempt"]
        assert [e for e in events if e["ev"] == "recover"
                and e["mode"] == "replay"]
        self.finish_and_check(eng, recovered, reference, jdir)


# ---------------------------------------------------------------------------
#: the subprocess workload (mirrors tests/test_fault_injection.setup with
#: a longer stream so the SIGKILL lands mid-decode)
CHILD_MAX_NEW = 64


def child_engine(tiny, journal_dir):
    eng, _ = setup(tiny, oversub=False, journal_dir=journal_dir,
                   max_new=CHILD_MAX_NEW, checkpoint_every=2)
    cfg = tiny[0]
    G = cfg.group_size
    return eng, make_prompts(cfg, [2 * G + 5, G + 3, 17, 9])


def child_main(journal_dir: str) -> None:
    """Entry point exec'd by the SIGKILL smoke test's subprocess."""
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng, prompts = child_engine((cfg, model, params), journal_dir)
    for p in prompts:
        eng.submit(p, CHILD_MAX_NEW)
    eng.run(jax.random.PRNGKey(7))


@pytest.mark.slow
class TestSigkillSmoke:
    def test_sigkill_and_recover(self, tiny, tmp_path):
        jdir = str(tmp_path / "j")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.abspath("src"), os.path.abspath("tests"),
             env.get("PYTHONPATH", "")])
        child = subprocess.Popen(
            [sys.executable, "-c",
             "import sys; from test_recovery import child_main; "
             "child_main(sys.argv[1])", jdir],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
        try:
            # wait for real decode progress, then pull the plug
            deadline = time.time() + 300
            while time.time() < deadline:
                if child.poll() is not None:
                    break
                events, _ = J.read_events(jdir)
                if sum(1 for e in events if e["ev"] == "tokens") >= 2:
                    break
                time.sleep(0.25)
            alive = child.poll() is None
            if alive:
                os.kill(child.pid, signal.SIGKILL)
            child.wait(timeout=60)
        finally:
            if child.poll() is None:
                child.kill()
        events, _ = J.read_events(jdir)
        assert any(e["ev"] == "tokens" for e in events), \
            "child made no journaled progress before the kill"

        # reference streams, computed in-process with the same workload
        ref_eng, prompts = child_engine(tiny, None)
        refs = [ref_eng.submit(p, CHILD_MAX_NEW) for p in prompts]
        ref_eng.run(jax.random.PRNGKey(7))
        assert all(r.status == "ok" for r in refs)

        eng, _ = child_engine(tiny, jdir)
        recovered = eng.recover()
        if alive:
            assert recovered, "SIGKILL mid-decode must leave work to recover"
        eng.run(jax.random.PRNGKey(7))
        assert all(r.status == "ok" for r in recovered)
        recs = J.replay(J.read_events(jdir)[0])
        assert sorted(recs) == [0, 1, 2, 3]
        for rid, rec in recs.items():
            assert rec.status == "ok"
            assert rec.tokens == list(refs[rid].tokens), \
                f"req {rid} diverged after SIGKILL recovery"
        check_drained(eng)
