"""Pallas kernel tests (interpret mode): shape/dtype sweeps vs pure-jnp
oracles, plus end-to-end equivalence of the pallas attention path against
the model's jnp reference attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hier_kv_cache as HC
from repro.core.quantization import quantize_k_block, quantize_v_block
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.quant_attention import quant_region_attention
from repro.kernels.quant_pack import quantize_kv_block
from repro.models import common as L


def make_quant_region(key, BH, NB, G, D):
    k1, k2 = jax.random.split(key)
    k = jax.random.normal(k1, (BH, NB, G, 1, D))
    v = jax.random.normal(k2, (BH, NB, G, 1, D))
    kq = quantize_k_block(k)
    vq = quantize_v_block(v)
    sq = lambda t: t.squeeze(3)
    return (sq(kq.upper), sq(kq.lower), kq.scale.squeeze(3), kq.zero.squeeze(3),
            sq(vq.upper), sq(vq.lower), sq(vq.scale), sq(vq.zero))


@pytest.mark.parametrize("shape", [
    # (BH, NB, G, D, gT, blocks)
    (2, 3, 16, 32, 4, 3),
    (1, 4, 8, 64, 1, 2),
    (3, 2, 32, 128, 8, 1),
    (2, 5, 16, 32, 4, 0),     # empty quant region
])
@pytest.mark.parametrize("mode", ["draft", "target"])
def test_quant_attention_vs_ref(shape, mode):
    BH, NB, G, D, gT, blocks = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    planes = make_quant_region(key, BH, NB, G, D)
    q = jax.random.normal(jax.random.fold_in(key, 1), (BH, gT, D))

    out_k, lse_k = quant_region_attention(q, *planes, blocks, mode)
    out_r, lse_r = kref.quant_region_attention_ref(q, *planes, blocks, mode)

    if blocks > 0:
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                                   atol=2e-5, rtol=2e-5)
    else:
        assert not np.isfinite(np.asarray(lse_k)).any()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_attention_dtypes(dtype):
    BH, NB, G, D, gT = 2, 3, 16, 64, 4
    key = jax.random.PRNGKey(7)
    planes = make_quant_region(key, BH, NB, G, D)
    q = jax.random.normal(jax.random.fold_in(key, 1), (BH, gT, D)).astype(dtype)
    out_k, _ = quant_region_attention(q, *planes, 3, "target")
    out_r, _ = kref.quant_region_attention_ref(q, *planes, 3, "target")
    assert out_k.dtype == dtype
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("shape", [(2, 16, 32), (4, 8, 64), (1, 32, 128)])
def test_quant_pack_vs_ref(shape):
    BH, G, D = shape
    key = jax.random.PRNGKey(11)
    k = jax.random.normal(key, (BH, G, D)) * 2.0 + 0.5
    v = jax.random.normal(jax.random.fold_in(key, 1), (BH, G, D))
    got = quantize_kv_block(k, v)
    want = kref.quantize_kv_block_ref(k, v)
    for name in want:
        g = np.asarray(got[name], np.float32)
        w = np.asarray(want[name], np.float32)
        if name.endswith("_lower"):
            # rounding ties may flip ±1 code (FMA ordering); bound the
            # dequantized effect instead of exact code equality
            gu, gl = np.divmod(g, 16) if False else (g // 16, g % 16)
            wu, wl = w // 16, w % 16
            np.testing.assert_array_equal(gu, wu, err_msg=name + " hi")
            assert np.abs(gl - wl).max() <= 1, name
            assert (np.abs(gl - wl) > 0).mean() < 0.005, name
        else:
            np.testing.assert_allclose(g, w, atol=1e-5, err_msg=name)


class TestEndToEndPallasAttention:
    """pallas hier_attention == jnp attend_hier on a real cache."""

    @pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2)])
    @pytest.mark.parametrize("T", [1, 4])
    def test_matches_jnp_path(self, Hq, Hkv, T):
        B, G, D, NB = 2, 16, 32, 5
        S = 3 * G + 5
        key = jax.random.PRNGKey(3)
        cache = HC.init_cache(B, NB, G, Hkv, D)
        k = jax.random.normal(key, (B, S, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
        cache = HC.prefill(cache, k, v)
        nk = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D))
        nv = jax.random.normal(jax.random.fold_in(key, 3), (B, T, Hkv, D))
        cache = HC.append(cache, nk, nv)
        q = jax.random.normal(jax.random.fold_in(key, 4), (B, T, Hq, D))

        for mode in ("draft", "target"):
            ref = L.attend_hier(q, cache, S, mode)
            got = kops.hier_attention(q, cache, S, mode)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=3e-5, rtol=3e-5,
                                       err_msg=f"mode={mode}")

    def test_jit_compiles(self):
        B, G, D, Hkv, NB, T = 1, 16, 32, 2, 3, 2
        cache = HC.init_cache(B, NB, G, Hkv, D)
        k = jax.random.normal(jax.random.PRNGKey(0), (B, 2 * G, Hkv, D))
        cache = HC.prefill(cache, k, k)
        q = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, D))
        cache = HC.append(cache, q[:, :, :Hkv], q[:, :, :Hkv])
        f = jax.jit(lambda q, c: kops.hier_attention(q, c, 2 * G, "target"))
        out = f(q, cache)
        assert np.isfinite(np.asarray(out)).all()


class TestBlockedImpl:
    """'blocked' hierarchical attention (§Perf iteration) == 'flat'."""

    @pytest.mark.parametrize("Hq,Hkv,T", [(4, 4, 1), (8, 2, 4)])
    def test_blocked_matches_flat(self, Hq, Hkv, T):
        B, G, D, NB = 2, 16, 32, 5
        S = 3 * G + 5
        key = jax.random.PRNGKey(13)
        cache = HC.init_cache(B, NB, G, Hkv, D)
        k = jax.random.normal(key, (B, S, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
        cache = HC.prefill(cache, k, v)
        nk = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D))
        cache = HC.append(cache, nk, nk)
        q = jax.random.normal(jax.random.fold_in(key, 4), (B, T, Hq, D))
        for mode in ("draft", "target"):
            flat = L.attend_hier(q, cache, S, mode, impl="flat")
            blocked = L.attend_hier(q, cache, S, mode, impl="blocked")
            np.testing.assert_allclose(np.asarray(blocked), np.asarray(flat),
                                       atol=3e-5, rtol=3e-5,
                                       err_msg=f"mode={mode}")

    def test_blocked_empty_quant_region(self):
        B, G, D, Hkv = 1, 16, 32, 2
        cache = HC.init_cache(B, 3, G, Hkv, D)
        k = jax.random.normal(jax.random.PRNGKey(0), (B, 10, Hkv, D))
        cache = HC.prefill(cache, k, k)  # all in buffer
        q = jax.random.normal(jax.random.PRNGKey(1), (B, 2, Hkv, D))
        cache = HC.append(cache, q, q)
        flat = L.attend_hier(q, cache, 10, "target", impl="flat")
        blocked = L.attend_hier(q, cache, 10, "target", impl="blocked")
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(flat),
                                   atol=3e-5)
