"""Pallas kernel tests (interpret mode): shape/dtype sweeps vs pure-jnp
oracles, plus end-to-end equivalence of the pallas attention path against
the model's jnp reference attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hier_kv_cache as HC
from repro.core.quantization import quantize_k_block, quantize_v_block
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.quant_attention import (
    hier_flash_attention,
    paged_hier_flash_attention,
    quant_region_attention,
)
from repro.kernels.quant_pack import quantize_kv_block
from repro.models import common as L


def make_quant_region(key, BH, NB, G, D):
    k1, k2 = jax.random.split(key)
    k = jax.random.normal(k1, (BH, NB, G, 1, D))
    v = jax.random.normal(k2, (BH, NB, G, 1, D))
    kq = quantize_k_block(k)
    vq = quantize_v_block(v)
    sq = lambda t: t.squeeze(3)
    return (sq(kq.upper), sq(kq.lower), kq.scale.squeeze(3), kq.zero.squeeze(3),
            sq(vq.upper), sq(vq.lower), sq(vq.scale), sq(vq.zero))


@pytest.mark.parametrize("shape", [
    # (BH, NB, G, D, gT, blocks)
    (2, 3, 16, 32, 4, 3),
    (1, 4, 8, 64, 1, 2),
    (3, 2, 32, 128, 8, 1),
    (2, 5, 16, 32, 4, 0),     # empty quant region
])
@pytest.mark.parametrize("mode", ["draft", "target"])
def test_quant_attention_vs_ref(shape, mode):
    BH, NB, G, D, gT, blocks = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    planes = make_quant_region(key, BH, NB, G, D)
    q = jax.random.normal(jax.random.fold_in(key, 1), (BH, gT, D))

    out_k, lse_k = quant_region_attention(q, *planes, blocks, mode)
    out_r, lse_r = kref.quant_region_attention_ref(q, *planes, blocks, mode)

    if blocks > 0:
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                                   atol=2e-5, rtol=2e-5)
    else:
        assert not np.isfinite(np.asarray(lse_k)).any()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_attention_dtypes(dtype):
    BH, NB, G, D, gT = 2, 3, 16, 64, 4
    key = jax.random.PRNGKey(7)
    planes = make_quant_region(key, BH, NB, G, D)
    q = jax.random.normal(jax.random.fold_in(key, 1), (BH, gT, D)).astype(dtype)
    out_k, _ = quant_region_attention(q, *planes, 3, "target")
    out_r, _ = kref.quant_region_attention_ref(q, *planes, 3, "target")
    assert out_k.dtype == dtype
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("shape", [(2, 16, 32), (4, 8, 64), (1, 32, 128)])
def test_quant_pack_vs_ref(shape):
    BH, G, D = shape
    key = jax.random.PRNGKey(11)
    k = jax.random.normal(key, (BH, G, D)) * 2.0 + 0.5
    v = jax.random.normal(jax.random.fold_in(key, 1), (BH, G, D))
    got = quantize_kv_block(k, v)
    want = kref.quantize_kv_block_ref(k, v)
    for name in want:
        g = np.asarray(got[name], np.float32)
        w = np.asarray(want[name], np.float32)
        if name.endswith("_lower"):
            # rounding ties may flip ±1 code (FMA ordering); bound the
            # dequantized effect instead of exact code equality
            gu, gl = np.divmod(g, 16) if False else (g // 16, g % 16)
            wu, wl = w // 16, w % 16
            np.testing.assert_array_equal(gu, wu, err_msg=name + " hi")
            assert np.abs(gl - wl).max() <= 1, name
            assert (np.abs(gl - wl) > 0).mean() < 0.005, name
        else:
            np.testing.assert_allclose(g, w, atol=1e-5, err_msg=name)


def make_buffer(key, BH, G, D, scale=1.0):
    bk = jax.random.normal(key, (BH, 2 * G, D)) * scale
    bv = jax.random.normal(jax.random.fold_in(key, 1), (BH, 2 * G, D)) * scale
    return bk, bv


class TestSinglePassHier:
    """Single-pass hierarchical kernel == the old two-pass path (quant
    flash + materialized-mask FP chunk + LSE merge, kernels/ref.py).
    Tolerance 3e-5: both sides are f32 online softmax, differing only in
    summation order."""

    @pytest.mark.parametrize("shape", [
        # (BH, NB, G, D, T, g, blocks, buf_len)
        (2, 4, 16, 32, 1, 1, 3, 20),    # decode step, both chunks live
        (2, 4, 16, 32, 4, 2, 3, 24),    # γ-window queries, GQA replicas
        (1, 3, 8, 64, 2, 1, 0, 10),     # empty quant region
        (3, 5, 16, 32, 1, 1, 5, 4),     # full region, C_F1-only buffer
        (2, 3, 16, 32, 2, 1, 2, 0),     # empty FP buffer (odd NB → KB=1)
    ])
    @pytest.mark.parametrize("mode", ["draft", "target"])
    def test_vs_twopass_ref(self, shape, mode):
        BH, NB, G, D, T, g, blocks, buf_len = shape
        key = jax.random.PRNGKey(hash(shape) % 2**31)
        planes = make_quant_region(key, BH, NB, G, D)
        bk, bv = make_buffer(jax.random.fold_in(key, 2), BH, G, D)
        q = jax.random.normal(jax.random.fold_in(key, 3), (BH, g * T, D))
        stream_pos = blocks * G + buf_len - T   # queries are the newest tokens

        got = hier_flash_attention(q, *planes, bk, bv, blocks, buf_len,
                                   stream_pos, T, mode)
        want = kref.hier_attention_twopass_ref(q, *planes, bk, bv, blocks,
                                               buf_len, stream_pos, T, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5,
                                   err_msg=f"mode={mode}")

    @pytest.mark.parametrize("kb", [1, 2, 4])
    def test_kb_invariant(self, kb):
        """KB (quant groups per grid step) must not change the math."""
        BH, NB, G, D, T = 2, 4, 16, 32, 2
        key = jax.random.PRNGKey(21)
        planes = make_quant_region(key, BH, NB, G, D)
        bk, bv = make_buffer(jax.random.fold_in(key, 2), BH, G, D)
        q = jax.random.normal(jax.random.fold_in(key, 3), (BH, T, D))
        out = hier_flash_attention(q, *planes, bk, bv, 3, 12, 3 * G + 12 - T,
                                   T, "target", kb=kb)
        want = kref.hier_attention_twopass_ref(q, *planes, bk, bv, 3, 12,
                                               3 * G + 12 - T, T, "target")
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_bf16_queries(self):
        BH, NB, G, D, T = 2, 2, 16, 64, 1
        key = jax.random.PRNGKey(23)
        planes = make_quant_region(key, BH, NB, G, D)
        bk, bv = make_buffer(jax.random.fold_in(key, 2), BH, G, D)
        q = jax.random.normal(jax.random.fold_in(key, 3),
                              (BH, T, D)).astype(jnp.bfloat16)
        got = hier_flash_attention(q, *planes, bk, bv, 2, 18, 2 * G + 17,
                                   T, "target")
        assert got.dtype == jnp.bfloat16
        want = kref.hier_attention_twopass_ref(
            q.astype(jnp.float32), *planes, bk, bv, 2, 18, 2 * G + 17, T,
            "target")
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), atol=2e-2, rtol=2e-2)


class TestSinglePassPaged:
    """Paged single-pass kernel vs the paged two-pass reference, ragged
    slots with non-empty FP buffers."""

    def _make_pool(self, key, P, H, G, D):
        # planes flattened per (block, head): row p*H + h
        k = jax.random.normal(key, ((P + 1) * H, 1, G, 1, D))
        v = jax.random.normal(jax.random.fold_in(key, 1),
                              ((P + 1) * H, 1, G, 1, D))
        kq = quantize_k_block(k)
        vq = quantize_v_block(v)
        sq = lambda t: t[:, 0].squeeze(2)
        return (sq(kq.upper), sq(kq.lower),
                kq.scale[:, 0].squeeze(2), kq.zero[:, 0].squeeze(2),
                sq(vq.upper), sq(vq.lower), sq(vq.scale), sq(vq.zero))

    @pytest.mark.parametrize("mode", ["draft", "target"])
    @pytest.mark.parametrize("T,g", [(1, 1), (3, 2)])
    def test_ragged_vs_twopass_ref(self, mode, T, g):
        R, H, P, NBmax, G, D = 3, 2, 7, 4, 8, 32
        key = jax.random.PRNGKey(31)
        planes = self._make_pool(key, P, H, G, D)
        bk, bv = make_buffer(jax.random.fold_in(key, 2), R * H, G, D)
        q = jax.random.normal(jax.random.fold_in(key, 3), (R * H, g * T, D))

        # ragged: slot 0 mid-stream, slot 1 buffer-only, slot 2 full table
        blocks = jnp.asarray([2, 0, 4], jnp.int32)
        buf_len = jnp.asarray([10, 2 * G, 5], jnp.int32)
        block_table = jnp.asarray(
            [[5, 1, 0, 0], [0, 0, 0, 0], [2, 6, 3, 4]], jnp.int32)
        stream_pos = blocks * G + buf_len - T

        got = paged_hier_flash_attention(
            q, *planes, bk, bv, block_table, blocks, buf_len, stream_pos,
            H, T, mode)
        want = kref.paged_hier_attention_twopass_ref(
            q, *planes, bk, bv, block_table, blocks, buf_len, stream_pos,
            H, T, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5,
                                   err_msg=f"mode={mode}")

    def test_kb_lanes_invariant(self):
        R, H, P, NBmax, G, D, T = 2, 1, 5, 3, 8, 32, 1
        key = jax.random.PRNGKey(37)
        planes = self._make_pool(key, P, H, G, D)
        bk, bv = make_buffer(jax.random.fold_in(key, 2), R * H, G, D)
        q = jax.random.normal(jax.random.fold_in(key, 3), (R * H, T, D))
        blocks = jnp.asarray([3, 1], jnp.int32)
        buf_len = jnp.asarray([9, 16], jnp.int32)
        bt = jnp.asarray([[4, 0, 2], [1, 0, 0]], jnp.int32)
        pos = blocks * G + buf_len - T
        outs = [paged_hier_flash_attention(q, *planes, bk, bv, bt, blocks,
                                           buf_len, pos, H, T, "target", kb=kb)
                for kb in (1, 2, 3)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                       atol=3e-5, rtol=3e-5)


class TestEndToEndPallasAttention:
    """pallas hier_attention == jnp attend_hier on a real cache."""

    @pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2)])
    @pytest.mark.parametrize("T", [1, 4])
    def test_matches_jnp_path(self, Hq, Hkv, T):
        B, G, D, NB = 2, 16, 32, 5
        S = 3 * G + 5
        key = jax.random.PRNGKey(3)
        cache = HC.init_cache(B, NB, G, Hkv, D)
        k = jax.random.normal(key, (B, S, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
        cache = HC.prefill(cache, k, v)
        nk = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D))
        nv = jax.random.normal(jax.random.fold_in(key, 3), (B, T, Hkv, D))
        cache = HC.append(cache, nk, nv)
        q = jax.random.normal(jax.random.fold_in(key, 4), (B, T, Hq, D))

        for mode in ("draft", "target"):
            ref = L.attend_hier(q, cache, S, mode)
            got = kops.hier_attention(q, cache, S, mode)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=3e-5, rtol=3e-5,
                                       err_msg=f"mode={mode}")

    def test_jit_compiles(self):
        B, G, D, Hkv, NB, T = 1, 16, 32, 2, 3, 2
        cache = HC.init_cache(B, NB, G, Hkv, D)
        k = jax.random.normal(jax.random.PRNGKey(0), (B, 2 * G, Hkv, D))
        cache = HC.prefill(cache, k, k)
        q = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, D))
        cache = HC.append(cache, q[:, :, :Hkv], q[:, :, :Hkv])
        f = jax.jit(lambda q, c: kops.hier_attention(q, c, 2 * G, "target"))
        out = f(q, cache)
        assert np.isfinite(np.asarray(out)).all()


class TestBlockedImpl:
    """'blocked' hierarchical attention (§Perf iteration) == 'flat'."""

    @pytest.mark.parametrize("Hq,Hkv,T", [(4, 4, 1), (8, 2, 4)])
    def test_blocked_matches_flat(self, Hq, Hkv, T):
        B, G, D, NB = 2, 16, 32, 5
        S = 3 * G + 5
        key = jax.random.PRNGKey(13)
        cache = HC.init_cache(B, NB, G, Hkv, D)
        k = jax.random.normal(key, (B, S, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
        cache = HC.prefill(cache, k, v)
        nk = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D))
        cache = HC.append(cache, nk, nk)
        q = jax.random.normal(jax.random.fold_in(key, 4), (B, T, Hq, D))
        for mode in ("draft", "target"):
            flat = L.attend_hier(q, cache, S, mode, impl="flat")
            blocked = L.attend_hier(q, cache, S, mode, impl="blocked")
            np.testing.assert_allclose(np.asarray(blocked), np.asarray(flat),
                                       atol=3e-5, rtol=3e-5,
                                       err_msg=f"mode={mode}")

    def test_blocked_empty_quant_region(self):
        B, G, D, Hkv = 1, 16, 32, 2
        cache = HC.init_cache(B, 3, G, Hkv, D)
        k = jax.random.normal(jax.random.PRNGKey(0), (B, 10, Hkv, D))
        cache = HC.prefill(cache, k, k)  # all in buffer
        q = jax.random.normal(jax.random.PRNGKey(1), (B, 2, Hkv, D))
        cache = HC.append(cache, q, q)
        flat = L.attend_hier(q, cache, 10, "target", impl="flat")
        blocked = L.attend_hier(q, cache, 10, "target", impl="blocked")
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(flat),
                                   atol=3e-5)
