"""Dry-run integration tests: run the actual dryrun entry point (with its
512 forced host devices) in a subprocess for a cheap combo on both meshes.
The full 10×4×2 sweep runs via `python -m repro.launch.dryrun --both-meshes`
and is recorded in EXPERIMENTS.md §Dry-run."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(arch, shape, multi=False, timeout=900):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out",
           "experiments/dryrun_test"]
    if multi:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=timeout)


@pytest.mark.slow
def test_dryrun_single_pod_decode():
    r = _run_dryrun("rwkv6-1.6b", "decode_32k")
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    path = os.path.join(REPO, "experiments/dryrun_test",
                        "rwkv6-1.6b__decode_32k__16x16.json")
    with open(path) as f:
        rec = json.load(f)
    assert rec["flops"] > 0
    assert rec["bytes_accessed"] > 0


@pytest.mark.slow
def test_dryrun_multi_pod_decode():
    r = _run_dryrun("rwkv6-1.6b", "decode_32k", multi=True)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    path = os.path.join(REPO, "experiments/dryrun_test",
                        "rwkv6-1.6b__decode_32k__2x16x16.json")
    assert os.path.exists(path)
