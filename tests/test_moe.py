"""MoE dispatch tests: routing correctness, capacity behaviour, and
scatter ≡ shard_map equivalence on a local mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import axis_rules
from repro.launch.mesh import make_local_mesh
from repro.models.moe import _apply_moe_scatter, apply_moe, init_moe_params, moe_capacity


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-moe-235b-a22b", smoke=True)
    key = jax.random.PRNGKey(0)
    p = init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    return cfg, p, x


def test_output_shape_and_aux(setup):
    cfg, p, x = setup
    y, aux = apply_moe(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert 0 <= float(aux) < 1.0


def test_matches_dense_reference(setup):
    """Scatter dispatch == brute-force per-token top-k combination."""
    cfg, p, x = setup
    B, T, d = x.shape
    xf = np.asarray(x.reshape(-1, d), np.float32)
    probs = np.asarray(jax.nn.softmax(
        x.reshape(-1, d).astype(jnp.float32) @ p["router"], -1))
    top_e = np.argsort(-probs, axis=-1)[:, : cfg.top_k]
    top_p = np.take_along_axis(probs, top_e, axis=-1)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    def ffn(e, xi):
        g = np.asarray(jax.nn.silu(xi @ p["experts"]["w_gate"][e]))
        return (g * (xi @ p["experts"]["w_up"][e])) @ p["experts"]["w_down"][e]

    want = np.stack([
        sum(top_p[n, k] * ffn(int(top_e[n, k]), xf[n])
            for k in range(cfg.top_k))
        for n in range(xf.shape[0])])
    got, _ = apply_moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got.reshape(-1, d)), want,
                               atol=2e-5, rtol=2e-4)


def test_capacity_drops_overflow():
    cfg = get_config("qwen3-moe-235b-a22b", smoke=True).replace(
        capacity_factor=0.05)  # absurdly small -> most tokens dropped
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    y, _ = apply_moe(p, cfg, x)
    assert np.isfinite(np.asarray(y)).all()
    # dropped tokens -> output strictly smaller norm than capacity 1.25
    cfg2 = cfg.replace(capacity_factor=1.25)
    y2, _ = apply_moe(p, cfg2, x)
    assert float(jnp.abs(y).sum()) < float(jnp.abs(y2).sum())


def test_shard_map_matches_scatter(setup):
    cfg, p, x = setup
    mesh = make_local_mesh()
    y0, aux0 = _apply_moe_scatter(p, cfg, x)
    cfg_sm = cfg.replace(moe_impl="shard_map")
    with mesh, axis_rules(mesh, "train"):
        y1, aux1 = jax.jit(lambda p, x: apply_moe(p, cfg_sm, x))(p, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(float(aux0), float(aux1), atol=1e-5)


def test_capacity_rounding():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert moe_capacity(cfg, 16384) % 128 == 0
    assert moe_capacity(cfg, 100) % 4 == 0
