"""Chunked/padded serve-time prefill pipeline.

Covers the flash-prefill kernel (interpret mode) against the jnp causal
oracle; bucket-padded one-shot prefill against the unpadded path; the
chunked paged prefill against the dense-prefill + adopt oracle (cache
contents and greedy continuations); decode-interleaved admission (token
identity + no admission freeze); compile-once-per-bucket across a ragged
prompt sweep; and the backend dispatch helpers (`interpret_default`,
`quant_pack_impl`)."""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serving.engine as engine_mod
from repro.configs import get_config
from repro.core import paged_kv_cache as PC
from repro.core.quantization import quantize_kv_block_pair
from repro.kernels import interpret_default
from repro.kernels import ref as kref
from repro.kernels.prefill_attention import flash_prefill_attention
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine, Engine


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_prompt(cfg, n, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, cfg.vocab_size))


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------

class TestFlashPrefillKernel:
    """Interpret-mode parity of the causal flash-prefill kernel vs the jnp
    oracle (kernels/ref.py) over the shared [BH, gT, D] GQA layout."""

    @pytest.mark.parametrize("shape", [
        # (BH, g, T, S, D, q_start, kv_len)
        (2, 1, 16, 48, 32, 0, 48),    # one-shot, exact bucket
        (2, 2, 12, 40, 64, 0, 29),    # GQA g>1, ragged final chunk
        (3, 1, 8, 64, 32, 24, 32),    # mid-prompt band chunk
        (1, 3, 7, 21, 32, 14, 21),    # odd T (block-size fallback)
        (1, 1, 5, 5, 64, 0, 5),       # chunk == S edge
        (2, 2, 4, 32, 32, 28, 30),    # band with padded chunk tail
    ])
    def test_vs_ref(self, shape):
        BH, g, T, S, D, q0, kvl = shape
        key = jax.random.PRNGKey(hash(shape) % 2**31)
        q = jax.random.normal(key, (BH, g * T, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, D))
        got = flash_prefill_attention(q, k, v, q0, kvl, T,
                                      q_block=8, k_block=16)
        want = kref.prefill_attention_ref(q, k, v, q0, kvl, T)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_block_sizes_invariant(self):
        BH, g, T, S, D = 2, 2, 12, 48, 32
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (BH, g * T, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, D))
        outs = [flash_prefill_attention(q, k, v, 20, 32, T,
                                        q_block=qb, k_block=kb)
                for qb, kb in ((1, 1), (4, 8), (12, 48), (128, 128))]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                       atol=3e-5, rtol=3e-5)

    def test_bf16(self):
        BH, T, S, D = 2, 8, 24, 64
        key = jax.random.PRNGKey(5)
        q = jax.random.normal(key, (BH, T, D)).astype(jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, D))
        got = flash_prefill_attention(q, k, v, 16, 24, T)
        assert got.dtype == jnp.bfloat16
        want = kref.prefill_attention_ref(q.astype(jnp.float32), k, v,
                                          16, 24, T)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), atol=2e-2, rtol=2e-2)

    def test_jit_traced_scalars_single_compile(self):
        """q_start/kv_len are traced: every chunk reuses one program."""
        BH, T, S, D = 1, 8, 32, 32
        key = jax.random.PRNGKey(7)
        q = jax.random.normal(key, (BH, T, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, D))
        f = jax.jit(lambda q, k, q0, kvl: flash_prefill_attention(
            q, k, k, q0, kvl, T))
        for q0, kvl in ((0, 8), (8, 16), (24, 32)):
            out = f(q, k, jnp.asarray(q0), jnp.asarray(kvl))
            assert np.isfinite(np.asarray(out)).all()
        assert f._cache_size() == 1


# ---------------------------------------------------------------------------
# backend dispatch helpers
# ---------------------------------------------------------------------------

class TestDispatchHelpers:
    def test_interpret_default_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        assert interpret_default() is True
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        assert interpret_default() is False
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
        # auto: interpret anywhere but TPU
        assert interpret_default() is (jax.default_backend() != "tpu")

    def test_quant_pack_dispatch_parity(self, monkeypatch):
        """The Pallas pack route (interpret mode here) must agree with the
        jnp quantizer through the same [..., G, H, D] adapter — upper
        planes and scales exactly, lower plane within the known ±1
        rounding-tie tolerance."""
        key = jax.random.PRNGKey(11)
        k = jax.random.normal(key, (3, 16, 2, 32)) * 1.5
        v = jax.random.normal(jax.random.fold_in(key, 1), (3, 16, 2, 32))
        monkeypatch.setenv("REPRO_QUANT_PACK", "jnp")
        kq_j, vq_j = quantize_kv_block_pair(k, v)
        monkeypatch.setenv("REPRO_QUANT_PACK", "pallas")
        kq_p, vq_p = quantize_kv_block_pair(k, v)
        for a, b in ((kq_j, kq_p), (vq_j, vq_p)):
            assert a.upper.shape == b.upper.shape
            assert a.scale.shape == b.scale.shape
            np.testing.assert_array_equal(np.asarray(a.upper),
                                          np.asarray(b.upper))
            np.testing.assert_allclose(np.asarray(a.scale),
                                       np.asarray(b.scale), atol=1e-6)
            np.testing.assert_allclose(np.asarray(a.zero),
                                       np.asarray(b.zero), atol=1e-6)
            lj = np.asarray(a.lower, np.int32)
            lp = np.asarray(b.lower, np.int32)
            dh = np.abs((lj >> 4) - (lp >> 4))
            dl = np.abs((lj & 0xF) - (lp & 0xF))
            assert max(dh.max(), dl.max()) <= 1


# ---------------------------------------------------------------------------
# bucket-padded one-shot prefill (static engine)
# ---------------------------------------------------------------------------

def assert_cache_leaves_close(got, want, atol=2e-4, rtol=2e-4,
                              code_frac=0.01):
    """Leaf-wise cache comparison that is exact where exactness is defined.

    Float leaves (fp buffers, scales, zeros) compare with ``allclose``.
    uint8 leaves are packed INT4 code planes: the fp inputs feeding the
    quantizer are only reproducible up to reassociation (different prefill
    shapes tile the projection matmuls differently), so a value sitting on
    a rounding threshold may legitimately land one code apart. Codes must
    agree to ±1 nibble at a small fraction of positions; anything larger
    (or widespread) is real corruption and still fails.
    """
    flat_got = jax.tree_util.tree_flatten_with_path(got)[0]
    flat_want = jax.tree_util.tree_flatten_with_path(want)[0]
    assert len(flat_got) == len(flat_want)
    for (path, a), (_, b) in zip(flat_got, flat_want):
        a, b = np.asarray(a), np.asarray(b)
        where = jax.tree_util.keystr(path)
        assert a.shape == b.shape, where
        if a.dtype == np.uint8:
            for plane_a, plane_b in ((a & 15, b & 15), (a >> 4, b >> 4)):
                diff = np.abs(plane_a.astype(np.int16) - plane_b.astype(np.int16))
                np.testing.assert_array_less(
                    diff.max(initial=0), 2,
                    err_msg=f"{where}: codes differ by more than one "
                            "quantization step")
                frac = float((diff > 0).mean())
                assert frac <= code_frac, (
                    f"{where}: {frac:.2%} of codes differ (threshold "
                    f"flips should be rare, got > {code_frac:.0%})")
        else:
            np.testing.assert_allclose(a.astype(np.float32),
                                       b.astype(np.float32),
                                       atol=atol, rtol=rtol, err_msg=where)


class TestCacheComparison:
    """The ±1-code comparison still catches real cache corruption."""

    def test_rejects_multi_step_corruption(self):
        base = np.arange(64, dtype=np.uint8).reshape(8, 8)
        bad = base.copy()
        bad[3, 3] += 2          # two quantization steps in the low nibble
        with pytest.raises(AssertionError):
            assert_cache_leaves_close([bad], [base])

    def test_rejects_widespread_flips(self):
        base = np.zeros((8, 8), dtype=np.uint8)
        bad = base + 1          # every low nibble off by one code
        with pytest.raises(AssertionError):
            assert_cache_leaves_close([bad], [base])

    def test_accepts_rare_threshold_flip(self):
        base = np.arange(1024, dtype=np.uint8).reshape(32, 32) & 0x77
        ok = base.copy()
        ok[3, 3] += 1           # one rounding-threshold flip
        assert_cache_leaves_close([ok], [base])

    def test_float_leaves_stay_strict(self):
        base = np.ones((4, 4), dtype=np.float32)
        bad = base.copy()
        bad[0, 0] += 1e-2
        with pytest.raises(AssertionError):
            assert_cache_leaves_close([bad], [base])


class TestPaddedStaticPrefill:
    @pytest.mark.parametrize("policy", ["quantspec", "fp"])
    @pytest.mark.parametrize("L", [7, 37, 97])
    def test_model_level_equivalence(self, tiny, policy, L):
        cfg, model, params = tiny
        Sp = ((L + 31) // 32) * 32 + 32
        tok = jnp.asarray(make_prompt(cfg, L, seed=L))[None]
        padded = jnp.pad(tok, ((0, 0), (0, Sp - L)))
        st = model.init_serve_state(1, max_seq=Sp + 64, policy=policy)
        lo_u, st_u = model.prefill(params, tok, st, policy=policy)
        st = model.init_serve_state(1, max_seq=Sp + 64, policy=policy)
        lo_p, st_p = model.prefill(params, padded, st, policy=policy,
                                   ctx_kw={"prefill_len": jnp.asarray(L)})
        np.testing.assert_allclose(np.asarray(lo_p), np.asarray(lo_u),
                                   atol=2e-5, rtol=2e-5)
        # caches agree everywhere they are defined (valid prefix masks);
        # packed INT4 planes compare code-wise: the padded prefill tiles
        # its matmuls differently, so threshold values may round one code
        # apart even though both inputs are correct
        assert_cache_leaves_close(st_p, st_u)

    def test_engine_tokens_identical_to_legacy(self, tiny):
        cfg, model, params = tiny
        L, max_new = 41, 12
        prompt = jnp.asarray(make_prompt(cfg, L, seed=2))[None]
        legacy = Engine(model, params, policy="quantspec", gamma=3,
                        greedy=True, max_seq=256)
        legacy._bucketed = False          # force the per-length path
        bucketed = Engine(model, params, policy="quantspec", gamma=3,
                          greedy=True, max_seq=256, prefill_chunk=32)
        r_l = legacy.generate(prompt, max_new, key=jax.random.PRNGKey(7))
        r_b = bucketed.generate(prompt, max_new, key=jax.random.PRNGKey(7))
        np.testing.assert_array_equal(r_l.tokens, r_b.tokens)

    def test_compiles_once_per_bucket(self, tiny):
        cfg, model, params = tiny
        eng = Engine(model, params, policy="quantspec", gamma=2, greedy=True,
                     max_seq=256, prefill_chunk=32)
        lens = [5, 20, 30, 33, 50, 64]        # buckets {32, 64}
        for i, L in enumerate(lens):
            eng.generate(jnp.asarray(make_prompt(cfg, L, seed=i))[None], 2,
                         key=jax.random.PRNGKey(i))
        assert eng.prefill_compiles() == 2, \
            f"expected 2 bucket programs, got {eng.prefill_compiles()}"


    def test_pallas_dispatch_tokens_identical(self, tiny, monkeypatch):
        """REPRO_PREFILL_ATTN=pallas routes serve prefill through the flash
        kernel (interpret mode here) with unchanged greedy output."""
        cfg, model, params = tiny
        prompt = jnp.asarray(make_prompt(cfg, 41, seed=8))[None]
        monkeypatch.setenv("REPRO_PREFILL_ATTN", "jnp")
        eng = Engine(model, params, policy="quantspec", gamma=2, greedy=True,
                     max_seq=256, prefill_chunk=32)
        want = eng.generate(prompt, 6, key=jax.random.PRNGKey(7)).tokens
        monkeypatch.setenv("REPRO_PREFILL_ATTN", "pallas")
        eng = Engine(model, params, policy="quantspec", gamma=2, greedy=True,
                     max_seq=256, prefill_chunk=32)
        got = eng.generate(prompt, 6, key=jax.random.PRNGKey(7)).tokens
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# chunked paged prefill vs the dense + adopt oracle
# ---------------------------------------------------------------------------

def _attn_states(state):
    """Flatten the paged engine state into per-layer (AttnState, stacked)."""
    out = []
    for k in ("head", "tail"):
        for mix, _ in state[k]:
            out.append((mix, False))
    for mix, _ in state["blocks"]:
        out.append((mix, True))
    return out


class TestChunkedVsDense:
    """Chunked admission writes pool blocks/buffers identical (up to fp
    reassociation of the attention sums feeding the quantizer) to the
    legacy dense-prefill + adopt_hier copy path, and one-shot (single
    chunk) admission matches it exactly."""

    @pytest.mark.parametrize("n_chunks_hint", [1, 4])
    def test_cache_contents_match_dense_adopt(self, tiny, n_chunks_hint):
        cfg, model, params = tiny
        G = cfg.group_size
        L = 3 * G + 5
        C = L if n_chunks_hint == 1 else G // 2   # one-shot vs 7 chunks
        prompt = make_prompt(cfg, L, seed=3)
        max_seq = L + 2 * G + 16

        # chunked engine admission (no decode yet)
        ceng = ContinuousEngine(model, params, gamma=2, greedy=True,
                                max_slots=1, max_seq=max_seq,
                                prefill_chunk=C)
        req = ceng.submit(prompt, 2)     # >1 so admission doesn't retire
        key = jax.random.PRNGKey(0)
        while ceng._prefilling is not None or req.prefill_chunks == 0:
            key = ceng._advance_prefill(key)
        assert req.prefill_pos == L

        # dense oracle: batch-1 contiguous prefill + adopt into a pool
        st = model.init_serve_state(1, max_seq=max_seq, policy="quantspec")
        _, dense = model.prefill(params, jnp.asarray(prompt)[None], st,
                                 policy="quantspec")
        n_blocks = (L - G) // G
        table = PC.init_table(1, ceng.nbmax, ceng.pool_blocks)
        table, ids = PC.alloc_blocks(table, 0, n_blocks)

        eng_layers = _attn_states(ceng.state)
        dense_layers = _attn_states(dense)
        assert len(eng_layers) == len(dense_layers)
        bt_ids = np.asarray(ceng.table.block_table[0, :n_blocks])
        assert int(ceng.table.blocks[0]) == n_blocks
        assert int(ceng.table.buf_len[0]) == L - n_blocks * G

        for (em, stacked), (dm, _) in zip(eng_layers, dense_layers):
            pools = [jax.tree.map(lambda x: x[i], em.primary)
                     for i in range(cfg.n_repeats)] if stacked else [em.primary]
            hiers = [jax.tree.map(lambda x: x[i], dm.primary)
                     for i in range(cfg.n_repeats)] if stacked else [dm.primary]
            for pool, hier in zip(pools, hiers):
                for name in ("k_upper", "k_lower", "v_upper", "v_lower"):
                    got = np.asarray(getattr(pool, name)[bt_ids])
                    want = np.asarray(getattr(hier, name)[0, :n_blocks])
                    # identical fp inputs up to attention reassociation;
                    # codes may differ only at rare rounding boundaries
                    mismatch = (got != want).mean()
                    assert mismatch < 5e-3, (name, mismatch)
                for name in ("k_scale", "k_zero", "v_scale", "v_zero"):
                    got = np.asarray(getattr(pool, name)[bt_ids])
                    want = np.asarray(getattr(hier, name)[0, :n_blocks])
                    np.testing.assert_allclose(got, want, atol=1e-5,
                                               rtol=1e-5, err_msg=name)
                buf_len = L - n_blocks * G
                for b, hb in (("buf_k", "buf_k"), ("buf_v", "buf_v")):
                    got = np.asarray(getattr(pool, b)[0, :buf_len])
                    want = np.asarray(getattr(hier, hb)[0, :buf_len])
                    np.testing.assert_allclose(got, want, atol=1e-5,
                                               rtol=1e-5)

    def test_greedy_continuation_identical(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        L = 2 * G + 9
        prompt = make_prompt(cfg, L, seed=4)
        max_seq = L + 16 + 2 * G + 8
        static = Engine(model, params, policy="quantspec", gamma=3,
                        greedy=True, max_seq=max_seq)
        want = static.generate(jnp.asarray(prompt)[None], 10,
                               key=jax.random.PRNGKey(7)).tokens[0]
        for C in (G // 2, L):                 # multi-chunk and one-shot
            ceng = ContinuousEngine(model, params, gamma=3, greedy=True,
                                    max_slots=1, max_seq=max_seq,
                                    prefill_chunk=C)
            (res,) = ceng.generate([prompt], 10, key=jax.random.PRNGKey(7))
            np.testing.assert_array_equal(res.tokens[0], want,
                                          err_msg=f"chunk={C}")


# ---------------------------------------------------------------------------
# decode-interleaved admission
# ---------------------------------------------------------------------------

class TestInterleavedAdmission:
    def test_decode_advances_while_admitting(self, tiny):
        """Admitting a long prompt must not freeze in-flight decodes: the
        active request keeps generating between prefill chunks."""
        cfg, model, params = tiny
        G = cfg.group_size
        long_len = 3 * G + 5
        max_seq = long_len + 2 * G + 72
        ceng = ContinuousEngine(model, params, gamma=2, greedy=True,
                                max_slots=2, max_seq=max_seq,
                                prefill_chunk=G // 2)
        a = ceng.submit(make_prompt(cfg, 17, seed=5), 64)
        key = ceng.step(jax.random.PRNGKey(0))     # admit + start decoding a
        b = ceng.submit(make_prompt(cfg, long_len, seed=6), 4)
        gen_before, chunks_seen = a.generated, []
        while ceng._prefilling is not None or b.prefill_chunks == 0:
            key = ceng.step(key)
            chunks_seen.append(b.prefill_chunks)
            assert len(chunks_seen) < 50
        assert b.prefill_chunks >= 7               # long prompt, 7+ chunks
        assert a.generated > gen_before            # a decoded throughout
        # at most one chunk advanced per engine iteration
        steps = np.diff([0] + chunks_seen)
        assert steps.max() <= 1
        ceng.run(key)

    def test_token_identity_with_interleaving(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        lens = [3 * G + 5, 2 * G + 3, 17]
        max_new = 8
        max_seq = max(lens) + max_new + 2 * G + 8
        prompts = [make_prompt(cfg, n, seed=10 + i)
                   for i, n in enumerate(lens)]
        static = []
        for p in prompts:
            eng = Engine(model, params, policy="quantspec", gamma=3,
                         greedy=True, max_seq=max_seq)
            static.append(eng.generate(jnp.asarray(p)[None], max_new,
                                       key=jax.random.PRNGKey(7)).tokens[0])
        ceng = ContinuousEngine(model, params, gamma=3, greedy=True,
                                max_slots=2, max_seq=max_seq,
                                prefill_chunk=G // 2)
        results = ceng.generate(prompts, max_new, key=jax.random.PRNGKey(7))
        for i, r in enumerate(results):
            np.testing.assert_array_equal(r.tokens[0], static[i],
                                          err_msg=f"request {i}")
        assert int(ceng.table.free_top) == ceng.pool_blocks

    def test_chunk_step_compiles_once_per_bucket(self, tiny):
        cfg, model, params = tiny
        G = cfg.group_size
        ceng = ContinuousEngine(model, params, gamma=2, greedy=True,
                                max_slots=1, max_seq=8 * G,
                                prefill_chunk=G)
        # lens spanning buckets {G, 2G, 3G}: 5 prompts, 3 buckets
        for i, L in enumerate([7, G - 1, G + 3, 2 * G, 3 * G - 5]):
            ceng.generate([make_prompt(cfg, L, seed=20 + i)], 2,
                          key=jax.random.PRNGKey(i))
        assert ceng._chunk_jit._cache_size() == 3
        assert ceng._finalize_jit._cache_size() == 3


# ---------------------------------------------------------------------------
# the dense intermediate is gone
# ---------------------------------------------------------------------------

class TestNoDenseIntermediate:
    def test_engine_has_no_adopt_path(self):
        src = inspect.getsource(engine_mod)
        assert "adopt_hier(" not in src          # no call site (docs may
        assert "_dense_prefill" not in src       # mention its removal)
        assert not hasattr(ContinuousEngine, "_adopt")

    def test_scratch_sized_to_bucket_not_max_seq(self, tiny):
        """Admission allocates only the transient chunk-bucket fp scratch —
        no max_seq-sized dense cache."""
        cfg, model, params = tiny
        G = cfg.group_size
        max_seq = 64 * G                      # deliberately huge
        L = 2 * G + 3
        C = G // 2
        ceng = ContinuousEngine(model, params, gamma=2, greedy=True,
                                max_slots=1, max_seq=max_seq,
                                prefill_chunk=C)
        req = ceng.submit(make_prompt(cfg, L, seed=30), 1)
        ceng._advance_prefill(jax.random.PRNGKey(0))   # one chunk in flight
        job = ceng._prefilling
        assert job is not None and job.chunk == 1
        bucket = -(-L // C) * C
        assert job.bucket == bucket
        S_scratch = job.scratch[0].k.shape[-3]
        assert S_scratch == bucket + 2 * G
        assert S_scratch < max_seq // 4
        ceng.run(jax.random.PRNGKey(0))
        assert req.generated == 1
        assert ceng._prefilling is None
