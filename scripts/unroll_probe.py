"""§Perf pair-C probe: per-layer cost of mistral long_500k decode when the
layer loop is unrolled (vs the while-loop scan), isolating the while-carry
copy overhead."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import sys
import time

sys.path.insert(0, "src")

from repro.distributed.sharding import axis_rules
from repro.launch import dryrun as D
from repro.launch.mesh import make_production_mesh


def main():
    mesh = make_production_mesh()
    out = {}
    with mesh, axis_rules(mesh, "long"):
        for n in (0, 4, 8):
            t0 = time.time()
            fn, args, cfg = D.build_step("mistral-large-123b", "long_500k",
                                         mesh, n_repeats=n)
            a = D._analyse(fn.lower(*args).compile(), False)
            out[n] = a
            print(f"unrolled n={n} flops={a['flops']:.3e} "
                  f"bytes={a['bytes_accessed']:.3e} ({time.time()-t0:.0f}s)",
                  flush=True)
    b8 = (out[8]["bytes_accessed"] - out[0]["bytes_accessed"]) / 8
    b4 = (out[4]["bytes_accessed"] - out[0]["bytes_accessed"]) / 4
    print(f"per-layer bytes unrolled: n=4 {b4:.3e}  n=8 {b8:.3e}")
    print(f"projected 88-layer unrolled total: "
          f"{out[0]['bytes_accessed'] + 88 * b8:.3e}")
    json.dump({str(k): v for k, v in out.items()},
              open("experiments/perf/mistral_long500k_unroll_probe.json", "w"),
              indent=1)


if __name__ == "__main__":
    main()
