"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json (between the <!-- X:BEGIN/END --> markers).

    PYTHONPATH=src python scripts/update_experiments.py
"""

import re
import sys

sys.path.insert(0, "src")

from benchmarks.roofline import load_results, roofline_terms  # noqa: E402


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | FLOPs/chip | bytes/chip | coll bytes | "
        "temp GB/chip | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("cfg_opts"):
            continue  # perf variants live in §Perf
        coll = sum(v for k, v in r["collectives"].items() if k != "count")
        temp = (r["memory"].get("temp_size_in_bytes") or 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['flops']:.2e} | {r['bytes_accessed']:.2e} | {coll:.2e} | "
            f"{temp:.2f} | {r['compile_s']:.0f} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/chip | useful |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != "16x16" or r.get("cfg_opts"):
            continue  # roofline table is single-pod baselines
        terms, dom, mf, useful = roofline_terms(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {terms['compute']:.2e} | "
            f"{terms['memory']:.2e} | {terms['collective']:.2e} | **{dom}** | "
            f"{mf:.2e} | {useful:.1%} |")
    return "\n".join(lines)


def splice(text, marker, content):
    begin, end = f"<!-- {marker}:BEGIN -->", f"<!-- {marker}:END -->"
    pattern = re.compile(re.escape(begin) + r".*?" + re.escape(end), re.S)
    return pattern.sub(begin + "\n" + content + "\n" + end, text)


def main():
    recs = load_results()
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = splice(text, "DRYRUN", dryrun_table(recs))
    text = splice(text, "ROOFLINE", roofline_table(recs))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print(f"updated EXPERIMENTS.md from {len(recs)} dry-run records")


if __name__ == "__main__":
    main()
