"""Shared benchmark utilities: the CPU-trained tiny model + eval data."""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticCorpus
from repro.models.stack import StackModel
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step

CKPT = "checkpoints/bench-tiny"
VOCAB = 64
BIGRAM_TEMP = 0.25
TRAIN_STEPS = 250
EVAL_SEQ = 256


def bench_config():
    return get_config("tiny-lm").replace(vocab_size=VOCAB, group_size=32)


def corpus():
    return SyntheticCorpus(VOCAB, seed=0, bigram_temp=BIGRAM_TEMP,
                           copy_prob=0.7, copy_len=48)


def get_trained_model(steps: int = TRAIN_STEPS, verbose: bool = True):
    """Train (or load) the benchmark model. Returns (cfg, model, params)."""
    cfg = bench_config()
    model = StackModel(cfg)
    params_t = model.init(jax.random.PRNGKey(0))
    if os.path.exists(os.path.join(CKPT, "params.npz")):
        params, step = load_checkpoint(CKPT, params_t)
        if verbose:
            print(f"[bench] loaded checkpoint ({step} steps)")
        return cfg, model, params
    opt = AdamW(lr=3e-3, warmup_steps=20, total_steps=steps)
    opt_state = opt.init(params_t)
    step_fn = jax.jit(make_train_step(model, opt))
    it = corpus().batches(batch=6, seq=256)
    params = params_t
    t0 = time.time()
    for i in range(steps):
        params, opt_state, m = step_fn(params, opt_state, next(it))
        if verbose and (i % 100 == 0 or i == steps - 1):
            print(f"[bench] train step {i}: loss={float(m['loss']):.3f}")
    save_checkpoint(CKPT, params, step=steps)
    if verbose:
        print(f"[bench] trained {steps} steps in {time.time()-t0:.0f}s, "
              f"saved to {CKPT}")
    return cfg, model, params


def eval_batches(n: int = 4, batch: int = 8, seq: int = EVAL_SEQ):
    """Held-out eval batches with copy-destination masks (the positions
    whose prediction depends on the *quantized* region of the cache)."""
    c = corpus()
    out = []
    for i in range(n):
        key = jax.random.fold_in(jax.random.PRNGKey(999), i)
        tokens, mask = c.sample_with_mask(key, batch, seq)
        out.append({"tokens": tokens, "copy_mask": mask})
    return out


def ce_with_kv_sim(model, params, batches, kv_sim):
    """(overall CE, copy-position CE) under simulated KV-cache quant."""
    @jax.jit
    def ce(params, tokens, mask):
        logits, _ = model.train_logits(params, tokens, kv_sim=kv_sim)
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]
        m = mask[:, 1:].astype(jnp.float32)
        return jnp.mean(nll), jnp.sum(nll * m) / jnp.maximum(m.sum(), 1)

    overall, copy = zip(*[
        (float(a), float(b)) for a, b in
        (ce(params, b["tokens"], b["copy_mask"]) for b in batches)])
    return float(np.mean(overall)), float(np.mean(copy))
