"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip-quality] [--skip-engine]

Emits human-readable tables per benchmark plus a final
``name,us_per_call,derived`` CSV block.
"""

from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-quality", action="store_true")
    ap.add_argument("--skip-engine", action="store_true")
    args = ap.parse_args()

    csv_rows = []  # (name, variant, derived)
    timings = {}
    prefix = {}

    def section(name, fn):
        print(f"\n{'='*72}\n## {name}\n{'='*72}")
        t0 = time.time()
        n_before = len(csv_rows)
        fn(csv_rows)
        timings[name] = time.time() - t0
        for row in csv_rows[n_before:]:
            prefix[row[0]] = name

    from benchmarks import arithmetic_intensity
    section("Table 1 / Fig 2 — arithmetic intensity (TPU v5e)",
            arithmetic_intensity.run)

    from benchmarks import kernel_bench
    section("Table 4 — quantized attention kernel", kernel_bench.run)

    if not args.skip_quality:
        from benchmarks import ppl_quality
        section("Table 2 & 5 — KV-quantization quality", ppl_quality.run)

    if not args.skip_engine:
        from benchmarks import acceptance_speedup
        section("Table 3, 6 / Fig 4, 9 — acceptance & speedup",
                acceptance_speedup.run)

    from benchmarks import roofline
    section("§Roofline — dry-run derived terms", roofline.run)

    print(f"\n{'='*72}\n## CSV (name,us_per_call,derived)\n{'='*72}")
    print("name,us_per_call,derived")
    for name, variant, derived in csv_rows:
        us = timings.get(prefix.get(name, ""), 0.0) * 1e6
        print(f"{name}.{variant},{us:.0f},{derived}")

    # kernel_bench wrote the perf-trajectory artifacts
    for artifact in ("BENCH_decode.json", "BENCH_prefill.json"):
        assert os.path.exists(artifact), \
            f"kernel_bench did not emit {artifact}"
        print(f"\nperf-trajectory artifact: {artifact} "
              f"({os.path.getsize(artifact)} bytes)")


if __name__ == "__main__":
    main()
