"""Paper Table 4: attention-kernel latency with the hierarchical quantized
KV cache vs FP16 FlashAttention.

Real wall-time needs a TPU; this container validates the kernels in
interpret mode and *projects* latency from bytes-moved (decode attention is
~60× below the v5e ridge point — see arithmetic_intensity.py — so latency ≈
bytes / 819 GB/s). CPU wall-clock of the jnp reference path is reported as
a relative-sanity column; the projected ratios are the reproduction of the
paper's 1.44×/2.88× claims (expected slightly higher here because scales
are the only overhead and TPU has no tail-quantization effects).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hier_kv_cache as HC
from repro.kernels import ops as kops
from repro.launch.mesh import HBM_BW
from repro.models import common as L

H, D, G = 32, 128, 128


def kv_bytes(S, mode):
    per_elem = {"fp16": 2.0, "int8": 1.0, "int4": 0.5}[mode]
    scale_bytes = 0.0
    if mode != "fp16":
        # k: D scales+zeros per block; v: G per block (fp32)
        per_block = (D + G) * 2 * 4.0
        scale_bytes = (S / G) * per_block * 2  # K and V
    return 2 * S * H * D * per_elem + scale_bytes


def projected_us(S, mode):
    return kv_bytes(S, mode) / HBM_BW * 1e6


def cpu_wall_us(S_small=2048, iters=3):
    """Relative CPU sanity: jnp attention over fp32-materialized cache
    (target mode) vs draft mode on a small S."""
    B, T = 1, 1
    cache = HC.init_cache(B, S_small // G + 2, G, H, D)
    k = jax.random.normal(jax.random.PRNGKey(0), (B, S_small, H, D))
    cache = HC.prefill(cache, k, k)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, D))
    out = {}
    for mode in ("draft", "target"):
        f = jax.jit(lambda q, c, m=mode: L.attend_hier(q, c, S_small, m))
        f(q, cache).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            f(q, cache).block_until_ready()
        out[mode] = (time.perf_counter() - t0) / iters * 1e6
    return out


def run(csv_rows):
    print("\n# Table 4 — attention kernel: projected TPU-v5e latency "
          "(bytes / 819 GB/s), B=1, 32 heads, head_dim 128")
    print(f"{'kernel':<24} {'64k':>12} {'256k':>12} {'512k':>12}")
    rows = {}
    for mode, label in (("fp16", "FlashAttention (FP16)"),
                        ("int8", "QuantSpec INT8 (target)"),
                        ("int4", "QuantSpec INT4 (draft)")):
        us = [projected_us(S, mode) for S in (65536, 262144, 524288)]
        rows[mode] = us
        ratios = "" if mode == "fp16" else \
            "  (" + "/".join(f"{rows['fp16'][i]/us[i]:.2f}x"
                             for i in range(3)) + ")"
        print(f"{label:<24} " + " ".join(f"{u:>9.1f}us" for u in us) + ratios)
        for S, u in zip((65536, 262144, 524288), us):
            csv_rows.append(("tab4_kernel", f"{mode}_S{S}", f"{u:.2f}"))

    print("\npaper Table 4 (A6000, measured): INT8 1.44-1.51x, INT4 2.86-2.88x")
    print(f"this repo (v5e, projected):      INT8 "
          f"{rows['fp16'][0]/rows['int8'][0]:.2f}x, INT4 "
          f"{rows['fp16'][0]/rows['int4'][0]:.2f}x")

    wall = cpu_wall_us()
    print(f"\nCPU sanity (jnp path, S=2048): draft {wall['draft']:.0f}us, "
          f"target {wall['target']:.0f}us")
    csv_rows.append(("tab4_cpu_sanity", "draft_vs_target",
                     f"{wall['draft']:.1f};{wall['target']:.1f}"))
    return csv_rows


if __name__ == "__main__":
    run([])
