"""Paper Table 4: attention-kernel latency with the hierarchical quantized
KV cache vs FP16 FlashAttention.

Real wall-time needs a TPU; this container validates the kernels in
interpret mode and *projects* latency from bytes-moved (decode attention is
~60× below the v5e ridge point — see arithmetic_intensity.py — so latency ≈
bytes / 819 GB/s). CPU wall-clock of the jnp reference path is reported as
a relative-sanity column; the projected ratios are the reproduction of the
paper's 1.44×/2.88× claims (expected slightly higher here because scales
are the only overhead and TPU has no tail-quantization effects).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hier_kv_cache as HC
from repro.core import weight_quant as WQ
from repro.kernels import ops as kops
from repro.kernels import quant_matmul as QM
from repro.launch.mesh import HBM_BW
from repro.models import common as L

H, D, G = 32, 128, 128


def kv_bytes(S, mode):
    per_elem = {"fp16": 2.0, "int8": 1.0, "int4": 0.5}[mode]
    scale_bytes = 0.0
    if mode != "fp16":
        # k: D scales+zeros per block; v: G per block (fp32)
        per_block = (D + G) * 2 * 4.0
        scale_bytes = (S / G) * per_block * 2  # K and V
    return 2 * S * H * D * per_elem + scale_bytes


def projected_us(S, mode):
    return kv_bytes(S, mode) / HBM_BW * 1e6


def cpu_wall_us(S_small=2048, iters=3):
    """Relative CPU sanity: jnp attention over fp32-materialized cache
    (target mode) vs draft mode on a small S."""
    B, T = 1, 1
    cache = HC.init_cache(B, S_small // G + 2, G, H, D)
    k = jax.random.normal(jax.random.PRNGKey(0), (B, S_small, H, D))
    cache = HC.prefill(cache, k, k)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, D))
    out = {}
    for mode in ("draft", "target"):
        f = jax.jit(lambda q, c, m=mode: L.attend_hier(q, c, S_small, m))
        f(q, cache).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            f(q, cache).block_until_ready()
        out[mode] = (time.perf_counter() - t0) / iters * 1e6
    return out


# ---------------------------------------------------------------------------
# BENCH_decode.json — the decode hot path's perf trajectory (started by the
# fused-kernel PR). Decode attention AND the draft matmul are ~60× below the
# v5e ridge point, so projected rates are bytes-bound (bytes / 819 GB/s);
# measured CPU columns are relative sanity only.
# ---------------------------------------------------------------------------

def weight_matmul_bytes(K, N, group=128, kind="fp16"):
    """HBM bytes one decode token streams for a [K, N] weight."""
    scales = 2 * 4.0 * (K // group) * N          # fp32 scale + zero
    if kind == "fp16":
        return 2.0 * K * N
    if kind == "fused_int4":                      # packed plane + scales only
        return 0.5 * K * N + scales
    if kind == "dequant_int4":                    # + fp32 round-trip when the
        return 0.5 * K * N + scales + 8.0 * K * N  # dequant materializes
    raise ValueError(kind)


def matmul_cpu_wall_us(M=4, K=2048, N=2048, iters=5):
    """Relative CPU sanity: jit'd dequant+dot vs fp32 dot."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N))
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, K))
    q = WQ.quantize_weight(w)
    out = {}
    for name, f in (("dequant_dot", jax.jit(lambda x, q=q: x @ q.dequant())),
                    ("fp32_dot", jax.jit(lambda x, w=w: x @ w))):
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            f(x).block_until_ready()
        out[name] = (time.perf_counter() - t0) / iters * 1e6
    return out


def fused_parity_max_err(M=2, K=256, N=128, group=128):
    """Interpret-mode fused kernel vs dequant()@x — the number the parity
    tests bound (documents that the fast path is the same math)."""
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, K))
    q = WQ.quantize_weight(w, group=group)
    got = QM.int4_matmul(x, q.packed, q.scale, q.zero)
    ref = x @ q.dequant()
    return float(jnp.max(jnp.abs(got - ref)))


def decode_metrics(smoke: bool = False) -> dict:
    """The BENCH_decode.json payload: HBM bytes/token + projected tokens/s
    for the three attention precisions and the three matmul paths, plus a
    whole-decode projection for a 7B-class model."""
    Ss = (4096,) if smoke else (65536, 262144, 524288)
    attention = {}
    for S in Ss:
        row = {}
        for mode, kind in (("fp16", "fp16"), ("int8_target", "int8"),
                           ("int4_draft", "int4")):
            b = kv_bytes(S, kind)
            row[mode] = {"bytes_per_token": b,
                         "proj_tokens_per_s": HBM_BW / b}
        for mode in ("int8_target", "int4_draft"):
            row[mode]["speedup_vs_fp16"] = (row["fp16"]["bytes_per_token"]
                                            / row[mode]["bytes_per_token"])
        # single-pass saving vs the old two-pass path: second out+lse write,
        # re-read of both partial outputs for the merge, and the
        # materialized [B·H, gT, 2G] FP-buffer mask
        BH, gT = H, 1
        two_pass_extra = (BH * gT * (D + 1) * 4        # buffer-pass out + lse
                          + 3 * BH * gT * D * 4        # LSE-merge traffic
                          + BH * gT * 2 * G)           # bool mask
        row["single_pass_saved_bytes_per_token"] = float(two_pass_extra)
        attention[f"S={S}"] = row

    K = N = 1024 if smoke else 4096
    matmul = {
        "shape": {"d_in": K, "d_out": N, "group": 128},
        "bytes_per_token": {
            kind: weight_matmul_bytes(K, N, kind=kind)
            for kind in ("fp16", "fused_int4", "dequant_int4")},
        "interpret_parity_max_err": fused_parity_max_err(),
    }
    bpt = matmul["bytes_per_token"]
    matmul["proj_speedup"] = {
        "fused_vs_fp16": bpt["fp16"] / bpt["fused_int4"],
        "fused_vs_dequant": bpt["dequant_int4"] / bpt["fused_int4"],
    }
    if not smoke:
        matmul["measured_cpu_us"] = matmul_cpu_wall_us()

    # whole-decode projection (7B-class, weights + KV both streamed/token)
    n_params = 7e9
    S_ref = Ss[0]
    decode = {}
    for name, wb, kv in (
            ("fp16_baseline", 2.0 * n_params, kv_bytes(S_ref, "fp16")),
            ("draft_int4", (0.5 + 8.0 / 128) * n_params,
             kv_bytes(S_ref, "int4")),
            ("target_verify", 2.0 * n_params, kv_bytes(S_ref, "int8"))):
        b = wb + 32 * kv                     # 32 layers' attention
        decode[name] = {"bytes_per_token": b, "proj_tokens_per_s": HBM_BW / b}
    decode["meta"] = {"n_params": n_params, "layers": 32, "S": S_ref,
                      "note": "int4 weight bytes include 1/16 group-scale "
                              "overhead (fp32 scale+zero per 128-group)"}

    return {
        "meta": {"H": H, "D": D, "G": G, "hbm_bw_bytes_per_s": HBM_BW,
                 "smoke": smoke, "source": "benchmarks/kernel_bench.py "
                 "(projection: decode is bandwidth-bound, see "
                 "arithmetic_intensity.py)"},
        "attention": attention,
        "matmul": matmul,
        "decode_projection": decode,
    }


def write_decode_json(path: str, smoke: bool = False) -> dict:
    m = decode_metrics(smoke=smoke)
    with open(path, "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"\nwrote {path} (attention {len(m['attention'])} context sizes, "
          f"fused-vs-fp16 matmul {m['matmul']['proj_speedup']['fused_vs_fp16']:.2f}x, "
          f"parity max err {m['matmul']['interpret_parity_max_err']:.1e})")
    return m


def run(csv_rows, json_path="BENCH_decode.json"):
    print("\n# Table 4 — attention kernel: projected TPU-v5e latency "
          "(bytes / 819 GB/s), B=1, 32 heads, head_dim 128")
    print(f"{'kernel':<24} {'64k':>12} {'256k':>12} {'512k':>12}")
    rows = {}
    for mode, label in (("fp16", "FlashAttention (FP16)"),
                        ("int8", "QuantSpec INT8 (target)"),
                        ("int4", "QuantSpec INT4 (draft)")):
        us = [projected_us(S, mode) for S in (65536, 262144, 524288)]
        rows[mode] = us
        ratios = "" if mode == "fp16" else \
            "  (" + "/".join(f"{rows['fp16'][i]/us[i]:.2f}x"
                             for i in range(3)) + ")"
        print(f"{label:<24} " + " ".join(f"{u:>9.1f}us" for u in us) + ratios)
        for S, u in zip((65536, 262144, 524288), us):
            csv_rows.append(("tab4_kernel", f"{mode}_S{S}", f"{u:.2f}"))

    print("\npaper Table 4 (A6000, measured): INT8 1.44-1.51x, INT4 2.86-2.88x")
    print(f"this repo (v5e, projected):      INT8 "
          f"{rows['fp16'][0]/rows['int8'][0]:.2f}x, INT4 "
          f"{rows['fp16'][0]/rows['int4'][0]:.2f}x")

    wall = cpu_wall_us()
    print(f"\nCPU sanity (jnp path, S=2048): draft {wall['draft']:.0f}us, "
          f"target {wall['target']:.0f}us")
    csv_rows.append(("tab4_cpu_sanity", "draft_vs_target",
                     f"{wall['draft']:.1f};{wall['target']:.1f}"))

    # ---- decode hot path (fused matmul + single-pass attention) ------------
    m = write_decode_json(json_path)
    bpt = m["matmul"]["bytes_per_token"]
    print(f"\n# decode matmul (d={m['matmul']['shape']['d_in']}): "
          f"HBM bytes/token fp16 {bpt['fp16']/1e6:.1f}MB, fused INT4 "
          f"{bpt['fused_int4']/1e6:.1f}MB "
          f"({m['matmul']['proj_speedup']['fused_vs_fp16']:.2f}x), "
          f"unfused dequant {bpt['dequant_int4']/1e6:.1f}MB")
    for kind in ("fp16", "fused_int4", "dequant_int4"):
        csv_rows.append(("decode_matmul", kind, f"{bpt[kind]:.0f}"))
    for name, row in m["decode_projection"].items():
        if name != "meta":
            csv_rows.append(("decode_proj", name,
                             f"{row['proj_tokens_per_s']:.1f}"))
    return csv_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_decode.json",
                    help="where to write the decode-hot-path metrics")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + skip CPU wall timing (CI)")
    args = ap.parse_args()
    if args.smoke:
        write_decode_json(args.json, smoke=True)
    else:
        run([], json_path=args.json)


if __name__ == "__main__":
    main()
