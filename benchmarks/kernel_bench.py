"""Paper Table 4: attention-kernel latency with the hierarchical quantized
KV cache vs FP16 FlashAttention.

Real wall-time needs a TPU; this container validates the kernels in
interpret mode and *projects* latency from bytes-moved (decode attention is
~60× below the v5e ridge point — see arithmetic_intensity.py — so latency ≈
bytes / 819 GB/s). CPU wall-clock of the jnp reference path is reported as
a relative-sanity column; the projected ratios are the reproduction of the
paper's 1.44×/2.88× claims (expected slightly higher here because scales
are the only overhead and TPU has no tail-quantization effects).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import hier_kv_cache as HC
from repro.core import weight_quant as WQ
from repro.kernels import quant_matmul as QM
from repro.launch.mesh import HBM_BW
from repro.models import common as L

H, D, G = 32, 128, 128


def kv_bytes(S, mode):
    per_elem = {"fp16": 2.0, "int8": 1.0, "int4": 0.5}[mode]
    scale_bytes = 0.0
    if mode != "fp16":
        # k: D scales+zeros per block; v: G per block (fp32)
        per_block = (D + G) * 2 * 4.0
        scale_bytes = (S / G) * per_block * 2  # K and V
    return 2 * S * H * D * per_elem + scale_bytes


def projected_us(S, mode):
    return kv_bytes(S, mode) / HBM_BW * 1e6


def cpu_wall_us(S_small=2048, iters=3):
    """Relative CPU sanity: jnp attention over fp32-materialized cache
    (target mode) vs draft mode on a small S."""
    B, T = 1, 1
    cache = HC.init_cache(B, S_small // G + 2, G, H, D)
    k = jax.random.normal(jax.random.PRNGKey(0), (B, S_small, H, D))
    cache = HC.prefill(cache, k, k)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, D))
    out = {}
    for mode in ("draft", "target"):
        f = jax.jit(lambda q, c, m=mode: L.attend_hier(q, c, S_small, m))
        f(q, cache).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            f(q, cache).block_until_ready()
        out[mode] = (time.perf_counter() - t0) / iters * 1e6
    return out


# ---------------------------------------------------------------------------
# BENCH_decode.json — the decode hot path's perf trajectory (started by the
# fused-kernel PR). Decode attention AND the draft matmul are ~60× below the
# v5e ridge point, so projected rates are bytes-bound (bytes / 819 GB/s);
# measured CPU columns are relative sanity only.
# ---------------------------------------------------------------------------

def weight_matmul_bytes(K, N, group=128, kind="fp16"):
    """HBM bytes one decode token streams for a [K, N] weight."""
    scales = 2 * 4.0 * (K // group) * N          # fp32 scale + zero
    if kind == "fp16":
        return 2.0 * K * N
    if kind == "fused_int4":                      # packed plane + scales only
        return 0.5 * K * N + scales
    if kind == "dequant_int4":                    # + fp32 round-trip when the
        return 0.5 * K * N + scales + 8.0 * K * N  # dequant materializes
    raise ValueError(kind)


def matmul_cpu_wall_us(M=4, K=2048, N=2048, iters=5):
    """Relative CPU sanity: jit'd dequant+dot vs fp32 dot."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N))
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, K))
    q = WQ.quantize_weight(w)
    out = {}
    for name, f in (("dequant_dot", jax.jit(lambda x, q=q: x @ q.dequant())),
                    ("fp32_dot", jax.jit(lambda x, w=w: x @ w))):
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            f(x).block_until_ready()
        out[name] = (time.perf_counter() - t0) / iters * 1e6
    return out


def fused_parity_max_err(M=2, K=256, N=128, group=128):
    """Interpret-mode fused kernel vs dequant()@x — the number the parity
    tests bound (documents that the fast path is the same math)."""
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (K, N)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, K))
    q = WQ.quantize_weight(w, group=group)
    got = QM.int4_matmul(x, q.packed, q.scale, q.zero)
    ref = x @ q.dequant()
    return float(jnp.max(jnp.abs(got - ref)))


def decode_metrics(smoke: bool = False) -> dict:
    """The BENCH_decode.json payload: HBM bytes/token + projected tokens/s
    for the three attention precisions and the three matmul paths, plus a
    whole-decode projection for a 7B-class model."""
    Ss = (4096,) if smoke else (65536, 262144, 524288)
    attention = {}
    for S in Ss:
        row = {}
        for mode, kind in (("fp16", "fp16"), ("int8_target", "int8"),
                           ("int4_draft", "int4")):
            b = kv_bytes(S, kind)
            row[mode] = {"bytes_per_token": b,
                         "proj_tokens_per_s": HBM_BW / b}
        for mode in ("int8_target", "int4_draft"):
            row[mode]["speedup_vs_fp16"] = (row["fp16"]["bytes_per_token"]
                                            / row[mode]["bytes_per_token"])
        # single-pass saving vs the old two-pass path: second out+lse write,
        # re-read of both partial outputs for the merge, and the
        # materialized [B·H, gT, 2G] FP-buffer mask
        BH, gT = H, 1
        two_pass_extra = (BH * gT * (D + 1) * 4        # buffer-pass out + lse
                          + 3 * BH * gT * D * 4        # LSE-merge traffic
                          + BH * gT * 2 * G)           # bool mask
        row["single_pass_saved_bytes_per_token"] = float(two_pass_extra)
        attention[f"S={S}"] = row

    K = N = 1024 if smoke else 4096
    matmul = {
        "shape": {"d_in": K, "d_out": N, "group": 128},
        "bytes_per_token": {
            kind: weight_matmul_bytes(K, N, kind=kind)
            for kind in ("fp16", "fused_int4", "dequant_int4")},
        "interpret_parity_max_err": fused_parity_max_err(),
    }
    bpt = matmul["bytes_per_token"]
    matmul["proj_speedup"] = {
        "fused_vs_fp16": bpt["fp16"] / bpt["fused_int4"],
        "fused_vs_dequant": bpt["dequant_int4"] / bpt["fused_int4"],
    }
    if not smoke:
        matmul["measured_cpu_us"] = matmul_cpu_wall_us()

    # whole-decode projection (7B-class, weights + KV both streamed/token)
    n_params = 7e9
    S_ref = Ss[0]
    decode = {}
    for name, wb, kv in (
            ("fp16_baseline", 2.0 * n_params, kv_bytes(S_ref, "fp16")),
            ("draft_int4", (0.5 + 8.0 / 128) * n_params,
             kv_bytes(S_ref, "int4")),
            ("target_verify", 2.0 * n_params, kv_bytes(S_ref, "int8"))):
        b = wb + 32 * kv                     # 32 layers' attention
        decode[name] = {"bytes_per_token": b, "proj_tokens_per_s": HBM_BW / b}
    decode["meta"] = {"n_params": n_params, "layers": 32, "S": S_ref,
                      "note": "int4 weight bytes include 1/16 group-scale "
                              "overhead (fp32 scale+zero per 128-group)"}

    return {
        "meta": {"H": H, "D": D, "G": G, "hbm_bw_bytes_per_s": HBM_BW,
                 "smoke": smoke, "source": "benchmarks/kernel_bench.py "
                 "(projection: decode is bandwidth-bound, see "
                 "arithmetic_intensity.py)"},
        "attention": attention,
        "matmul": matmul,
        "decode_projection": decode,
    }


# ---------------------------------------------------------------------------
# BENCH_prefill.json — the prefill pipeline's perf trajectory (started by
# the chunked-prefill PR).  Prefill attention is compute-bound, but the jnp
# path ALSO materializes per-chunk [B, Hkv, g, Tc, S] logits/probs in HBM —
# bytes the flash kernel never moves; and the old continuous-engine
# admission allocated a dense max_seq HierKVCache and copied it into the
# pool (adopt_hier), traffic the direct-to-pool chunk pipeline eliminates.
# Compile counts are measured for real on a tiny ragged prompt sweep.
# ---------------------------------------------------------------------------

Q_CHUNK = 512          # jnp path's query-chunk (models/common.py)
QB_FLASH = 128         # flash kernel query block


def hier_cache_bytes(S, layers=1):
    """Dense hierarchical-cache footprint for S tokens (one layer unless
    ``layers``): 4 nibble planes + per-block scales/zeros + fp32 buffer."""
    nb = S // G
    planes = 4 * S * H * (D // 2)                      # k/v upper+lower
    scales = nb * 2 * 4.0 * (H * D + G * H)            # k [1,H,D], v [G,H,1]
    buf = 2 * 2 * G * H * D * 4.0                      # k+v double buffer
    return layers * (planes + scales + buf)


def prefill_attn_flops(S):
    """Causal-triangle attention FLOPs for one layer (QKᵀ + PV)."""
    return 2 * 2 * H * D * S * (S + 1) / 2


def jnp_prefill_logit_bytes(S):
    """HBM traffic of the materialized softmax intermediates on the jnp
    path: per query chunk ending at ``end``, logits + probs [Hq, Tc, end]
    f32, each written once and read once."""
    total = 0.0
    for start in range(0, S, Q_CHUNK):
        end = min(start + Q_CHUNK, S)
        total += (end - start) * end
    return 4.0 * H * total * 4.0          # 2 arrays × (write + read)


def flash_prefill_bytes(S):
    """Flash kernel HBM traffic: q + out once, k/v re-streamed once per
    query block (no materialized logits)."""
    qo = 2 * S * H * D * 4.0
    nq = -(-S // QB_FLASH)
    kv = 2 * S * H * D * 4.0 * nq
    return qo + kv


def compile_count_sweep(smoke: bool = False) -> dict:
    """Measured compile counts over a ragged prompt sweep (tiny-lm on this
    backend): the bucketed static prefill and the chunked continuous
    admission must each compile once per chunk bucket, not once per
    prompt length."""
    import jax

    from repro.configs import get_config
    from repro.models.stack import StackModel
    from repro.serving.engine import ContinuousEngine, Engine

    cfg = get_config("tiny-lm", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    Gt = cfg.group_size

    static_lens = [5, 20, 33, 50] if smoke else [5, 20, 33, 50, 64, 90, 117]
    eng = Engine(model, params, policy="quantspec", gamma=2, greedy=True,
                 max_seq=8 * Gt, prefill_chunk=32)
    for i, L in enumerate(static_lens):
        p = jax.random.randint(jax.random.PRNGKey(i), (1, L), 0,
                               cfg.vocab_size)
        eng.generate(p, 1, key=jax.random.PRNGKey(i))
    static_buckets = len({-(-L // 32) for L in static_lens})

    cont_lens = [10, 15, 40] if smoke else [10, 15, 40, 44, 70]
    ceng = ContinuousEngine(model, params, gamma=2, greedy=True,
                            max_slots=1, max_seq=8 * Gt, prefill_chunk=16)
    for i, L in enumerate(cont_lens):
        p = jax.random.randint(jax.random.PRNGKey(100 + i), (L,), 0,
                               cfg.vocab_size)
        ceng.generate([p], 1, key=jax.random.PRNGKey(i))
    cont_buckets = len({-(-L // 16) for L in cont_lens})

    return {
        "static": {"prompts": len(static_lens), "buckets": static_buckets,
                   "prefill_compiles": eng.prefill_compiles()},
        "continuous": {"prompts": len(cont_lens), "buckets": cont_buckets,
                       "chunk_compiles": ceng._chunk_jit._cache_size(),
                       "finalize_compiles": ceng._finalize_jit._cache_size()},
    }


def prefill_metrics(smoke: bool = False) -> dict:
    """The BENCH_prefill.json payload: flash-vs-jnp prefill traffic/FLOPs
    over a prompt sweep, the admission bytes the direct-to-pool pipeline
    eliminates, and measured compile counts across a ragged sweep."""
    Ss = (4096,) if smoke else (32768, 131072, 524288)
    attention = {}
    for S in Ss:
        jnp_extra = jnp_prefill_logit_bytes(S)
        attention[f"S={S}"] = {
            "flops": prefill_attn_flops(S),
            "jnp_materialized_logit_bytes": jnp_extra,
            "flash_bytes": flash_prefill_bytes(S),
            "jnp_bytes": flash_prefill_bytes(S) + jnp_extra,
            "logit_traffic_eliminated_ratio":
                jnp_extra / flash_prefill_bytes(S),
        }

    # admission: dense max_seq cache + adopt copy vs chunked direct-to-pool
    L = Ss[0]
    max_seq = 2 * L
    dense_alloc = hier_cache_bytes(max_seq)
    copy_traffic = 2 * hier_cache_bytes(L)       # read dense + write pool
    scratch = 2 * L * H * D * 4.0                # transient fp k+v, 1 layer
    admission = {
        "prompt": L, "max_seq": max_seq,
        "dense_cache_bytes_eliminated": dense_alloc,
        "adopt_copy_bytes_eliminated": copy_traffic,
        "transient_scratch_bytes": scratch,
        "note": "per layer; the dense intermediate was allocated at "
                "max_seq and fully copied into the pool by adopt_hier — "
                "the chunk pipeline writes pool blocks directly and keeps "
                "only a prompt-bucket fp scratch for the admission's "
                "duration (the scratch is fp-precision so its bytes can "
                "exceed the quantized planes; the win is that it is "
                "transient, bucket-sized rather than max_seq-sized, and "
                "the copy traffic disappears entirely)",
    }

    return {
        "meta": {"H": H, "D": D, "G": G, "q_chunk": Q_CHUNK,
                 "qb_flash": QB_FLASH, "smoke": smoke,
                 "source": "benchmarks/kernel_bench.py"},
        "attention": attention,
        "admission": admission,
        "compile_counts": compile_count_sweep(smoke=smoke),
    }


def write_prefill_json(path: str, smoke: bool = False) -> dict:
    m = prefill_metrics(smoke=smoke)
    with open(path, "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
        f.write("\n")
    cc = m["compile_counts"]
    first = next(iter(m["attention"].values()))
    print(f"\nwrote {path} (logit-traffic eliminated "
          f"{first['logit_traffic_eliminated_ratio']:.1f}x of flash bytes; "
          f"static compiles {cc['static']['prefill_compiles']}/"
          f"{cc['static']['buckets']} buckets, continuous "
          f"{cc['continuous']['chunk_compiles']}/"
          f"{cc['continuous']['buckets']})")
    return m


def write_decode_json(path: str, smoke: bool = False) -> dict:
    m = decode_metrics(smoke=smoke)
    with open(path, "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"\nwrote {path} (attention {len(m['attention'])} context sizes, "
          f"fused-vs-fp16 matmul {m['matmul']['proj_speedup']['fused_vs_fp16']:.2f}x, "
          f"parity max err {m['matmul']['interpret_parity_max_err']:.1e})")
    return m


def run(csv_rows, json_path="BENCH_decode.json",
        prefill_json_path="BENCH_prefill.json"):
    print("\n# Table 4 — attention kernel: projected TPU-v5e latency "
          "(bytes / 819 GB/s), B=1, 32 heads, head_dim 128")
    print(f"{'kernel':<24} {'64k':>12} {'256k':>12} {'512k':>12}")
    rows = {}
    for mode, label in (("fp16", "FlashAttention (FP16)"),
                        ("int8", "QuantSpec INT8 (target)"),
                        ("int4", "QuantSpec INT4 (draft)")):
        us = [projected_us(S, mode) for S in (65536, 262144, 524288)]
        rows[mode] = us
        ratios = "" if mode == "fp16" else \
            "  (" + "/".join(f"{rows['fp16'][i]/us[i]:.2f}x"
                             for i in range(3)) + ")"
        print(f"{label:<24} " + " ".join(f"{u:>9.1f}us" for u in us) + ratios)
        for S, u in zip((65536, 262144, 524288), us):
            csv_rows.append(("tab4_kernel", f"{mode}_S{S}", f"{u:.2f}"))

    print("\npaper Table 4 (A6000, measured): INT8 1.44-1.51x, INT4 2.86-2.88x")
    print(f"this repo (v5e, projected):      INT8 "
          f"{rows['fp16'][0]/rows['int8'][0]:.2f}x, INT4 "
          f"{rows['fp16'][0]/rows['int4'][0]:.2f}x")

    wall = cpu_wall_us()
    print(f"\nCPU sanity (jnp path, S=2048): draft {wall['draft']:.0f}us, "
          f"target {wall['target']:.0f}us")
    csv_rows.append(("tab4_cpu_sanity", "draft_vs_target",
                     f"{wall['draft']:.1f};{wall['target']:.1f}"))

    # ---- decode hot path (fused matmul + single-pass attention) ------------
    m = write_decode_json(json_path)
    bpt = m["matmul"]["bytes_per_token"]
    print(f"\n# decode matmul (d={m['matmul']['shape']['d_in']}): "
          f"HBM bytes/token fp16 {bpt['fp16']/1e6:.1f}MB, fused INT4 "
          f"{bpt['fused_int4']/1e6:.1f}MB "
          f"({m['matmul']['proj_speedup']['fused_vs_fp16']:.2f}x), "
          f"unfused dequant {bpt['dequant_int4']/1e6:.1f}MB")
    for kind in ("fp16", "fused_int4", "dequant_int4"):
        csv_rows.append(("decode_matmul", kind, f"{bpt[kind]:.0f}"))
    for name, row in m["decode_projection"].items():
        if name != "meta":
            csv_rows.append(("decode_proj", name,
                             f"{row['proj_tokens_per_s']:.1f}"))

    # ---- prefill pipeline (flash-prefill + chunked admission) --------------
    mp = write_prefill_json(prefill_json_path)
    for S, row in mp["attention"].items():
        csv_rows.append(("prefill_attn", S,
                         f"{row['logit_traffic_eliminated_ratio']:.2f}"))
    adm = mp["admission"]
    csv_rows.append(("prefill_admission", "dense_bytes_eliminated",
                     f"{adm['dense_cache_bytes_eliminated']:.0f}"))
    cc = mp["compile_counts"]
    csv_rows.append(("prefill_compiles", "static",
                     f"{cc['static']['prefill_compiles']};"
                     f"{cc['static']['buckets']}"))
    return csv_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_decode.json",
                    help="where to write the decode-hot-path metrics")
    ap.add_argument("--prefill-json", default="BENCH_prefill.json",
                    help="where to write the prefill-pipeline metrics")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + skip CPU wall timing (CI)")
    args = ap.parse_args()
    if args.smoke:
        write_decode_json(args.json, smoke=True)
        m = write_prefill_json(args.prefill_json, smoke=True)
        cc = m["compile_counts"]
        assert cc["static"]["prefill_compiles"] == cc["static"]["buckets"], cc
        assert cc["continuous"]["chunk_compiles"] == \
            cc["continuous"]["buckets"], cc
    else:
        run([], json_path=args.json, prefill_json_path=args.prefill_json)


if __name__ == "__main__":
    main()
