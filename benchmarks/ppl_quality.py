"""Paper Table 2 (FP16 vs INT8-KV perplexity) and Table 5 (quantization-axis
ablation), at CPU scale: the benchmark model is trained on the synthetic
corpus (local bigram + long-range copy), then evaluated with the KV cache
quantize-dequantized exactly as the hierarchical cache stores it.

Two CE columns: overall, and restricted to copy-destination positions —
predictions that *require reading the quantized region* (the local bigram
part is predictable from the FP buffer alone, diluting any cache-fidelity
effect; the copy positions isolate it, mirroring why the paper evaluates on
long-context summarization).

Expected replication of the paper's claims:
  * INT8 (both planes) ≈ FP16 perplexity        (Table 2)
  * INT4 (upper plane) slightly worse            (draft-quality gap)
  * key/value quantization-axis ordering          (Table 5)
"""

from __future__ import annotations

import math

from benchmarks.common import ce_with_kv_sim, eval_batches, get_trained_model

RESIDUAL = 64  # FP-buffer tokens (2G with the bench G=32)


def run(csv_rows):
    cfg, model, params = get_trained_model()
    batches = eval_batches()

    # ---- Table 2: precision sweep -------------------------------------------
    print("\n# Table 2 — perplexity vs KV-cache precision "
          "(key=channel, value=token, G=%d, R=%d)" % (cfg.group_size, RESIDUAL))
    print(f"{'cache':<26} {'CE':>9} {'ppl':>9} {'copy-CE':>9} {'copy-ppl':>9}")
    results = {}
    for name, bits in (("FP16", 16), ("INT8 (QuantSpec target)", 8),
                       ("INT4 (QuantSpec draft)", 4)):
        ce, cce = ce_with_kv_sim(model, params, batches,
                                 ("channel", "token", bits, RESIDUAL))
        results[bits] = (ce, cce)
        print(f"{name:<26} {ce:>9.4f} {math.exp(ce):>9.4f} "
              f"{cce:>9.4f} {math.exp(cce):>9.4f}")
        csv_rows.append(("tab2_ppl", f"kv_{bits}bit",
                         f"ppl={math.exp(ce):.4f};copy_ppl={math.exp(cce):.4f}"))

    gap8 = results[8][1] - results[16][1]
    gap4 = results[4][1] - results[16][1]
    print(f"copy-CE gaps vs FP16 — INT8: {gap8:+.5f}  INT4: {gap4:+.5f} "
          f"(paper Tab2: INT8 ~= FP16; draft plane pays a small gap)")
    csv_rows.append(("tab2_gap", "copy_ce_int8_int4",
                     f"{gap8:+.5f};{gap4:+.5f}"))

    # ---- Table 5: quantization-axis ablation (INT4) --------------------------
    print("\n# Table 5 — INT4 quant-axis ablation (copy-CE; lower is better)")
    print(f"{'key axis':<10} {'value axis':<11} {'CE':>9} {'copy-CE':>9}")
    table5 = {}
    for k_axis in ("channel", "token"):
        for v_axis in ("channel", "token"):
            ce, cce = ce_with_kv_sim(model, params, batches,
                                     (k_axis, v_axis, 4, RESIDUAL))
            table5[(k_axis, v_axis)] = cce
            print(f"{k_axis:<10} {v_axis:<11} {ce:>9.4f} {cce:>9.4f}")
            csv_rows.append(("tab5_axis", f"k_{k_axis}__v_{v_axis}",
                             f"{cce:.4f}"))
    best = min(table5, key=table5.get)
    print(f"best combo: key={best[0]}, value={best[1]} "
          f"(paper: key=channel, value=token)")
    csv_rows.append(("tab5_best", f"k_{best[0]}__v_{best[1]}", "1"))
    return csv_rows


if __name__ == "__main__":
    run([])
