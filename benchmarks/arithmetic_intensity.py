"""Paper Table 1 / Figure 2: arithmetic-intensity analysis of LLM inference,
re-derived for TPU v5e (the paper used an A6000).

Computes exact FLOPs/MOPs for the linear and attention components of prefill
and decode over a (batch × context-length) grid, classifies each regime
against the v5e ridge point, and reports where weight vs KV-cache
quantization pays — the analysis that motivates QuantSpec §3.1.
"""

from __future__ import annotations

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

RIDGE = PEAK_FLOPS_BF16 / HBM_BW  # ≈ 240 FLOP/byte on v5e


def _model_dims(cfg):
    d = cfg.d_model
    # per-layer linear params (weights loaded per step)
    lin = 0
    for spec in cfg.layers:
        lin += d * cfg.hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
        lin += cfg.num_heads * cfg.hd * d
        lin += 3 * d * cfg.d_ff
    lin += 2 * cfg.vocab_size * d
    return d, lin


def intensity(cfg, B, S, kind, *, dtype_bytes=2, kv_bits=16, w_bits=16,
              gen=1):
    """Returns dict with linear/attention/aggregate FLOPs, MOPs, AI."""
    d, lin_params = _model_dims(cfg)
    L = cfg.num_layers
    kvd = cfg.num_kv_heads * cfg.hd

    if kind == "prefill":
        flops_lin = 2 * B * S * lin_params
        mops_lin = dtype_bytes * (B * S * d * L * 2
                                  + lin_params * (w_bits / 16))
        flops_att = 2 * 2 * B * S * S / 2 * cfg.num_heads * cfg.hd * L
        # flash-attention: scores never materialized
        mops_att = dtype_bytes * (B * S * (cfg.num_heads + 2 * cfg.num_kv_heads)
                                  * cfg.hd * L) + B * S * L
    else:  # decode: generate `gen` tokens
        flops_lin = 2 * gen * B * lin_params
        mops_lin = gen * (dtype_bytes * B * d * L * 2
                          + lin_params * 2 * (w_bits / 16))
        flops_att = 2 * 2 * gen * B * S * cfg.num_heads * cfg.hd * L
        mops_att = gen * (2 * B * S * kvd * L * (kv_bits / 8)
                          + dtype_bytes * B * d * L) + gen * B * S * L

    out = {
        "linear": (flops_lin, mops_lin, flops_lin / mops_lin),
        "attention": (flops_att, mops_att, flops_att / mops_att),
    }
    fa, ma = flops_lin + flops_att, mops_lin + mops_att
    out["aggregate"] = (fa, ma, fa / ma)
    out["attention_latency_frac"] = (mops_att / HBM_BW) / (
        mops_att / HBM_BW + max(mops_lin / HBM_BW, flops_lin / PEAK_FLOPS_BF16))
    return out


def run(csv_rows):
    cfg = get_config("llama2-7b-32k")
    print(f"# TPU v5e ridge point: {RIDGE:.0f} FLOP/byte "
          f"(197 TFLOP/s bf16, 819 GB/s HBM)")
    print(f"{'phase':<8} {'B':>4} {'S':>7} {'AI_lin':>9} {'AI_att':>8} "
          f"{'AI_agg':>8} {'bound':>8} {'att%lat':>8}")
    for phase in ("prefill", "decode"):
        for B in (1, 8, 64):
            for S in (1024, 8192, 32768, 131072):
                r = intensity(cfg, B, S, phase)
                agg = r["aggregate"][2]
                bound = "compute" if agg > RIDGE else "memory"
                print(f"{phase:<8} {B:>4} {S:>7} {r['linear'][2]:>9.1f} "
                      f"{r['attention'][2]:>8.2f} {agg:>8.2f} {bound:>8} "
                      f"{r['attention_latency_frac']:>8.1%}")
                csv_rows.append(
                    ("arithmetic_intensity",
                     f"{phase}_B{B}_S{S}",
                     f"AI={agg:.3f};bound={bound}"))

    # the paper's §3.1 conclusion: quantization strategy per regime
    print("\n# regime → dominant memory traffic (what to quantize)")
    for B, S in ((1, 1024), (1, 32768), (64, 1024), (64, 131072)):
        r = intensity(cfg, B, S, "decode")
        frac = r["attention_latency_frac"]
        rec = ("KV cache" if frac > 0.6 else
               "weights" if frac < 0.4 else "both")
        print(f"decode B={B:<3} S={S:<7} attention={frac:.0%} of latency "
              f"→ quantize {rec}")
        csv_rows.append(("ai_regime", f"B{B}_S{S}", f"quantize={rec}"))
    return csv_rows


if __name__ == "__main__":
    run([])
