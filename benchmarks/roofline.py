"""§Roofline: three-term roofline per (arch × shape × mesh) from the
dry-run's compiled artifacts (experiments/dryrun/*.json).

    compute    = FLOPs_per_chip / 197 TFLOP/s
    memory     = bytes_per_chip / 819 GB/s
    collective = collective_bytes / (chips × 50 GB/s)

`cost_analysis()` on a partitioned executable reports per-chip numbers
(verified empirically — see EXPERIMENTS.md §Dry-run), so compute/memory
terms divide by per-chip peaks directly; collective bytes are parsed from
the post-SPMD HLO as global result-shape bytes, hence divided by the chip
count × per-link bandwidth per the brief's formula.

Also reports MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/replication waste).
"""

from __future__ import annotations

import glob
import json
import os

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = "experiments/dryrun"

TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
          "decode_32k": 128 * 1, "long_500k": 1 * 1}


def load_results(dry_dir=DRYRUN_DIR):
    out = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def roofline_terms(rec):
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    coll_bytes = sum(v for k, v in rec["collectives"].items()
                     if k != "count")
    t_compute = (rec["flops"] or 0) / PEAK_FLOPS_BF16
    t_memory = (rec["bytes_accessed"] or 0) / HBM_BW
    t_coll = coll_bytes / (chips * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)

    tokens = TOKENS.get(rec["shape"], 1)
    factor = 6 if rec["shape"] == "train_4k" else 2
    model_flops_per_chip = factor * rec["params_active"] * tokens / chips
    useful = model_flops_per_chip / max(rec["flops"] or 1, 1)
    return terms, dom, model_flops_per_chip, useful


def run(csv_rows, dry_dir=DRYRUN_DIR):
    recs = load_results(dry_dir)
    if not recs:
        print("\n# §Roofline: no dry-run results found — run "
              "`python -m repro.launch.dryrun --both-meshes` first")
        return csv_rows
    print("\n# §Roofline — per (arch × shape × mesh), seconds per step")
    print(f"{'arch':<22} {'shape':<12} {'mesh':<8} {'compute':>9} "
          f"{'memory':>9} {'collect':>9} {'dominant':>10} {'useful%':>8}")
    for rec in recs:
        terms, dom, mf, useful = roofline_terms(rec)
        print(f"{rec['arch']:<22} {rec['shape']:<12} {rec['mesh']:<8} "
              f"{terms['compute']:>9.2e} {terms['memory']:>9.2e} "
              f"{terms['collective']:>9.2e} {dom:>10} {useful:>8.1%}")
        csv_rows.append(
            ("roofline", f"{rec['arch']}_{rec['shape']}_{rec['mesh']}",
             f"compute={terms['compute']:.3e};memory={terms['memory']:.3e};"
             f"collective={terms['collective']:.3e};dom={dom};"
             f"useful={useful:.3f}"))
    return csv_rows


if __name__ == "__main__":
    run([])
