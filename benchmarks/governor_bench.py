"""Precision-governor benchmark: the guaranteed AR floor under acceptance
collapse.

    PYTHONPATH=src python benchmarks/governor_bench.py [--smoke]
        [--json BENCH_governor.json]

Four waves over identical prompts (greedy, so outputs are token-identical
everywhere — speculative decoding is exact and the ladder trades
throughput, never content).  Requests == slots and stall preemption is
disabled, so the waves measure decode, not scheduler churn:

* **ar_baseline** — ``gamma=0``: the pure autoregressive engine (the
  paper's non-speculative serving baseline, one dispatch + readback per
  token).  Its per-request decode rate is the floor the governor must
  guarantee.
* **no_governor** — ``gamma`` speculation with slot 0's drafts
  deterministically corrupted (`FaultInjector.mangle_draft`, acceptance
  ~0) and no governor: the collapsed slot burns a full-γ draft+verify
  round (~3-4x an AR step) per ~1 token, forever.
* **no_governor_collapse** — both slots' drafts corrupted, no governor:
  the whole batch pays full-γ rounds for ~1 token each.  The ungoverned
  worst case the ladder exists to escape.
* **governor_mixed** — the mixed wave with the acceptance-aware
  governor: the collapsed slot walks the INT4→INT8→AR ladder down to
  verify-only decode while the co-batched healthy slot keeps INT4
  speculation.
* **governor_collapse** — both slots corrupted, governor on: the whole
  batch walks to the AR floor, so the megastep's fused AR path (a
  verify-only 1-token target step per round, no draft work) actually
  engages.

On top of the waves, a **steady-state floor microbenchmark** isolates
the AR-floor guarantee from the one-time ladder-walk transient: a fully
collapsed governor engine is driven to the floor, then timed *step-by-
step interleaved* with an identically driven ``gamma=0`` engine, so
machine-load drift hits both engines alike.  The floor's per-round work
is the
same compiled ``paged_ar_step`` the AR engine runs — the waves assert
token identity — plus the megastep's branch plumbing (`lax.cond` over
the carried decode state) and a full-γ probe round every
``probe_every + 1`` rounds, which together cost ~13% of a round on the
XLA CPU backend (they amortize into memory-bound attention on real
accelerators).  The interleaved ratio measures a stable ~0.87 on CPU
and is gated at ≥0.8 as a regression bound — against the 2.5-4x
collapse the ladder escapes, the floor is parity within backend
overhead, never a cliff.

Every ladder transition is masking inside the one compiled megastep —
both governor waves assert exactly one compile.

``--smoke`` (CI) asserts on the written ``BENCH_governor.json``:

* steady-state floor ≥ 0.8x the AR baseline measured the same way (the
  AR-floor guarantee, net of branch-plumbing overhead and timing
  jitter);
* under total collapse the governed wave beats the ungoverned one by
  ≥1.7x end-to-end *including* its ladder walk, and the governed mixed
  wave's collapsed slot beats its ungoverned twin by ≥1.2x (the
  robustness win — smaller in the mixed wave because a co-batched
  healthy slot keeps every round on the spec cadence until it finishes);
* the co-batched healthy slot retains ≥80% of its no-governor
  throughput, measured steady-state in the same interleaved style
  (~0.86 typical: the governor's per-round machinery — `lax.cond`
  branch plumbing on the XLA CPU backend — costs ~13% of a spec round;
  it amortizes into memory-bound attention on real accelerators);
* every collapsed request walked the full ladder, zero recompiles, and
  all waves are token-identical to the AR baseline.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

sys.path.insert(0, ".")   # repo root (benchmarks.common) when run as a script
sys.path.insert(0, "src")
sys.path.insert(0, "tests")   # the deterministic fault harness lives here

from benchmarks.common import bench_config, corpus  # noqa: E402
from fault_injection import FaultInjector  # noqa: E402
from repro.core.spec_decode import RUNG_AR  # noqa: E402
from repro.models.stack import StackModel  # noqa: E402
from repro.serving.engine import ContinuousEngine  # noqa: E402

#: governor thresholds tuned around the untrained tiny model's natural
#: acceptance (~0.3): corrupted drafts (~0.0) fall through the floor
#: every window, while a healthy slot's windowed acceptance — a binomial
#: with p~0.3 over 16 proposals — dips below the 0.1 floor only ~3% of
#: evaluations, so spurious demotion churn is rare.  (A tighter window=8
#: with floor=0.15 demoted healthy slots every ~15 rounds and randomly
#: walked them all the way onto the AR floor.)
GOV_KW = dict(governor=True, accept_window=16, accept_floor=0.1,
              accept_ceiling=0.2, probe_every=32, gamma_lo=2)

SLOTS = 2


def _rate(req):
    """Decode tok/s for one finished request (prefill excluded)."""
    return len(req.tokens) / max(req.finish_t - req.admit_t - req.prefill_s,
                                 1e-9)


def _engine(model, params, max_seq, *, gamma, fault=None, **kw):
    """One benchmark engine: slots == wave size and stall preemption off,
    so nothing is queued, preempted, or resumed mid-wave."""
    return ContinuousEngine(model, params, gamma=gamma, greedy=True,
                            max_slots=SLOTS, max_seq=max_seq,
                            rounds_per_step=4 if gamma > 0 else 0,
                            preempt_patience=10**9, fault=fault, **kw)


def _warm(eng, prompts):
    """Warm the compile caches (prefill buckets + megastep / AR step) on a
    throwaway wave so timed runs measure decode, not XLA."""
    for p in prompts:
        eng.submit(p, 8)
    eng.run(jax.random.PRNGKey(11))


def _run(model, params, prompts, max_new, max_seq, *, gamma, collapsed,
         **kw):
    fault = FaultInjector() if gamma > 0 and collapsed else None
    eng = _engine(model, params, max_seq, gamma=gamma, fault=fault, **kw)
    _warm(eng, prompts)
    reqs = [eng.submit(p, max_new) for p in prompts]
    if fault is not None:
        for i, r in enumerate(reqs):
            if i in collapsed:
                fault.mangle_draft(req_id=r.req_id, mode=1)
    t0 = time.perf_counter()
    eng.run(jax.random.PRNGKey(7))
    wall = time.perf_counter() - t0
    assert all(r.status == "ok" for r in reqs), \
        [(r.req_id, r.status, r.reason) for r in reqs]
    assert int(eng.table.free_top) == eng.pool_blocks, "leaked pool blocks"
    groups = {"collapsed": [r for i, r in enumerate(reqs) if i in collapsed],
              "healthy": [r for i, r in enumerate(reqs)
                          if i not in collapsed]}
    row = {
        "wall_s": round(wall, 4),
        "tok_s": round(sum(len(r.tokens) for r in reqs) / max(wall, 1e-9),
                       2),
        "req_tok_s": round(float(np.mean([_rate(r) for r in reqs])), 2),
    }
    for name, rs in groups.items():
        if not rs:
            continue
        row[f"{name}_tok_s"] = round(
            float(np.mean([_rate(r) for r in rs])), 2)
        row[f"{name}_acceptance"] = round(
            float(np.mean([r.accepted / max(r.proposed, 1) for r in rs])), 3)
    if kw.get("governor"):
        row["ladder"] = {
            str(i): {"demotions": r.demotions,
                     "promotions": r.promotions,
                     "ar_rounds": r.ar_rounds,
                     "int8_rounds": r.int8_rounds,
                     "final_rung": r.rung}
            for i, r in enumerate(reqs)}
        row["megastep_compiles"] = eng._mega._cache_size()
    return row, {r.req_id: list(r.tokens) for r in reqs}


def _floor_microbench(model, params, prompts, max_seq, gamma, *,
                      segments=4, gov_steps=8):
    """Steady-state AR-floor throughput vs the dedicated AR engine.

    Both engines decode the same prompts; the governor engine (every
    draft corrupted) is first driven onto the AR floor, then the two are
    timed interleaved — one governor megastep (``rps`` fused rounds)
    followed by ``rps`` AR steps, repeatedly — accumulating each
    engine's own wall time, so machine-load drift hits both engines
    alike.  Finishes both engines and asserts their outputs are
    token-identical.
    """
    rps = 4
    # enough budget for the ladder walk + every timed segment
    max_new = 32 + segments * gov_steps * rps + 32
    fault = FaultInjector()
    gov = _engine(model, params, max_seq, gamma=gamma, fault=fault,
                  **GOV_KW)
    ar = _engine(model, params, max_seq, gamma=0)
    _warm(gov, prompts)
    _warm(ar, prompts)
    greqs = [gov.submit(p, max_new) for p in prompts]
    areqs = [ar.submit(p, max_new) for p in prompts]
    fault.mangle_draft(mode=1)
    kg = jax.random.PRNGKey(7)
    ka = jax.random.PRNGKey(7)
    toks = lambda reqs: sum(len(r.tokens) for r in reqs)
    walk = 0
    while not all(r.rung == RUNG_AR for r in greqs) and walk < 40:
        kg = gov.step(kg)
        walk += 1
    assert all(r.rung == RUNG_AR for r in greqs), \
        "collapsed slots never reached the AR floor"
    for _ in range(4):   # settle the AR engine past admission
        ka = ar.step(ka)
    tg = ta = 0.0
    g0, a0 = toks(greqs), toks(areqs)
    for _ in range(segments * gov_steps):
        t0 = time.perf_counter()
        kg = gov.step(kg)
        tg += time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(rps):   # match tokens: rps AR steps per megastep
            ka = ar.step(ka)
        ta += time.perf_counter() - t0
    floor_rate = (toks(greqs) - g0) / SLOTS / tg
    ar_rate = (toks(areqs) - a0) / SLOTS / ta
    gov.run(kg)
    ar.run(ka)
    assert [list(r.tokens) for r in greqs] == \
        [list(r.tokens) for r in areqs], \
        "floor microbench outputs diverged from the AR engine"
    return {
        "floor_tok_s": round(float(floor_rate), 2),
        "ar_tok_s": round(float(ar_rate), 2),
        "ratio": round(float(floor_rate / ar_rate), 3),
        "walk_steps": walk,
    }


def _retention_microbench(model, params, prompts, max_seq, gamma, *,
                          segments=4, steps=5):
    """Steady-state healthy-slot retention: a mixed batch (slot 0
    collapsed, slot 1 healthy) under the governor vs the same batch
    ungoverned, timed step-by-step interleaved once the governed slot
    sits on the AR floor, accumulating each engine's own wall time —
    the interleaving cancels the machine-load drift that whole-wave
    comparisons minutes apart pick up.  Ratio of the healthy slot's
    decode rates.  Finishes both engines and asserts token identity."""
    max_new = 320   # the healthy slot consumes ~2 tokens per round
    f_gov = FaultInjector()
    f_ref = FaultInjector()
    gov = _engine(model, params, max_seq, gamma=gamma, fault=f_gov,
                  **GOV_KW)
    ref = _engine(model, params, max_seq, gamma=gamma, fault=f_ref)
    _warm(gov, prompts)
    _warm(ref, prompts)
    greqs = [gov.submit(p, max_new) for p in prompts]
    rreqs = [ref.submit(p, max_new) for p in prompts]
    f_gov.mangle_draft(req_id=greqs[0].req_id, mode=1)
    f_ref.mangle_draft(req_id=rreqs[0].req_id, mode=1)
    kg = jax.random.PRNGKey(7)
    kr = jax.random.PRNGKey(7)
    walk = 0
    while greqs[0].rung != RUNG_AR and walk < 40:
        kg = gov.step(kg)
        walk += 1
    assert greqs[0].rung == RUNG_AR, \
        "collapsed slot never reached the AR floor"
    for _ in range(4):   # settle the reference engine past admission
        kr = ref.step(kr)
    tg = tr = 0.0
    g0, r0 = len(greqs[1].tokens), len(rreqs[1].tokens)
    for _ in range(segments * steps):
        t0 = time.perf_counter()
        kg = gov.step(kg)
        tg += time.perf_counter() - t0
        t0 = time.perf_counter()
        kr = ref.step(kr)
        tr += time.perf_counter() - t0
    gov_rate = (len(greqs[1].tokens) - g0) / tg
    ref_rate = (len(rreqs[1].tokens) - r0) / tr
    gov.run(kg)
    ref.run(kr)
    assert [list(r.tokens) for r in greqs] == \
        [list(r.tokens) for r in rreqs], \
        "retention microbench outputs diverged between engines"
    return {
        "governed_tok_s": round(float(gov_rate), 2),
        "ungoverned_tok_s": round(float(ref_rate), 2),
        "ratio": round(float(gov_rate / ref_rate), 3),
        "walk_steps": walk,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI; asserts the AR floor and "
                         "healthy-slot throughput retention")
    ap.add_argument("--json", default="BENCH_governor.json")
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--gamma", type=int, default=3)
    args = ap.parse_args()

    cfg = bench_config()
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # scheduling cost, not quality
    G = cfg.group_size
    data = corpus()
    key = jax.random.PRNGKey(5)
    max_new = args.max_new or (192 if args.smoke else 256)
    lens = [G + 5 + 3 * i for i in range(SLOTS)]
    prompts = [np.asarray(data.sample(jax.random.fold_in(key, i), 1, s)[0])
               for i, s in enumerate(lens)]
    max_seq = max(lens) + max(max_new, 320) + 2 * G + 8
    mixed = frozenset({0})                      # slot 0: mangled drafts
    everyone = frozenset(range(SLOTS))

    print(f"{SLOTS} requests (slot 0 draft-collapsed in the mixed waves), "
          f"{max_new} new tokens each, gamma={args.gamma}")
    rows = {}
    toks = {}
    specs = {
        "ar_baseline": dict(gamma=0, collapsed=mixed),
        "no_governor": dict(gamma=args.gamma, collapsed=mixed),
        "no_governor_collapse": dict(gamma=args.gamma, collapsed=everyone),
        "governor_mixed": dict(gamma=args.gamma, collapsed=mixed, **GOV_KW),
        "governor_collapse": dict(gamma=args.gamma, collapsed=everyone,
                                  **GOV_KW),
    }
    for name, kw in specs.items():
        rows[name], toks[name] = _run(model, params, prompts, max_new,
                                      max_seq, **kw)
        parts = "".join(
            f"  {g} {rows[name][f'{g}_tok_s']:>7.2f} tok/s"
            for g in ("collapsed", "healthy")
            if f"{g}_tok_s" in rows[name])
        print(f"  {name:<18} {rows[name]['wall_s']:>7.2f}s{parts}")

    for name in specs:
        assert toks[name] == toks["ar_baseline"], \
            f"{name} wave changed greedy outputs"

    floor = _floor_microbench(model, params, prompts, max_seq, args.gamma)
    print(f"  steady-state floor {floor['floor_tok_s']:.2f} tok/s vs "
          f"AR {floor['ar_tok_s']:.2f} tok/s "
          f"(ratio {floor['ratio']:.3f}, walk {floor['walk_steps']} steps)")
    retention = _retention_microbench(model, params, prompts, max_seq,
                                      args.gamma)
    print(f"  steady-state healthy retention "
          f"{retention['governed_tok_s']:.2f} vs "
          f"{retention['ungoverned_tok_s']:.2f} tok/s "
          f"(ratio {retention['ratio']:.3f})")

    out = {
        "config": {"requests": SLOTS, "mixed_collapsed": sorted(mixed),
                   "max_new": max_new, "gamma": args.gamma,
                   "group": G, "governor": GOV_KW,
                   "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        "token_identical": True,
        "floor_steady_state": floor,
        "healthy_retention": retention,
        **rows,
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.json}")

    for name, ids in (("governor_mixed", mixed),
                      ("governor_collapse", everyone)):
        gov = rows[name]
        walked = [v for k, v in gov["ladder"].items() if int(k) in ids]
        assert all(w["ar_rounds"] > 0 and w["demotions"] >= 3
                   for w in walked), \
            f"a collapsed request never reached the AR floor in {name}"
        assert gov["megastep_compiles"] == 1, \
            f"ladder transitions recompiled the megastep in {name}"
    if args.smoke:
        assert floor["ratio"] >= 0.8, (
            "AR floor violated: steady-state floor decode "
            f"({floor['floor_tok_s']} tok/s per slot) fell below 0.8x the "
            f"AR baseline ({floor['ar_tok_s']} tok/s) measured in "
            "paired alternating segments")
        won = rows["governor_collapse"]["collapsed_tok_s"]
        lost = rows["no_governor_collapse"]["collapsed_tok_s"]
        assert won >= 1.7 * lost, (
            "governor did not rescue the collapsed wave: "
            f"{won} vs {lost} tok/s ungoverned")
        won_m = rows["governor_mixed"]["collapsed_tok_s"]
        lost_m = rows["no_governor"]["collapsed_tok_s"]
        assert won_m >= 1.2 * lost_m, (
            "governor did not rescue the co-batched collapsed slot: "
            f"{won_m} vs {lost_m} tok/s ungoverned")
        assert retention["ratio"] >= 0.8, (
            "healthy slot lost speculation throughput under the governor: "
            f"steady-state retention {retention['ratio']} < 0.8 "
            f"({retention['governed_tok_s']} vs "
            f"{retention['ungoverned_tok_s']} tok/s)")
        print("smoke assertions passed: steady-state floor ratio "
              f"{floor['ratio']} >= 0.8; collapsed wave {won} tok/s >= "
              f"1.7x ungoverned {lost}; healthy retention "
              f"{retention['ratio']} >= 0.8")


if __name__ == "__main__":
    main()
