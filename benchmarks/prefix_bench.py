"""Prefix-caching benchmark: cold vs cached admission on the paged engine.

    PYTHONPATH=src python benchmarks/prefix_bench.py [--smoke]
        [--json BENCH_prefix.json]

Two serving patterns where cross-request prefix reuse dominates admission
cost:

* **shared-prompt** — N requests carrying the same long system prompt with
  short unique user suffixes (few-shot templates, agent scaffolds);
* **multi-turn** — one conversation resubmitted turn after turn, each turn's
  prompt = previous prompt + previous output + a new user message.

Each pattern runs on a cold `ContinuousEngine` (``prefix_cache=False``) and
a warm one (``prefix_cache=True``) over identical requests.  Outputs are
asserted token-identical (greedy) — the cache is an admission-cost
optimisation, never an approximation.  Recorded per engine: wall-clock,
tokens/s, and total admission chunks (the chunked-prefill dispatch count —
cached admissions prefill only the uncached suffix, so warm chunk counts
shrink proportionally to the shared prefix); plus the warm engine's index
telemetry (hit rate, hit tokens, resident blocks, harvest syncs).

``--smoke`` (CI) asserts hit_rate > 0 and warm chunks < cold chunks for
both patterns.  Results land in ``BENCH_prefix.json``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

sys.path.insert(0, ".")   # repo root (benchmarks.common) when run as a script
sys.path.insert(0, "src")

from benchmarks.common import bench_config, corpus  # noqa: E402
from repro.models.stack import StackModel  # noqa: E402
from repro.serving.engine import ContinuousEngine  # noqa: E402


def _engine(model, params, max_seq, gamma, prefix):
    # chunk = one quant group, so admission cost is measured at block
    # granularity (the unit the prefix cache actually saves)
    G = model.cfg.group_size
    return ContinuousEngine(model, params, gamma=gamma, greedy=True,
                            max_slots=2, max_seq=max_seq, prefill_chunk=G,
                            rounds_per_step=4, prefix_cache=prefix)


def _run(eng, prompts, max_new):
    reqs = [eng.submit(p, max_new) for p in prompts]
    t0 = time.perf_counter()
    eng.run(jax.random.PRNGKey(7))
    wall = time.perf_counter() - t0
    return wall, reqs


def _rows(model, params, prompt_seqs, max_new, max_seq, gamma):
    """Drive identical request sequences through a cold and a warm engine.
    ``prompt_seqs`` is a list of submission waves (requests inside a wave
    are interleaved by the scheduler; waves run back to back)."""
    out, toks = {}, {}
    for label, prefix in (("cold", False), ("warm", True)):
        eng = _engine(model, params, max_seq, gamma, prefix)
        wall, chunks, seqs = 0.0, 0, []
        for wave in prompt_seqs:
            w, reqs = _run(eng, wave, max_new)
            wall += w
            chunks += sum(r.prefill_chunks for r in reqs)
            seqs.extend(list(r.tokens) for r in reqs)
        n_tok = sum(len(s) for s in seqs)
        toks[label] = seqs
        out[label] = {
            "wall_s": round(wall, 4),
            "tok_s": round(n_tok / max(wall, 1e-9), 2),
            "prefill_chunks": chunks,
        }
        if prefix:
            st = eng.prefix.stats
            lookups = max(st["hits"] + st["misses"], 1)
            out[label].update(
                hit_rate=round(st["hits"] / lookups, 4),
                hit_tokens=st["hit_tokens"],
                index_blocks=st["blocks"],
                cache_syncs=eng.cache_syncs,
            )
    identical = toks["cold"] == toks["warm"]
    return out, identical


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI; asserts hit rate > 0, warm "
                         "chunks < cold chunks, and token identity")
    ap.add_argument("--json", default="BENCH_prefix.json")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--turns", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--gamma", type=int, default=3)
    args = ap.parse_args()

    cfg = bench_config()
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # admission cost, not quality
    G = cfg.group_size
    data = corpus()
    key = jax.random.PRNGKey(5)

    n_req = args.requests or (4 if args.smoke else 8)
    turns = args.turns or (3 if args.smoke else 5)
    max_new = args.max_new or (12 if args.smoke else 32)
    sys_len = 3 * G if args.smoke else 8 * G
    tail_len = 16 if args.smoke else 64

    # shared-prompt: one long system prefix, short unique user tails
    sys_p = np.asarray(data.sample(key, 1, sys_len)[0])
    shared = [np.concatenate([sys_p, np.asarray(
        data.sample(jax.random.fold_in(key, i), 1, tail_len)[0])])
        for i in range(n_req)]
    max_seq = sys_len + tail_len + (turns + 1) * (max_new + tail_len) + 4 * G

    print(f"shared-prompt: {n_req} requests, sys {sys_len} + tail "
          f"{tail_len} tokens, {max_new} new each")
    shared_rows, ident_shared = _rows(model, params, [shared], max_new,
                                      max_seq, args.gamma)
    for k, v in shared_rows.items():
        print(f"  {k:<5} {v['tok_s']:>8.1f} tok/s  "
              f"{v['prefill_chunks']:>3} admission chunks")

    # multi-turn: resubmit the growing conversation turn after turn; the
    # warm engine re-admits each turn from the cache.  Outputs feed the
    # next turn's prompt, so build the turn sequence once with a reference
    # engine and replay the identical prompts through cold/warm.
    ref = _engine(model, params, max_seq, args.gamma, prefix=False)
    conv = np.asarray(data.sample(jax.random.fold_in(key, 99), 1,
                                  2 * G)[0])
    waves = []
    for t in range(turns):
        waves.append([conv.copy()])
        _, reqs = _run(ref, [conv], max_new)
        user = np.asarray(data.sample(jax.random.fold_in(key, 200 + t), 1,
                                      tail_len)[0])
        conv = np.concatenate([conv, np.asarray(reqs[0].tokens, np.int32),
                               user])
    print(f"multi-turn: {turns} turns, {max_new} new/turn")
    turn_rows, ident_turns = _rows(model, params, waves, max_new, max_seq,
                                   args.gamma)
    for k, v in turn_rows.items():
        print(f"  {k:<5} {v['tok_s']:>8.1f} tok/s  "
              f"{v['prefill_chunks']:>3} admission chunks")

    out = {
        "config": {"requests": n_req, "turns": turns, "max_new": max_new,
                   "sys_len": sys_len, "tail_len": tail_len,
                   "gamma": args.gamma, "group": G,
                   "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        "shared_prompt": shared_rows,
        "multi_turn": turn_rows,
        "token_identical": bool(ident_shared and ident_turns),
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.json}")

    assert out["token_identical"], "cached admission changed greedy outputs"
    if args.smoke:
        for name, rows in (("shared_prompt", shared_rows),
                           ("multi_turn", turn_rows)):
            assert rows["warm"]["hit_rate"] > 0, name
            assert (rows["warm"]["prefill_chunks"]
                    < rows["cold"]["prefill_chunks"]), name
        print("smoke assertions passed: hit rate > 0, cached admission "
              "prefills fewer chunks, outputs token-identical")


if __name__ == "__main__":
    main()
