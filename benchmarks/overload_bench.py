"""Overload benchmark: graceful degradation under pool oversubscription.

    PYTHONPATH=src python benchmarks/overload_bench.py [--smoke]
        [--json BENCH_overload.json]

``--tiers`` switches to the three-tier hierarchy benchmark
(``BENCH_recovery.json``): a 2x-oversubscribed wave is forced through
device → host → disk with burst preemption storms and a 1-byte host
capacity, then measured twice on the *same warmed engine* — speculative
prefetch off vs on — comparing the per-resume swap-in blocking time (the
acceptance bar: prefetch-on must block strictly less than
dispatch-at-admission).  A final wave is crashed mid-flight after a
checkpoint and timed through ``recover()`` + replay to completion, with
greedy outputs verified token-identical to an unconstrained reference in
every wave.

A request wave whose worst-case KV footprint is ~2x the block pool is
driven through three engines over identical prompts:

* **unconstrained** — pool sized for the whole wave (reference outputs);
* **preempt** — 2x-oversubscribed pool, ``overflow="preempt"``: when the
  queue head cannot be admitted, a running slot's quantized blocks swap
  to the host tier (core/host_tier.py) and swap back in later — every
  request completes, and greedy outputs must stay token-identical to the
  unconstrained run (the swap is bit-exact);
* **reject** — same pool, ``overflow="reject"``: the admission-time
  rejection baseline sheds whatever doesn't fit.

Recorded per engine: wall-clock, tok/s, terminal-status counts, p50/p99
completion latency (``finish_t - submit_t``) over completed requests, and
the preempt engine's swap telemetry (preempts, resumes, bytes offloaded).
``--smoke`` (CI) asserts the preempt engine completes the whole wave
``ok`` and token-identical while the reject baseline sheds at least one
request.  Results land in ``BENCH_overload.json``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

sys.path.insert(0, ".")   # repo root (benchmarks.common) when run as a script
sys.path.insert(0, "src")

from benchmarks.common import bench_config, corpus  # noqa: E402
from repro.models.stack import StackModel  # noqa: E402
from repro.serving.engine import ContinuousEngine  # noqa: E402


def _run(model, params, prompts, max_new, max_seq, gamma, *, pool, overflow):
    eng = ContinuousEngine(
        model, params, gamma=gamma, greedy=True, max_slots=2,
        max_seq=max_seq, pool_blocks=pool, overflow=overflow,
        preempt_patience=2)
    reqs = [eng.submit(p, max_new) for p in prompts]
    t0 = time.perf_counter()
    eng.run(jax.random.PRNGKey(7))
    wall = time.perf_counter() - t0
    ok = [r for r in reqs if r.status == "ok"]
    lat = sorted(r.finish_t - r.submit_t for r in ok) or [0.0]
    statuses = {}
    for r in reqs:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    n_tok = sum(len(r.tokens) for r in ok)
    row = {
        "wall_s": round(wall, 4),
        "tok_s": round(n_tok / max(wall, 1e-9), 2),
        "completed_ok": len(ok),
        "statuses": statuses,
        "p50_latency_s": round(float(np.percentile(lat, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lat, 99)), 4),
    }
    if overflow == "preempt":
        row.update(preempts=eng.preempts, resumes=eng.resumes,
                   bytes_offloaded=(eng.host_tier.bytes_offloaded
                                    if eng.host_tier else 0))
    assert int(eng.table.free_top) == eng.pool_blocks, "leaked pool blocks"
    return row, {r.req_id: list(r.tokens) for r in ok}


def _workload(args):
    cfg = bench_config()
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # scheduling cost, not quality
    G = cfg.group_size
    data = corpus()
    key = jax.random.PRNGKey(5)
    n_req = args.requests or (6 if args.smoke else 12)
    max_new = args.max_new or (24 if args.smoke else 48)
    lens = [(2 + i % 3) * G + 5 + 3 * i for i in range(n_req)]
    prompts = [np.asarray(data.sample(jax.random.fold_in(key, i), 1, s)[0])
               for i, s in enumerate(lens)]
    max_seq = max(lens) + max_new + 2 * G + 8
    bounds = [-(-(s + max_new) // G) for s in lens]
    pool = max(int(round(sum(bounds) / args.oversub)), max(bounds) + 1)
    return cfg, model, params, prompts, max_new, max_seq, bounds, pool


def run_tiers(args):
    """Three-tier spill/prefetch/recovery benchmark (see module docstring);
    writes ``BENCH_recovery.json``-style output to ``args.json``."""
    import os
    import tempfile

    sys.path.insert(0, "tests")   # the deterministic fault harness lives here
    from fault_injection import FaultInjector  # noqa: E402
    from repro.serving import journal as J  # noqa: E402

    class _Crash(RuntimeError):
        pass

    class CrashInjector(FaultInjector):
        """Preempt + checkpoint one victim, then die like a SIGKILL."""

        def __init__(self, after):
            super().__init__()
            self.after = after
            self.fired = False

        def tick(self, engine):
            super().tick(engine)
            if self.fired or self.ticks < self.after:
                return
            busy = engine._prefilling.slot if engine._prefilling else None
            victim = engine.scheduler.preemption_victim(
                exclude=() if busy is None else (busy,))
            if victim is None:
                return
            engine._do_preempt(victim)
            engine._checkpoint()
            self.fired = True
            raise _Crash("injected kill")

    (cfg, model, params, prompts, max_new, max_seq, bounds,
     pool) = _workload(args)
    n_req = len(prompts)
    print(f"{n_req} requests, {max_new} new each; worst-case "
          f"{sum(bounds)} blocks vs pool {pool} "
          f"({sum(bounds) / pool:.2f}x), host capacity 1 byte "
          f"(every concurrent snapshot spills to disk)")

    ref_eng = ContinuousEngine(model, params, gamma=args.gamma, greedy=True,
                               max_slots=2, max_seq=max_seq,
                               overflow="wait")
    refs = [ref_eng.submit(p, max_new) for p in prompts]
    ref_eng.run(jax.random.PRNGKey(7))
    assert all(r.status == "ok" for r in refs)
    ref_toks = [list(r.tokens) for r in refs]

    root = tempfile.mkdtemp(prefix="tiers_bench_")
    eng = ContinuousEngine(
        model, params, gamma=args.gamma, greedy=True, max_slots=2,
        max_seq=max_seq, pool_blocks=pool, overflow="preempt",
        preempt_patience=2, fault=FaultInjector(),
        host_capacity_bytes=1, disk_dir=os.path.join(root, "kv"))

    def wave(prefetch, record=True):
        eng.prefetch = prefetch
        eng.fault = FaultInjector().preemption_storm(2, burst=2)
        reqs = [eng.submit(p, max_new) for p in prompts]
        base = (eng.resumes, eng.resume_block_s, eng.host_tier.spills,
                eng.host_tier.disk_restores, eng.prefetch_hits,
                eng.prefetch_misses)
        t0 = time.perf_counter()
        eng.run(jax.random.PRNGKey(7))
        wall = time.perf_counter() - t0
        assert all(r.status == "ok" for r in reqs), \
            [(r.req_id, r.status, r.reason) for r in reqs]
        for r, ref in zip(reqs, ref_toks):
            assert list(r.tokens) == ref, "tier traffic changed outputs"
        assert int(eng.table.free_top) == eng.pool_blocks, "leaked blocks"
        if not record:
            return None
        resumes = eng.resumes - base[0]
        block_s = eng.resume_block_s - base[1]
        return {
            "wall_s": round(wall, 4),
            "resumes": resumes,
            "resume_block_s": round(block_s, 6),
            "resume_block_ms_avg": round(1e3 * block_s / max(resumes, 1), 3),
            "spills": eng.host_tier.spills - base[2],
            "disk_restores": eng.host_tier.disk_restores - base[3],
            "prefetch_hits": eng.prefetch_hits - base[4],
            "prefetch_misses": eng.prefetch_misses - base[5],
        }

    wave(prefetch=True, record=False)   # warm compile + first-resume jit
    rows = {}
    for label, on in (("prefetch_off", False), ("prefetch_on", True)):
        rows[label] = wave(on)
        print(f"  {label:<13} {rows[label]['resumes']} resumes  "
              f"avg swap-in block {rows[label]['resume_block_ms_avg']:.2f}ms"
              f"  spills {rows[label]['spills']}  "
              f"disk restores {rows[label]['disk_restores']}")

    # crash mid-wave (post-checkpoint), then recover + replay to completion
    jdir = os.path.join(root, "journal")
    crash_eng = ContinuousEngine(
        model, params, gamma=args.gamma, greedy=True, max_slots=2,
        max_seq=max_seq, pool_blocks=pool, overflow="preempt",
        preempt_patience=2, fault=CrashInjector(after=4),
        journal_dir=jdir, checkpoint_every=2)
    for p in prompts:
        crash_eng.submit(p, max_new)
    try:
        crash_eng.run(jax.random.PRNGKey(7))
        raise SystemExit("crash injector never fired")
    except _Crash:
        pass
    del crash_eng
    fresh = ContinuousEngine(
        model, params, gamma=args.gamma, greedy=True, max_slots=2,
        max_seq=max_seq, pool_blocks=pool, overflow="preempt",
        preempt_patience=2, journal_dir=jdir, checkpoint_every=2)
    t0 = time.perf_counter()
    recovered = fresh.recover()
    fresh.run(jax.random.PRNGKey(7))
    recovery_wall = time.perf_counter() - t0
    events, _ = J.read_events(jdir)
    recs = J.replay(events)
    assert sorted(recs) == list(range(n_req))
    for rid, rec in recs.items():
        assert rec.status == "ok" and rec.tokens == ref_toks[rid], \
            f"request {rid} diverged across the crash"
    rows["recovery"] = {
        "requests": n_req,
        "completed_ok": sum(1 for r in recs.values() if r.status == "ok"),
        "token_identical": all(rec.tokens == ref_toks[rid]
                               for rid, rec in recs.items()),
        "recovered": len(recovered),
        "resume_mode": sum(1 for e in events if e["ev"] == "recover"
                           and e["mode"] == "resume"),
        "replay_mode": sum(1 for e in events if e["ev"] == "recover"
                           and e["mode"] == "replay"),
        "recovery_wall_s": round(recovery_wall, 4),
        "journal_events": len(events),
    }
    print(f"  recovery      {rows['recovery']['recovered']} requests "
          f"({rows['recovery']['resume_mode']} resume / "
          f"{rows['recovery']['replay_mode']} replay) in "
          f"{recovery_wall:.2f}s, token-identical")

    out = {
        "config": {"requests": n_req, "max_new": max_new,
                   "gamma": args.gamma, "group": cfg.group_size,
                   "pool_blocks": pool,
                   "oversubscription": round(sum(bounds) / pool, 3),
                   "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        **rows,
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.json}")

    assert rows["prefetch_on"]["resumes"] >= 1, "no resume exercised"
    assert rows["prefetch_off"]["spills"] >= 1, "no host→disk spill"
    assert rows["prefetch_on"]["prefetch_hits"] >= 1, "prefetch never hit"
    # the acceptance bar: speculative prefetch must strictly beat
    # dispatch-at-admission swap-ins on the same warmed engine
    assert (rows["prefetch_on"]["resume_block_ms_avg"]
            < rows["prefetch_off"]["resume_block_ms_avg"]), \
        "prefetch-on swap-in blocking did not beat the blocking baseline"
    print("tiers assertions passed: prefetch-on blocks "
          f"{rows['prefetch_on']['resume_block_ms_avg']:.2f}ms/resume vs "
          f"{rows['prefetch_off']['resume_block_ms_avg']:.2f}ms baseline")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI; asserts preempt completes "
                         "the wave ok + token-identical, reject sheds load")
    ap.add_argument("--tiers", action="store_true",
                    help="three-tier spill/prefetch/crash-recovery benchmark")
    ap.add_argument("--json", default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--gamma", type=int, default=3)
    ap.add_argument("--oversub", type=float, default=2.0,
                    help="worst-case footprint / pool blocks")
    args = ap.parse_args()
    if args.json is None:
        args.json = "BENCH_recovery.json" if args.tiers \
            else "BENCH_overload.json"
    if args.tiers:
        run_tiers(args)
        return

    cfg = bench_config()
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # scheduling cost, not quality
    G = cfg.group_size
    data = corpus()
    key = jax.random.PRNGKey(5)

    # generations must outlast the preemption patience window, or natural
    # retirements keep unblocking the head and no swap is ever needed
    n_req = args.requests or (6 if args.smoke else 12)
    max_new = args.max_new or (24 if args.smoke else 48)
    lens = [(2 + i % 3) * G + 5 + 3 * i for i in range(n_req)]
    prompts = [np.asarray(data.sample(jax.random.fold_in(key, i), 1, s)[0])
               for i, s in enumerate(lens)]
    max_seq = max(lens) + max_new + 2 * G + 8
    bounds = [-(-(s + max_new) // G) for s in lens]
    pool = max(int(round(sum(bounds) / args.oversub)), max(bounds) + 1)

    print(f"{n_req} requests, {max_new} new each; worst-case "
          f"{sum(bounds)} blocks vs pool {pool} "
          f"({sum(bounds) / pool:.2f}x oversubscribed)")
    rows = {}
    ref_row, ref_toks = _run(model, params, prompts, max_new, max_seq,
                             args.gamma, pool=None, overflow="wait")
    rows["unconstrained"] = ref_row
    for mode in ("preempt", "reject"):
        rows[mode], toks = _run(model, params, prompts, max_new, max_seq,
                                args.gamma, pool=pool, overflow=mode)
        rows[mode]["token_identical"] = all(
            toks[i] == ref_toks[i] for i in toks)
        print(f"  {mode:<9} {rows[mode]['completed_ok']}/{n_req} ok  "
              f"{rows[mode]['tok_s']:>8.1f} tok/s  "
              f"p99 {rows[mode]['p99_latency_s']:.3f}s  "
              f"{rows[mode]['statuses']}")

    out = {
        "config": {"requests": n_req, "max_new": max_new,
                   "gamma": args.gamma, "group": G, "pool_blocks": pool,
                   "oversubscription": round(sum(bounds) / pool, 3),
                   "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        **rows,
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.json}")

    assert rows["preempt"]["token_identical"], \
        "preempt/resume changed greedy outputs"
    if args.smoke:
        assert rows["preempt"]["completed_ok"] == n_req, \
            "preempt mode must complete the whole oversubscribed wave"
        assert rows["preempt"]["preempts"] >= 1, "no preemption exercised"
        assert rows["reject"]["completed_ok"] < n_req, \
            "reject baseline unexpectedly completed everything"
        print("smoke assertions passed: preempt-resume completed "
              f"{rows['preempt']['completed_ok']}/{n_req} token-identical; "
              f"reject baseline completed only "
              f"{rows['reject']['completed_ok']}")


if __name__ == "__main__":
    main()
