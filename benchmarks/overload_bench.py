"""Overload benchmark: graceful degradation under pool oversubscription.

    PYTHONPATH=src python benchmarks/overload_bench.py [--smoke]
        [--json BENCH_overload.json]

A request wave whose worst-case KV footprint is ~2x the block pool is
driven through three engines over identical prompts:

* **unconstrained** — pool sized for the whole wave (reference outputs);
* **preempt** — 2x-oversubscribed pool, ``overflow="preempt"``: when the
  queue head cannot be admitted, a running slot's quantized blocks swap
  to the host tier (core/host_tier.py) and swap back in later — every
  request completes, and greedy outputs must stay token-identical to the
  unconstrained run (the swap is bit-exact);
* **reject** — same pool, ``overflow="reject"``: the admission-time
  rejection baseline sheds whatever doesn't fit.

Recorded per engine: wall-clock, tok/s, terminal-status counts, p50/p99
completion latency (``finish_t - submit_t``) over completed requests, and
the preempt engine's swap telemetry (preempts, resumes, bytes offloaded).
``--smoke`` (CI) asserts the preempt engine completes the whole wave
``ok`` and token-identical while the reject baseline sheds at least one
request.  Results land in ``BENCH_overload.json``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

sys.path.insert(0, ".")   # repo root (benchmarks.common) when run as a script
sys.path.insert(0, "src")

from benchmarks.common import bench_config, corpus  # noqa: E402
from repro.models.stack import StackModel  # noqa: E402
from repro.serving.engine import ContinuousEngine  # noqa: E402


def _run(model, params, prompts, max_new, max_seq, gamma, *, pool, overflow):
    eng = ContinuousEngine(
        model, params, gamma=gamma, greedy=True, max_slots=2,
        max_seq=max_seq, pool_blocks=pool, overflow=overflow,
        preempt_patience=2)
    reqs = [eng.submit(p, max_new) for p in prompts]
    t0 = time.perf_counter()
    eng.run(jax.random.PRNGKey(7))
    wall = time.perf_counter() - t0
    ok = [r for r in reqs if r.status == "ok"]
    lat = sorted(r.finish_t - r.submit_t for r in ok) or [0.0]
    statuses = {}
    for r in reqs:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    n_tok = sum(len(r.tokens) for r in ok)
    row = {
        "wall_s": round(wall, 4),
        "tok_s": round(n_tok / max(wall, 1e-9), 2),
        "completed_ok": len(ok),
        "statuses": statuses,
        "p50_latency_s": round(float(np.percentile(lat, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lat, 99)), 4),
    }
    if overflow == "preempt":
        row.update(preempts=eng.preempts, resumes=eng.resumes,
                   bytes_offloaded=(eng.host_tier.bytes_offloaded
                                    if eng.host_tier else 0))
    assert int(eng.table.free_top) == eng.pool_blocks, "leaked pool blocks"
    return row, {r.req_id: list(r.tokens) for r in ok}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI; asserts preempt completes "
                         "the wave ok + token-identical, reject sheds load")
    ap.add_argument("--json", default="BENCH_overload.json")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--gamma", type=int, default=3)
    ap.add_argument("--oversub", type=float, default=2.0,
                    help="worst-case footprint / pool blocks")
    args = ap.parse_args()

    cfg = bench_config()
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # scheduling cost, not quality
    G = cfg.group_size
    data = corpus()
    key = jax.random.PRNGKey(5)

    # generations must outlast the preemption patience window, or natural
    # retirements keep unblocking the head and no swap is ever needed
    n_req = args.requests or (6 if args.smoke else 12)
    max_new = args.max_new or (24 if args.smoke else 48)
    lens = [(2 + i % 3) * G + 5 + 3 * i for i in range(n_req)]
    prompts = [np.asarray(data.sample(jax.random.fold_in(key, i), 1, s)[0])
               for i, s in enumerate(lens)]
    max_seq = max(lens) + max_new + 2 * G + 8
    bounds = [-(-(s + max_new) // G) for s in lens]
    pool = max(int(round(sum(bounds) / args.oversub)), max(bounds) + 1)

    print(f"{n_req} requests, {max_new} new each; worst-case "
          f"{sum(bounds)} blocks vs pool {pool} "
          f"({sum(bounds) / pool:.2f}x oversubscribed)")
    rows = {}
    ref_row, ref_toks = _run(model, params, prompts, max_new, max_seq,
                             args.gamma, pool=None, overflow="wait")
    rows["unconstrained"] = ref_row
    for mode in ("preempt", "reject"):
        rows[mode], toks = _run(model, params, prompts, max_new, max_seq,
                                args.gamma, pool=pool, overflow=mode)
        rows[mode]["token_identical"] = all(
            toks[i] == ref_toks[i] for i in toks)
        print(f"  {mode:<9} {rows[mode]['completed_ok']}/{n_req} ok  "
              f"{rows[mode]['tok_s']:>8.1f} tok/s  "
              f"p99 {rows[mode]['p99_latency_s']:.3f}s  "
              f"{rows[mode]['statuses']}")

    out = {
        "config": {"requests": n_req, "max_new": max_new,
                   "gamma": args.gamma, "group": G, "pool_blocks": pool,
                   "oversubscription": round(sum(bounds) / pool, 3),
                   "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        **rows,
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.json}")

    assert rows["preempt"]["token_identical"], \
        "preempt/resume changed greedy outputs"
    if args.smoke:
        assert rows["preempt"]["completed_ok"] == n_req, \
            "preempt mode must complete the whole oversubscribed wave"
        assert rows["preempt"]["preempts"] >= 1, "no preemption exercised"
        assert rows["reject"]["completed_ok"] < n_req, \
            "reject baseline unexpectedly completed everything"
        print("smoke assertions passed: preempt-resume completed "
              f"{rows['preempt']['completed_ok']}/{n_req} token-identical; "
              f"reject baseline completed only "
              f"{rows['reject']['completed_ok']}")


if __name__ == "__main__":
    main()
