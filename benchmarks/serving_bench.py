"""Serving-loop dispatch overhead: per-round loop vs the fused megastep.

    PYTHONPATH=src python benchmarks/serving_bench.py [--smoke]
        [--json BENCH_serving.json]

With the INT4 hot path, chunked prefill, and mesh sharding in place, the
per-round serving loop itself is the bottleneck at small batch: every spec
round pays a device→host sync (tokens + accept counts) plus Python
per-slot bookkeeping before the next round can even be dispatched.  The
megastep driver (``rounds_per_step = K``) fuses K rounds into one jitted
`lax.scan` with device-resident per-slot termination state and reads back
one packed buffer per megastep, double-buffered against the next
megastep's compute.

This benchmark drives BOTH engines over the same requests through

  * the legacy per-round loop  (``rounds_per_step = 0`` — the baseline), and
  * megasteps with K ∈ {1, 2, 4, 8},

and records wall-clock tokens/s plus the engines' own transfer telemetry
(``host_syncs`` blocking device→host transfers, ``decode_steps`` jitted
decode dispatches).  Megastep outputs are asserted token-identical to the
baseline per request (greedy).  Results land in ``BENCH_serving.json``:
the per-round loop pays ~2 syncs *per round*; every megastep row must
report ``syncs_per_step <= 1`` — one transfer per K rounds (asserted in
CI via ``--smoke``).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

sys.path.insert(0, ".")   # repo root (benchmarks.common) when run as a script
sys.path.insert(0, "src")

from benchmarks.common import bench_config, corpus  # noqa: E402
from repro.models.stack import StackModel  # noqa: E402
from repro.serving.engine import ContinuousEngine, Engine  # noqa: E402

K_SWEEP = (1, 2, 4, 8)


def _row(wall_s: float, n_tokens: int, eng) -> dict:
    steps = max(eng.decode_steps, 1)
    return {
        "wall_s": round(wall_s, 4),
        "tok_s": round(n_tokens / max(wall_s, 1e-9), 2),
        "host_syncs": eng.host_syncs,
        "decode_steps": eng.decode_steps,
        "syncs_per_step": round(eng.host_syncs / steps, 4),
    }


def bench_continuous(model, params, prompts, max_new, gamma, max_seq):
    """Legacy loop vs megastep sweep on the continuous engine; returns
    (rows, mismatches)."""
    rows, mismatches = {}, 0
    baseline = None
    for label, k in [("legacy", 0)] + [(f"K={k}", k) for k in K_SWEEP]:
        eng = ContinuousEngine(model, params, gamma=gamma, greedy=True,
                               max_slots=2, max_seq=max_seq,
                               rounds_per_step=k)
        eng.generate(prompts, max_new, key=jax.random.PRNGKey(7))  # warmup
        eng.host_syncs = eng.decode_steps = 0
        t0 = time.perf_counter()
        results = eng.generate(prompts, max_new, key=jax.random.PRNGKey(7))
        wall = time.perf_counter() - t0
        toks = [np.asarray(r.tokens[0]) for r in results]
        if baseline is None:
            baseline = toks
        else:
            mismatches += sum(not np.array_equal(a, b)
                              for a, b in zip(baseline, toks))
        rows[label] = _row(wall, len(prompts) * max_new, eng)
        print(f"  continuous {label:<7} {rows[label]['tok_s']:>8.1f} tok/s  "
              f"{rows[label]['host_syncs']:>4} syncs / "
              f"{rows[label]['decode_steps']} steps")
    return rows, mismatches


def bench_static(model, params, prompt, max_new, gamma, max_seq):
    rows, mismatches = {}, 0
    baseline = None
    B = prompt.shape[0]
    for label, k in [("legacy", 0)] + [(f"K={k}", k) for k in K_SWEEP]:
        eng = Engine(model, params, policy="quantspec", gamma=gamma,
                     greedy=True, max_seq=max_seq, rounds_per_step=k)
        eng.generate(prompt, max_new, key=jax.random.PRNGKey(7))  # warmup
        eng.host_syncs = eng.decode_steps = 0
        t0 = time.perf_counter()
        res = eng.generate(prompt, max_new, key=jax.random.PRNGKey(7))
        wall = time.perf_counter() - t0
        if baseline is None:
            baseline = res.tokens
        elif not np.array_equal(baseline, res.tokens):
            mismatches += 1
        rows[label] = _row(wall, B * max_new, eng)
        print(f"  static     {label:<7} {rows[label]['tok_s']:>8.1f} tok/s  "
              f"{rows[label]['host_syncs']:>4} syncs / "
              f"{rows[label]['decode_steps']} steps")
    return rows, mismatches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI: asserts megastep sync "
                         "counts and token-identity, skips nothing")
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--gamma", type=int, default=3)
    args = ap.parse_args()

    n_req = args.requests or (3 if args.smoke else 6)
    prompt_len = args.prompt_len or (48 if args.smoke else 96)
    max_new = args.max_new or (10 if args.smoke else 32)

    cfg = bench_config()
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))   # dispatch cost, not quality
    G = cfg.group_size
    data = corpus()
    key = jax.random.PRNGKey(3)
    lens = [max(8, prompt_len - 11 * i) for i in range(n_req)]
    prompts = [np.asarray(data.sample(jax.random.fold_in(key, i), 1, s)[0])
               for i, s in enumerate(lens)]
    max_seq = max(lens) + max_new + 2 * G + 8

    print(f"{n_req} requests, prompt lens {lens}, {max_new} new tokens, "
          f"gamma {args.gamma}")
    cont_rows, cont_mis = bench_continuous(model, params, prompts, max_new,
                                           args.gamma, max_seq)
    batch = np.stack([np.resize(p, (max(lens),)) for p in prompts[:2]])
    stat_rows, stat_mis = bench_static(model, params, jax.numpy.asarray(batch),
                                       max_new, args.gamma, max_seq)

    out = {
        "config": {"requests": n_req, "prompt_lens": lens,
                   "max_new": max_new, "gamma": args.gamma,
                   "k_sweep": list(K_SWEEP), "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        "continuous": cont_rows,
        "static": stat_rows,
        "token_identical": cont_mis == 0 and stat_mis == 0,
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.json}")

    best = cont_rows[f"K={K_SWEEP[-1]}"]
    legacy = cont_rows["legacy"]
    print(f"continuous: legacy {legacy['syncs_per_step']:.1f} syncs/round → "
          f"K={K_SWEEP[-1]} {best['syncs_per_step']:.2f} syncs/megastep "
          f"({legacy['host_syncs']}→{best['host_syncs']} total), "
          f"{best['tok_s'] / max(legacy['tok_s'], 1e-9):.2f}x tok/s")
    if not out["token_identical"]:
        raise SystemExit("megastep outputs diverged from the per-round loop")
    for section in ("continuous", "static"):
        for label, row in out[section].items():
            if label.startswith("K=") and row["syncs_per_step"] > 1:
                raise SystemExit(
                    f"{section} {label}: {row['syncs_per_step']} syncs per "
                    f"megastep (expected ≤ 1)")


if __name__ == "__main__":
    main()
