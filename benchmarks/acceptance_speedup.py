"""Paper Table 3 (acceptance / memory / speedup vs sparse-KV baselines),
Table 6 + Figure 9 (γ sweep), and Figure 4 (weight-only vs KV-only vs both).

Acceptance rates are *measured* by running the actual engines on the
CPU-trained benchmark model. End-to-end speedups are *modeled* from bytes
moved per decoding round on the target hardware (TPU v5e, 819 GB/s) — the
decode regime is memory-bound (see arithmetic_intensity.py), so latency ≈
bytes/BW; wall-clock CPU times are reported as a sanity column only.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import corpus, get_trained_model
from repro.launch.mesh import HBM_BW
from repro.serving.engine import Engine

PROMPT = 224
MAX_NEW = 32
GAMMAS = (1, 2, 4, 6)


# ---------------------------------------------------------------------------
# bytes model (per decoded-token latency on the target HW)
# ---------------------------------------------------------------------------

def _weight_bytes(cfg, bits):
    n = cfg.param_count()
    return n * bits / 8


def _kv_bytes(cfg, S, bits, *, residual=0, dtype_bytes=2):
    per_tok = 2 * cfg.num_kv_heads * cfg.hd * cfg.num_layers
    q = max(S - residual, 0)
    return q * per_tok * bits / 8 + min(S, residual) * per_tok * dtype_bytes


def modeled_round_time(cfg, S, gamma, policy, *, w_bits_draft=4,
                       kv_bits_draft=4, draft_budget=None):
    """Seconds per speculative round (γ draft passes + 1 target pass)."""
    R = 2 * cfg.group_size
    t_target = (_weight_bytes(cfg, 16)
                + _kv_bytes(cfg, S, 8, residual=R)) / HBM_BW
    if policy == "quantspec":
        t_draft = (_weight_bytes(cfg, w_bits_draft)
                   + _kv_bytes(cfg, S, kv_bits_draft, residual=R)) / HBM_BW
    else:  # sparse-KV baselines: fp16 weights + sparse fp16 draft cache
        t_draft = (_weight_bytes(cfg, 16)
                   + _kv_bytes(cfg, draft_budget or S // 4, 16)) / HBM_BW
    return gamma * t_draft + t_target


def modeled_ar_time(cfg, S):
    return (_weight_bytes(cfg, 16) + _kv_bytes(cfg, S, 16)) / HBM_BW


def cache_memory_bytes(cfg, S, policy, draft_budget=None):
    R = 2 * cfg.group_size
    if policy == "quantspec":
        return _kv_bytes(cfg, S, 8, residual=R)          # one shared cache
    base = _kv_bytes(cfg, S, 16)                          # fp16 target cache
    if policy in ("streaming", "snapkv"):
        base += _kv_bytes(cfg, draft_budget or S // 4, 16)
    return base


# ---------------------------------------------------------------------------

def measure_acceptance(model, params, prompt, policy, gamma, **kw):
    eng = Engine(model, params, policy=policy, gamma=gamma, greedy=True,
                 max_seq=PROMPT + MAX_NEW + 4 * model.cfg.group_size, **kw)
    res = eng.generate(prompt, MAX_NEW, key=jax.random.PRNGKey(5))
    return res.stats


def induction_fidelity(model, params, prompt, src, n=24):
    """Does full-context greedy generation continue the distant copy?
    (sanity: the discriminative eval only works if the model does induction)"""
    import numpy as np
    eng = Engine(model, params, policy="fp", gamma=0, greedy=True,
                 max_seq=PROMPT + MAX_NEW + 8)
    res = eng.generate(prompt, n, speculative=False)
    lead = 24
    hits = []
    for b in range(prompt.shape[0]):
        want = np.asarray(prompt[b, int(src[b]) + lead:
                                 int(src[b]) + lead + n])
        hits.append((res.tokens[b][: len(want)] == want).mean())
    return float(np.mean(hits))


def run(csv_rows):
    cfg, model, params = get_trained_model()
    # prompts end mid-copy: continuation requires the DISTANT source span —
    # the regime where sparse-KV drafts lose acceptance (paper §5.2)
    prompt, src = corpus().sample_induction(jax.random.PRNGKey(11), 4,
                                            PROMPT, lead=24)
    fid = induction_fidelity(model, params, prompt, src)
    print(f"[sanity] full-context induction fidelity: {fid:.1%} "
          "(target model continues the distant copy)")
    csv_rows.append(("sanity", "induction_fidelity", f"{fid:.3f}"))
    budget = PROMPT // 4
    kw = {
        "quantspec": {},
        "streaming": dict(quantize_weights=False,
                          ctx_kw=dict(draft_window=budget)),
        "snapkv": dict(quantize_weights=False,
                       ctx_kw=dict(draft_budget=budget, draft_window=32,
                                   obs_window=32)),
    }

    # ---- Table 6 / Fig 9: γ sweep -------------------------------------------
    print("\n# Table 6 / Fig 9 — acceptance & modeled speedup vs γ "
          f"(S={PROMPT}, budget={budget})")
    print(f"{'method':<13} {'γ':>2} {'accept%':>8} {'tok/rnd':>8} "
          f"{'speedup_model':>13} {'cpu_s':>7}")
    best = {}
    for policy in ("quantspec", "streaming", "snapkv"):
        for gamma in GAMMAS:
            st = measure_acceptance(model, params, prompt, policy, gamma,
                                    **kw[policy])
            t_round = modeled_round_time(cfg, PROMPT, gamma, policy,
                                         draft_budget=budget)
            sp = st.tokens_per_round * modeled_ar_time(cfg, PROMPT) / t_round
            best[policy] = max(best.get(policy, (0, None)),
                               (sp, (gamma, st)))
            print(f"{policy:<13} {gamma:>2} {st.acceptance_rate:>7.1%} "
                  f"{st.tokens_per_round:>8.2f} {sp:>12.2f}x "
                  f"{st.decode_s:>7.2f}")
            csv_rows.append(
                ("tab6_gamma", f"{policy}_g{gamma}",
                 f"acc={st.acceptance_rate:.3f};speedup={sp:.3f}"))

    # ---- Table 3 analogue: best-γ comparison ---------------------------------
    print("\n# Table 3 — per-method best γ (acceptance, cache memory, speedup)")
    print(f"{'method':<13} {'γ*':>3} {'accept%':>8} {'cacheMB':>8} "
          f"{'speedup':>8}")
    for policy, (sp, (gamma, st)) in best.items():
        mem = cache_memory_bytes(cfg, PROMPT, policy, budget) / 1e6
        print(f"{policy:<13} {gamma:>3} {st.acceptance_rate:>7.1%} "
              f"{mem:>8.2f} {sp:>7.2f}x")
        csv_rows.append(("tab3_best", policy,
                         f"gamma={gamma};acc={st.acceptance_rate:.3f};"
                         f"cache_mb={mem:.2f};speedup={sp:.3f}"))

    # ---- Fig 4: weight vs KV quantization across context length --------------
    print("\n# Fig 4 — modeled speedup: weight-only / KV-only / both "
          "(accept from measured γ=4 run)")
    st = measure_acceptance(model, params, prompt, "quantspec", 4)
    n_round = st.tokens_per_round
    print(f"{'S':>8} {'w-only':>8} {'kv-only':>8} {'both':>8}")
    for S in (4096, 16384, 65536, 262144):
        t_ar = modeled_ar_time(cfg, S)
        sp = {}
        for name, (wb, kb) in (("w-only", (4, 8)), ("kv-only", (16, 4)),
                               ("both", (4, 4))):
            t = modeled_round_time(cfg, S, 4, "quantspec",
                                   w_bits_draft=wb, kv_bits_draft=kb)
            sp[name] = n_round * t_ar / t
        print(f"{S:>8} {sp['w-only']:>7.2f}x {sp['kv-only']:>7.2f}x "
              f"{sp['both']:>7.2f}x")
        csv_rows.append(("fig4_ablation", f"S{S}",
                         ";".join(f"{k}={v:.3f}" for k, v in sp.items())))
    return csv_rows


if __name__ == "__main__":
    run([])
