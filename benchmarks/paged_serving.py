"""Continuous-batching throughput vs the static-batch engine, on the paged
hierarchical KV cache.

    PYTHONPATH=src python benchmarks/paged_serving.py [--requests 6]
        [--slots 2] [--max-new 24]

Protocol: ``--requests`` ragged-length prompts (spread around
``--prompt-len``) served two ways —

  static     : the static `Engine`, one batch-1 run per request (ragged
               prompts can't share a static batch), summed wall time.
  continuous : the `ContinuousEngine` with ``--slots`` slots; requests are
               admitted the moment a slot frees, so short requests retire
               early and the hardware never waits on the longest prompt.

Both decode greedily, so the continuous engine's outputs are checked
**token-identical** per request against the static engine — continuous
batching changes the schedule, not the math (the per-request spec-round
trajectory is exactly a batch-1 run's).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

sys.path.insert(0, ".")   # repo root (benchmarks.common) when run as a script
sys.path.insert(0, "src")

from benchmarks.common import get_trained_model, corpus  # noqa: E402
from repro.serving.engine import ContinuousEngine, Engine  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--train-steps", type=int, default=80)
    args = ap.parse_args()

    cfg, model, params = get_trained_model(steps=args.train_steps)
    G = cfg.group_size
    data = corpus()
    key = jax.random.PRNGKey(3)
    lens = [max(8, args.prompt_len - 11 * i) for i in range(args.requests)]
    prompts = [np.asarray(data.sample(jax.random.fold_in(key, i), 1, s)[0])
               for i, s in enumerate(lens)]
    max_seq = max(lens) + args.max_new + 2 * G + 8

    # ---- static engine: batch-1 per ragged request -------------------------
    static_eng = Engine(model, params, policy="quantspec", gamma=args.gamma,
                        greedy=True, max_seq=max_seq)
    static_tokens = []
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        res = static_eng.generate(jax.numpy.asarray(p)[None], args.max_new,
                                  key=jax.random.PRNGKey(7))
        static_tokens.append(res.tokens[0])
    static_s = time.perf_counter() - t0

    # ---- continuous engine -------------------------------------------------
    ceng = ContinuousEngine(model, params, gamma=args.gamma, greedy=True,
                            max_slots=args.slots, max_seq=max_seq)
    t0 = time.perf_counter()
    results = ceng.generate(prompts, args.max_new, key=jax.random.PRNGKey(7))
    cont_s = time.perf_counter() - t0

    n_tok = args.requests * args.max_new
    mismatches = sum(
        not np.array_equal(results[i].tokens[0], static_tokens[i])
        for i in range(args.requests))
    print(f"\n{args.requests} requests, prompt lens {lens}, "
          f"{args.max_new} new tokens each")
    print(f"{'engine':<12} {'wall_s':>8} {'tok/s':>8}")
    print(f"{'static':<12} {static_s:>8.2f} {n_tok / static_s:>8.1f}")
    print(f"{'continuous':<12} {cont_s:>8.2f} {n_tok / cont_s:>8.1f}  "
          f"({args.slots} slots, speedup {static_s / cont_s:.2f}x)")
    acc = float(np.mean([r.stats.acceptance_rate for r in results]))
    print(f"continuous acceptance {acc:.1%}; "
          f"token-identical to static: {mismatches == 0} "
          f"({args.requests - mismatches}/{args.requests} requests)")
    if mismatches:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
