"""End-to-end training driver: train tiny-lm (~20M params) on the synthetic
corpus for a few hundred steps with checkpointing, then evaluate perplexity
with FP16 vs hierarchical-quantized KV caches — the CPU-scale analogue of
the paper's Table 2 protocol.

    PYTHONPATH=src python examples/train_tiny.py --steps 300
"""

import argparse

import jax

from repro.configs import get_config
from repro.data.pipeline import SyntheticCorpus
from repro.models.stack import StackModel
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="checkpoints/tiny-lm")
    args = ap.parse_args()

    cfg = get_config("tiny-lm").replace(vocab_size=64)
    model = StackModel(cfg, remat=True)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt))

    corpus = SyntheticCorpus(cfg.vocab_size, seed=0, bigram_temp=0.25)
    print(f"corpus bigram entropy floor: {corpus.entropy_floor():.3f} nats")
    it = corpus.batches(args.batch, args.seq)

    for i in range(args.steps):
        params, opt_state, m = step_fn(params, opt_state, next(it))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"ppl {float(m['ppl']):.2f}  gnorm {float(m['grad_norm']):.2f}")

    save_checkpoint(args.ckpt, params, opt_state, step=args.steps,
                    metadata={"config": cfg.name, "vocab": cfg.vocab_size})
    print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
