"""Quickstart: QuantSpec self-speculative decoding on a small model.

    PYTHONPATH=src python examples/quickstart.py

Builds a small dense model, generates with (a) plain autoregressive
decoding and (b) QuantSpec (INT4 draft weights + hierarchical INT4/INT8 KV
cache), and shows that greedy outputs match while QuantSpec emits multiple
tokens per target pass.
"""

import jax

from repro.configs import get_config
from repro.models.stack import StackModel
from repro.serving.engine import Engine


def main():
    cfg = get_config("llama2-7b-32k", smoke=True)
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                                cfg.vocab_size)

    ar = Engine(model, params, policy="quantspec", gamma=0, greedy=True,
                max_seq=256)
    qs = Engine(model, params, policy="quantspec", gamma=4, greedy=True,
                max_seq=256)

    r_ar = ar.generate(prompt, 48, speculative=False)
    r_qs = qs.generate(prompt, 48, speculative=True)

    print("AR tokens      :", r_ar.tokens[0][:24].tolist())
    print("QuantSpec      :", r_qs.tokens[0][:24].tolist())
    print("match          :", (r_ar.tokens == r_qs.tokens).all())
    print(f"acceptance rate: {r_qs.stats.acceptance_rate:.1%}")
    print(f"tokens/round   : {r_qs.stats.tokens_per_round:.2f} "
          f"(AR = 1.00) over {r_qs.stats.rounds} rounds")


if __name__ == "__main__":
    main()
