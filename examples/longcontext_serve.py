"""End-to-end serving driver: batched requests, QuantSpec vs the sparse-KV
self-speculative baselines (StreamingLLM / SnapKV) on a long-ish prompt.

    PYTHONPATH=src python examples/longcontext_serve.py [--prompt-len 512]

Mirrors the paper's Table 3 protocol at CPU scale: same prompts, same
max-new-tokens, per-method acceptance rate and tokens-per-round. The
draft budget of the sparse baselines is matched to QuantSpec's 4-bit
cache (budget = context/4), as in §5.1 of the paper.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticCorpus
from repro.models.stack import StackModel
from repro.serving.engine import ContinuousEngine, Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--prompt-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--gamma", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config("tiny-lm")
    model = StackModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0, bigram_temp=0.25)
    prompt = corpus.sample(jax.random.PRNGKey(1), args.batch,
                           args.prompt_len)
    max_seq = args.prompt_len + args.max_new + 2 * cfg.group_size
    budget = args.prompt_len // 4  # match 4-bit cache bytes (paper §5.1)

    engines = {
        "AR (fp16)": Engine(model, params, policy="fp", gamma=0,
                            greedy=True, max_seq=max_seq),
        "QuantSpec": Engine(model, params, policy="quantspec",
                            gamma=args.gamma, greedy=True, max_seq=max_seq),
        "StreamingLLM": Engine(model, params, policy="streaming", gamma=1,
                               greedy=True, quantize_weights=False,
                               max_seq=max_seq,
                               ctx_kw=dict(draft_window=budget)),
        "SnapKV": Engine(model, params, policy="snapkv", gamma=1,
                         greedy=True, quantize_weights=False,
                         max_seq=max_seq,
                         ctx_kw=dict(draft_budget=budget, draft_window=32,
                                     obs_window=32)),
    }

    print(f"{'method':<14} {'accept%':>8} {'tok/round':>10} {'decode_s':>9}")
    for name, eng in engines.items():
        t0 = time.perf_counter()
        res = eng.generate(prompt, args.max_new, key=jax.random.PRNGKey(7))
        dt = time.perf_counter() - t0
        acc = res.stats.acceptance_rate if res.stats.proposed else float("nan")
        print(f"{name:<14} {acc:>7.1%} {res.stats.tokens_per_round:>10.2f} "
              f"{dt:>9.2f}")

    # continuous batching over the paged cache: ragged prompt lengths,
    # requests admitted/retired between rounds (per-request acceptance)
    ceng = ContinuousEngine(model, params, gamma=args.gamma, greedy=True,
                            max_slots=args.batch, max_seq=max_seq)
    ragged = [np.asarray(prompt[i, : args.prompt_len - 16 * i])
              for i in range(args.batch)]
    t0 = time.perf_counter()
    results = ceng.generate(ragged, args.max_new, key=jax.random.PRNGKey(7))
    dt = time.perf_counter() - t0
    acc = float(np.mean([r.stats.acceptance_rate for r in results]))
    tpr = float(np.mean([r.stats.tokens_per_round for r in results]))
    print(f"{'QS-paged (CB)':<14} {acc:>7.1%} {tpr:>10.2f} {dt:>9.2f}")


if __name__ == "__main__":
    main()
