"""Rule ``pallas``: static sanity checks for every ``pl.pallas_call``.

Three families of checks, all driven from the wrapper function that
builds the call (shapes there are plain Python ints at trace time, so a
small symbolic evaluator over the wrapper's locals goes a long way):

* **grid divisibility** — a grid dimension computed with ``//`` must
  carry evidence that the division is exact (a ``%`` guard in the
  wrapper, a guarded divisor like ``KB = kb if NB % kb == 0 else 1``, a
  ``_block_size``-style helper, or an explicit ceil-div ``-(-a // b)`` /
  ``pl.cdiv`` whose remainder the kernel masks);
* **VMEM footprint** — Σ(BlockSpec block bytes × usage multiplicity) ×
  pipeline factor + scratch bytes against a per-kernel budget, with
  unresolved dimension names bounded by :data:`DIM_BOUNDS`;
* **index_map hygiene** — index maps must be trace-time functions of the
  grid indices and scalar-prefetch refs only: closing over a traced
  array value (an unannotated array parameter, a ``jnp`` intermediate)
  forces a recompile per value or a trace error.

The VMEM table is also exported via :func:`vmem_report` for the CI
artifact and ``docs/static_analysis.md``.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.common import Finding, FuncInfo, Project, attr_chain, call_name, walk_calls

RULE = "pallas"

#: upper bounds for dimension names that cannot be resolved statically.
#: These mirror the serving configs: D ≤ 256 head dim, G ≤ 128 rows per
#: quant block, gT ≤ 512 (GQA replicas × spec window), M ≤ 1024 fused
#: rows (MAX_FUSED_ROWS), TN ≤ 512 matmul tile.
DIM_BOUNDS: Dict[str, int] = {
    "D": 256, "Dp": 128, "G": 128, "gT": 512, "T": 64, "g": 8,
    "M": 1024, "TN": 512, "N": 512, "K": 8192, "KB": 8,
    "BQ": 512, "BK": 512, "bq": 512, "bk": 512, "H": 64, "nh": 64,
}
DEFAULT_DIM_BOUND = 256

#: default per-kernel VMEM budget (bytes). TPU cores have ~16 MiB of
#: VMEM; we keep kernels under 12 MiB to leave headroom for the compiler.
DEFAULT_BUDGET = 12 * 2**20
KERNEL_BUDGETS: Dict[str, int] = {}

#: blocks are double-buffered by the pipeline
PIPELINE_FACTOR = 2

#: itemsize hints by spec/operand name fragment (packed INT4 planes
#: travel as uint8); anything else is costed at 4 bytes (f32 worst case)
ITEMSIZE_HINTS = {"pspec": 1, "packed": 1, "upper": 1, "lower": 1}

_DTYPE_SIZES = {
    "float32": 4, "int32": 4, "uint32": 4, "float16": 2, "bfloat16": 2,
    "int16": 2, "int8": 1, "uint8": 1, "bool_": 1, "float64": 8,
}


# ---------------------------------------------------------------------------
# symbolic int evaluation over a wrapper function's locals
# ---------------------------------------------------------------------------


class _IntEnv:
    def __init__(self, info: FuncInfo, bounds: Dict[str, int]):
        self.info = info
        self.bounds = bounds
        self.assigns: Dict[str, ast.expr] = {}
        self.param_defaults: Dict[str, int] = {}
        self.exact = True  # cleared whenever a bound is substituted
        self._collect()

    def _collect(self) -> None:
        node = self.info.node
        args = node.args
        pos = args.posonlyargs + args.args
        for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
            if isinstance(d, ast.Constant) and isinstance(d.value, int):
                self.param_defaults[a.arg] = d.value
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if isinstance(d, ast.Constant) and isinstance(d.value, int):
                self.param_defaults[a.arg] = d.value
        for sub in ast.walk(node):
            if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                tgt = sub.targets[0]
                if isinstance(tgt, ast.Name):
                    self.assigns[tgt.id] = sub.value
                elif isinstance(tgt, ast.Tuple) and all(
                    isinstance(e, ast.Name) for e in tgt.elts
                ):
                    # `BH, gT, D = q.shape`: bind each name to its bound
                    for e in tgt.elts:
                        self.assigns.setdefault(e.id, None)  # type: ignore[arg-type]

    def eval(self, node: Optional[ast.expr], depth: int = 0) -> Optional[int]:
        if node is None or depth > 12:
            return None
        if isinstance(node, ast.Constant):
            return node.value if isinstance(node.value, int) and not isinstance(node.value, bool) else None
        if isinstance(node, ast.Name):
            return self._eval_name(node.id, depth)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = self.eval(node.operand, depth + 1)
            return -v if v is not None else None
        if isinstance(node, ast.BinOp):
            left = self.eval(node.left, depth + 1)
            right = self.eval(node.right, depth + 1)
            if left is None or right is None:
                return None
            try:
                if isinstance(node.op, ast.Add):
                    return left + right
                if isinstance(node.op, ast.Sub):
                    return left - right
                if isinstance(node.op, ast.Mult):
                    return left * right
                if isinstance(node.op, ast.FloorDiv):
                    return left // right
                if isinstance(node.op, ast.Mod):
                    return left % right
                if isinstance(node.op, ast.Pow):
                    return left**right
            except (ZeroDivisionError, ValueError):
                return None
            return None
        if isinstance(node, ast.IfExp):
            a = self.eval(node.body, depth + 1)
            b = self.eval(node.orelse, depth + 1)
            if a is None or b is None:
                return a if b is None else b
            self.exact = False
            return max(a, b)
        if isinstance(node, ast.Call):
            name = call_name(node) or ""
            vals = [self.eval(a, depth + 1) for a in node.args]
            if name in ("max", "min") and vals and all(v is not None for v in vals):
                return (max if name == "max" else min)(vals)  # type: ignore[arg-type]
            if name in ("pl.cdiv", "cdiv") and len(vals) == 2 and None not in vals:
                return -(-vals[0] // vals[1])  # type: ignore[operator]
            return None
        return None

    def _eval_name(self, name: str, depth: int) -> Optional[int]:
        expr = self.assigns.get(name)
        if expr is not None:
            v = self.eval(expr, depth + 1)
            if v is not None:
                return v
        for source in (self.param_defaults, self.bounds, DIM_BOUNDS):
            if name in source:
                if source is not self.param_defaults:
                    self.exact = False
                return source[name]
        self.exact = False
        return DEFAULT_DIM_BOUND


# ---------------------------------------------------------------------------
# pallas_call site model
# ---------------------------------------------------------------------------


@dataclass
class KernelReport:
    qualname: str
    path: str
    line: int
    est_bytes: Optional[int]
    budget: int
    exact: bool
    detail: List[str] = field(default_factory=list)

    @property
    def over_budget(self) -> bool:
        return self.est_bytes is not None and self.est_bytes > self.budget


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _grid_spec_parts(call: ast.Call) -> Dict[str, Optional[ast.expr]]:
    """Extract grid/in_specs/out_specs/scratch/num_scalar_prefetch from a
    pallas_call, looking through ``grid_spec=PrefetchScalarGridSpec(...)``."""
    parts: Dict[str, Optional[ast.expr]] = {
        "grid": _kwarg(call, "grid"),
        "in_specs": _kwarg(call, "in_specs"),
        "out_specs": _kwarg(call, "out_specs"),
        "scratch_shapes": _kwarg(call, "scratch_shapes"),
        "num_scalar_prefetch": None,
    }
    gs = _kwarg(call, "grid_spec")
    if isinstance(gs, ast.Call):
        for key in parts:
            val = _kwarg(gs, key)
            if val is not None:
                parts[key] = val
    return parts


def _block_spec_calls(info: FuncInfo) -> Dict[str, ast.Call]:
    """Named BlockSpec assignments within the wrapper (incl. loop bodies)."""
    out: Dict[str, ast.Call] = {}
    for sub in ast.walk(info.node):
        if (
            isinstance(sub, ast.Assign)
            and len(sub.targets) == 1
            and isinstance(sub.targets[0], ast.Name)
            and isinstance(sub.value, ast.Call)
            and (call_name(sub.value) or "").endswith("BlockSpec")
        ):
            out[sub.targets[0].id] = sub.value
    return out


def _loop_multiplier(info: FuncInfo, name: str, env: _IntEnv) -> int:
    """If `name` is assigned inside `for _ in range(K)`, usage repeats K times."""
    for sub in ast.walk(info.node):
        if not isinstance(sub, ast.For):
            continue
        assigned_here = any(
            isinstance(s, ast.Assign)
            and any(
                isinstance(t, ast.Name) and t.id == name for t in s.targets
            )
            for s in ast.walk(sub)  # type: ignore[arg-type]
            if isinstance(s, ast.Assign)
        )
        if not assigned_here:
            continue
        it = sub.iter
        if isinstance(it, ast.Call) and (call_name(it) or "") == "range" and it.args:
            k = env.eval(it.args[-1 if len(it.args) == 1 else 1])
            if k is not None and k > 1:
                return k
    return 1


def _itemsize_for(name: str) -> int:
    lowered = name.lower()
    for frag, size in ITEMSIZE_HINTS.items():
        if frag in lowered:
            return size
    return 4


def _dtype_size(node: Optional[ast.expr]) -> int:
    name = (attr_chain(node) or "") if node is not None else ""
    return _DTYPE_SIZES.get(name.split(".")[-1], 4)


def _block_bytes(spec_call: ast.Call, env: _IntEnv, itemsize: int) -> Optional[int]:
    shape = spec_call.args[0] if spec_call.args else _kwarg(spec_call, "block_shape")
    if not isinstance(shape, (ast.Tuple, ast.List)):
        return None
    total = itemsize
    for dim in shape.elts:
        v = env.eval(dim)
        if v is None:
            return None
        total *= max(v, 1)
    return total


def _index_map_of(spec_call: ast.Call) -> Optional[ast.expr]:
    if len(spec_call.args) >= 2:
        return spec_call.args[1]
    return _kwarg(spec_call, "index_map")


# ---------------------------------------------------------------------------
# the three check families
# ---------------------------------------------------------------------------


class _PallasSite:
    def __init__(self, project: Project, info: FuncInfo, call: ast.Call):
        self.project = project
        self.info = info
        self.call = call
        self.env = _IntEnv(info, {})
        self.parts = _grid_spec_parts(call)
        self.findings: List[Finding] = []

    def _flag(self, node: ast.AST, msg: str) -> None:
        self.findings.append(
            Finding(RULE, self.info.file.rel, node.lineno, node.col_offset, msg)
        )

    # -- divisibility ------------------------------------------------------

    def _guarded_names(self) -> Set[str]:
        """Names whose defining expression proves divisibility handling."""
        guarded: Set[str] = set()
        for name, expr in self.env.assigns.items():
            if expr is None:
                continue
            has_mod = any(
                isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod)
                for n in ast.walk(expr)
            )
            calls_helper = any(
                self._helper_has_mod(call_name(c) or "") for c in walk_calls(expr)
            )
            if has_mod or calls_helper:
                guarded.add(name)
        return guarded

    def _helper_has_mod(self, name: str) -> bool:
        target = self.project.functions.get((self.info.file.rel, name))
        if target is None:
            return False
        return any(
            isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod)
            for n in ast.walk(target.node)
        )

    def _is_ceil_div(self, node: ast.BinOp) -> bool:
        # -(-a // b) written as UnaryOp(USub, BinOp(UnaryOp(USub, a) // b))
        return isinstance(node.left, ast.UnaryOp) and isinstance(
            node.left.op, ast.USub
        )

    def check_divisibility(self) -> None:
        grid = self.parts["grid"]
        if grid is None:
            return
        func_has_mod_on = {
            ast.unparse(n.right)
            for n in ast.walk(self.info.node)
            if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod)
        }
        asserts_text = " ".join(
            ast.unparse(s) for s in ast.walk(self.info.node) if isinstance(s, ast.Assert)
        )
        guarded = self._guarded_names()

        def expand(node: ast.expr, depth: int = 0):
            if depth > 6:
                return
            for sub in ast.walk(node):
                if isinstance(sub, ast.Name) and sub.id in self.env.assigns:
                    expr = self.env.assigns[sub.id]
                    if expr is not None and sub.id not in guarded:
                        yield from expand(expr, depth + 1)
                elif isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.FloorDiv):
                    yield sub

        for div in expand(grid):
            if self._is_ceil_div(div):
                continue
            a_txt, b_txt = ast.unparse(div.left), ast.unparse(div.right)
            if b_txt in func_has_mod_on or b_txt in guarded:
                continue
            if isinstance(div.right, ast.Name) and div.right.id in guarded:
                continue
            if b_txt in asserts_text or f"% {b_txt}" in asserts_text:
                continue
            # exact value known and divides cleanly
            a_val, b_val = self.env.eval(div.left), self.env.eval(div.right)
            if (
                a_val is not None
                and b_val not in (None, 0)
                and self.env.exact
                and a_val % b_val == 0  # type: ignore[operator]
            ):
                continue
            self._flag(
                div,
                f"grid dimension `{a_txt} // {b_txt}` has no divisibility "
                "guard — add a `%` check, use a guarded block size, or "
                "ceil-divide and mask the remainder in the kernel",
            )

    # -- index_map hygiene -------------------------------------------------

    _STATIC_GLOBALS = {
        "jnp", "jax", "np", "pl", "pltpu", "lax", "math", "functools", "partial",
    }

    def _static_local(self, name: str) -> bool:
        """Is a wrapper-local name a trace-time Python value (int-ish)?"""
        expr = self.env.assigns.get(name)
        if expr is None:
            # shape-unpack target or unknown: shape dims are static ints
            return name in self.env.assigns or name in DIM_BOUNDS
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Call):
                cname = call_name(sub) or ""
                if cname.startswith(("jnp.", "jax.", "lax.")) and not cname.endswith(
                    ".shape"
                ):
                    return False
        return True

    def _func_params(self) -> Dict[str, Optional[str]]:
        params: Dict[str, Optional[str]] = {}
        a = self.info.node.args
        for arg in a.posonlyargs + a.args + a.kwonlyargs:
            params[arg.arg] = ast.unparse(arg.annotation) if arg.annotation else None
        return params

    def check_index_maps(self, spec_names: Dict[str, ast.Call]) -> None:
        params = self._func_params()
        seen: Set[int] = set()
        for spec_call in list(spec_names.values()) + self._inline_specs():
            if id(spec_call) in seen:
                continue
            seen.add(id(spec_call))
            imap = _index_map_of(spec_call)
            if not isinstance(imap, ast.Lambda):
                continue
            bound = {x.arg for x in imap.args.args + imap.args.posonlyargs}
            for node in ast.walk(imap.body):
                if not isinstance(node, ast.Name) or not isinstance(node.ctx, ast.Load):
                    continue
                name = node.id
                if name in bound or name in self._STATIC_GLOBALS:
                    continue
                if name in params:
                    ann = params[name] or ""
                    if any(t in ann for t in ("int", "bool", "str", "float")):
                        continue
                    self._flag(
                        node,
                        f"index_map closes over parameter `{name}` with no "
                        "static annotation — traced values in index maps "
                        "break pipelining; pass scalars via scalar prefetch",
                    )
                elif name in self.env.assigns and not self._static_local(name):
                    self._flag(
                        node,
                        f"index_map closes over `{name}`, which is computed "
                        "from traced values — use scalar prefetch instead",
                    )

    def _inline_specs(self) -> List[ast.Call]:
        out = []
        for key in ("in_specs", "out_specs"):
            expr = self.parts[key]
            if expr is None:
                continue
            for sub in walk_calls(expr):
                if (call_name(sub) or "").endswith("BlockSpec"):
                    out.append(sub)
        return out

    # -- VMEM footprint ----------------------------------------------------

    def estimate_vmem(self, spec_names: Dict[str, ast.Call]) -> KernelReport:
        budget = KERNEL_BUDGETS.get(self.info.qualname, DEFAULT_BUDGET)
        report = KernelReport(
            qualname=self.info.qualname,
            path=self.info.file.rel,
            line=self.call.lineno,
            est_bytes=None,
            budget=budget,
            exact=True,
        )
        total = 0
        resolved_any = False

        # usage multiplicity: Load occurrences of each named spec anywhere in
        # the wrapper (covers helper-call args and list concatenation), times
        # a range(K) multiplier when the spec is rebuilt per lane in a loop.
        for name, spec_call in spec_names.items():
            uses = sum(
                1
                for n in ast.walk(self.info.node)
                if isinstance(n, ast.Name)
                and n.id == name
                and isinstance(n.ctx, ast.Load)
            )
            if uses == 0:
                continue
            mult = _loop_multiplier(self.info, name, self.env)
            nbytes = _block_bytes(spec_call, self.env, _itemsize_for(name))
            if nbytes is None:
                report.detail.append(f"{name}: unresolved block shape")
                report.exact = False
                continue
            resolved_any = True
            total += nbytes * uses * mult
            report.detail.append(
                f"{name}: {nbytes} B × {uses} use(s)"
                + (f" × {mult} lanes" if mult > 1 else "")
            )

        for spec_call in self._inline_specs():
            if any(spec_call is c for c in spec_names.values()):
                continue
            nbytes = _block_bytes(spec_call, self.env, 4)
            if nbytes is not None:
                resolved_any = True
                total += nbytes
                report.detail.append(f"inline BlockSpec: {nbytes} B")

        total *= PIPELINE_FACTOR

        scratch = self.parts["scratch_shapes"]
        if isinstance(scratch, (ast.List, ast.Tuple)):
            for item in scratch.elts:
                if isinstance(item, ast.Call):
                    shape = item.args[0] if item.args else None
                    size = _dtype_size(item.args[1] if len(item.args) > 1 else None)
                    if isinstance(shape, (ast.Tuple, ast.List)):
                        dims = [self.env.eval(d) for d in shape.elts]
                        if None not in dims:
                            n = size
                            for d in dims:
                                n *= max(d, 1)  # type: ignore[arg-type]
                            total += n
                            resolved_any = True
                            report.detail.append(f"scratch: {n} B")

        if resolved_any:
            report.est_bytes = total
            report.exact = report.exact and self.env.exact
        return report


def collect_sites(project: Project, kernel_dirs: Tuple[str, ...] = ("kernels/",)) -> List[Tuple[FuncInfo, ast.Call]]:
    sites = []
    for (rel, _qual), info in sorted(project.functions.items()):
        if not any(frag in rel for frag in kernel_dirs):
            continue
        for call in walk_calls(info.node):
            if (call_name(call) or "").endswith("pallas_call"):
                sites.append((info, call))
    return sites


def vmem_report(project: Project) -> List[KernelReport]:
    reports = []
    for info, call in collect_sites(project):
        site = _PallasSite(project, info, call)
        reports.append(site.estimate_vmem(_block_spec_calls(info)))
    return reports


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for info, call in collect_sites(project):
        site = _PallasSite(project, info, call)
        spec_names = _block_spec_calls(info)
        site.check_divisibility()
        site.check_index_maps(spec_names)
        report = site.estimate_vmem(spec_names)
        if report.over_budget:
            site._flag(
                call,
                f"estimated VMEM footprint {report.est_bytes} B exceeds the "
                f"{report.budget} B budget for `{info.qualname}` "
                f"({'; '.join(report.detail)})",
            )
        findings.extend(site.findings)
    findings.sort(key=lambda f: (f.path, f.line, f.col))
    return findings
