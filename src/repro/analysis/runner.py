"""Orchestration for ``python -m repro.analysis``."""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.analysis import donation, host_sync, pallas_checks, recompile, sharding_specs
from repro.analysis import baseline as baseline_mod
from repro.analysis.common import Finding, Project, apply_suppressions
from repro.analysis.jit_registry import JitRegistry

ALL_RULES = ("host-sync", "donation", "sharding-spec", "pallas", "recompile")


def run_checks(project: Project, rules: Sequence[str]) -> List[Finding]:
    registry = JitRegistry(project)
    findings: List[Finding] = []
    if "host-sync" in rules:
        findings.extend(host_sync.check(project, registry))
    if "donation" in rules:
        findings.extend(donation.check(project, registry))
    if "sharding-spec" in rules:
        findings.extend(sharding_specs.check(project))
    if "pallas" in rules:
        findings.extend(pallas_checks.check(project))
    if "recompile" in rules:
        findings.extend(recompile.check(project, registry))
    return apply_suppressions(project, findings)


def analyze_paths(
    paths: Sequence[Path],
    root: Optional[Path] = None,
    rules: Sequence[str] = ALL_RULES,
) -> List[Finding]:
    root = root or Path.cwd()
    project = Project.load(paths, root)
    return run_checks(project, rules)


def format_vmem_report(project: Project) -> str:
    lines = [
        f"{'kernel':<38} {'file:line':<42} {'est VMEM':>12} {'budget':>10}  status",
        "-" * 110,
    ]
    for rep in pallas_checks.vmem_report(project):
        est = "unresolved" if rep.est_bytes is None else f"{rep.est_bytes / 2**20:.2f} MiB"
        approx = "" if rep.exact else "~"
        status = "OVER" if rep.over_budget else "ok"
        lines.append(
            f"{rep.qualname:<38} {rep.path + ':' + str(rep.line):<42} "
            f"{approx + est:>12} {rep.budget / 2**20:>8.1f} MiB  {status}"
        )
        for det in rep.detail:
            lines.append(f"    {det}")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: static hot-path invariant checks "
        "(host-sync, donation, sharding-spec, pallas, recompile).",
    )
    parser.add_argument("paths", nargs="*", default=["src"], help="files or directories")
    parser.add_argument("--json", action="store_true", help="machine-readable output")
    parser.add_argument(
        "--rules",
        default=",".join(ALL_RULES),
        help=f"comma-separated subset of: {', '.join(ALL_RULES)}",
    )
    parser.add_argument(
        "--baseline",
        default=baseline_mod.DEFAULT_BASELINE,
        help="baseline file of accepted findings (default: %(default)s)",
    )
    parser.add_argument(
        "--write-baseline",
        action="store_true",
        help="record current findings into the baseline and exit 0",
    )
    parser.add_argument(
        "--vmem-report",
        action="store_true",
        help="print the per-kernel Pallas VMEM budget table",
    )
    args = parser.parse_args(argv)

    rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    unknown = [r for r in rules if r not in ALL_RULES]
    if unknown:
        parser.error(f"unknown rule(s): {', '.join(unknown)}")

    root = Path.cwd()
    paths = [Path(p) for p in args.paths]
    project = Project.load(paths, root)
    findings = run_checks(project, rules)

    if args.vmem_report:
        print(format_vmem_report(project))
        if not args.json:
            print()

    baseline_path = Path(args.baseline)
    if args.write_baseline:
        n = baseline_mod.save(baseline_path, project, findings)
        print(f"repro-lint: wrote {n} finding(s) to {baseline_path}")
        return 0

    known = baseline_mod.load(baseline_path)
    fresh, matched = baseline_mod.subtract(project, findings, known)

    if args.json:
        payload: Dict[str, object] = {
            "findings": [
                {
                    "rule": f.rule,
                    "path": f.path,
                    "line": f.line,
                    "col": f.col,
                    "message": f.message,
                }
                for f in fresh
            ],
            "baselined": matched,
            "checked_files": len(project.files),
        }
        print(json.dumps(payload, indent=2))
    else:
        for f in fresh:
            print(f.render())
        summary = (
            f"repro-lint: {len(fresh)} finding(s) in {len(project.files)} file(s)"
        )
        if matched:
            summary += f" ({matched} baselined)"
        print(summary, file=sys.stderr)

    return 1 if fresh else 0
