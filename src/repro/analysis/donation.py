"""Rule ``donation``: use-after-donate of buffers passed to donated argnums.

``jax.jit(..., donate_argnums=...)`` invalidates the caller's buffer the
moment the call is dispatched; any later read of the same binding sees
deleted memory and raises (or silently copies, defeating the donation).
This rule finds, for every call through a jit binding constructed with
``donate_argnums``:

* reads of a donated binding after the call, before it is reassigned;
* donated carries inside loops that are never refreshed before the next
  iteration re-donates them.

Bindings are matched textually (``state``, ``self.table``) within the
calling function; aliases created from jitted attributes
(``mega_fn = self._mega``) are resolved through the jit registry.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, FuncInfo, Project, attr_chain
from repro.analysis.jit_registry import JitRegistry, JitSite

RULE = "donation"


@dataclass
class _Linear:
    """A function body flattened to source order, with loop extents."""

    stmts: List[ast.stmt]
    #: for each loop statement: (start index, end index) of its body in `stmts`
    loop_spans: List[Tuple[ast.stmt, int, int]]


def _linearize(body: Sequence[ast.stmt]) -> _Linear:
    out = _Linear(stmts=[], loop_spans=[])

    def visit(stmts: Sequence[ast.stmt]) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            out.stmts.append(stmt)
            if isinstance(stmt, (ast.For, ast.While)):
                start = len(out.stmts)
                visit(stmt.body)
                out.loop_spans.append((stmt, start, len(out.stmts)))
                visit(stmt.orelse)
            elif isinstance(stmt, ast.If):
                visit(stmt.body)
                visit(stmt.orelse)
            elif isinstance(stmt, ast.With):
                visit(stmt.body)
            elif isinstance(stmt, ast.Try):
                visit(stmt.body)
                for handler in stmt.handlers:
                    visit(handler.body)
                visit(stmt.orelse)
                visit(stmt.finalbody)

    visit(body)
    return out


def _expr_key(node: ast.expr) -> Optional[str]:
    """Track donations of plain names and attribute chains only."""
    if isinstance(node, (ast.Name, ast.Attribute)):
        return attr_chain(node)
    return None


def _own_parts(stmt: ast.stmt) -> Tuple[List[ast.AST], List[ast.expr]]:
    """(read roots, store targets) directly owned by a statement.

    Compound statements contribute only their header expressions — their
    bodies appear separately in the linearized list, so walking the whole
    node would double-count nested statements.
    """
    if isinstance(stmt, ast.Assign):
        return [stmt.value], list(stmt.targets)
    if isinstance(stmt, ast.AugAssign):
        return [stmt.value, stmt.target], [stmt.target]
    if isinstance(stmt, ast.AnnAssign):
        return ([stmt.value] if stmt.value else []), [stmt.target]
    if isinstance(stmt, ast.For):
        return [stmt.iter], [stmt.target]
    if isinstance(stmt, (ast.While, ast.If)):
        return [stmt.test], []
    if isinstance(stmt, ast.With):
        reads: List[ast.AST] = [i.context_expr for i in stmt.items]
        stores = [i.optional_vars for i in stmt.items if i.optional_vars]
        return reads, stores
    if isinstance(stmt, ast.Try):
        return [], []
    if isinstance(stmt, ast.Delete):
        return [], list(stmt.targets)
    return [stmt], []


def _stores(stmt: ast.stmt) -> Set[str]:
    """Binding keys written by this statement (assignment targets, for targets)."""
    written: Set[str] = set()

    def add_target(t: Optional[ast.expr]) -> None:
        if t is None:
            return
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                add_target(e)
        elif isinstance(t, ast.Starred):
            add_target(t.value)
        else:
            key = _expr_key(t)
            if key:
                written.add(key)

    for t in _own_parts(stmt)[1]:
        add_target(t)  # type: ignore[arg-type]
    return written


def _reads(stmt: ast.stmt, keys: Set[str]) -> List[Tuple[str, ast.expr]]:
    """Occurrences of tracked keys read (Load context) within a statement.

    Store targets are walked too: writing *into* a donated buffer
    (``x[i] = v``) reads the deleted array and must flag.
    """
    reads, stores = _own_parts(stmt)
    hits: List[Tuple[str, ast.expr]] = []
    for root in list(reads) + list(stores):
        for node in ast.walk(root):
            if isinstance(node, (ast.Name, ast.Attribute)) and isinstance(
                getattr(node, "ctx", None), ast.Load
            ):
                key = attr_chain(node)
                if key in keys:
                    hits.append((key, node))
    # attribute loads nest: `self.state.foo` reports both; keep outermost only
    seen: Set[int] = set()
    uniq = []
    for key, node in hits:
        if id(node) in seen:
            continue
        for sub in ast.walk(node):
            if sub is not node:
                seen.add(id(sub))
        uniq.append((key, node))
    return uniq


class _FunctionDonationCheck:
    def __init__(self, project: Project, registry: JitRegistry, info: FuncInfo):
        self.project = project
        self.registry = registry
        self.info = info
        self.findings: List[Finding] = []

    def run(self) -> List[Finding]:
        linear = _linearize(self.info.node.body)
        aliases = self._collect_aliases(linear)
        for idx, stmt in enumerate(linear.stmts):
            for root in _own_parts(stmt)[0]:
                for call in ast.walk(root):
                    if isinstance(call, ast.Call):
                        site = self._donating_site(call, aliases)
                        if site is not None:
                            self._check_call(linear, idx, stmt, call, site)
        return self.findings

    def _collect_aliases(self, linear: _Linear) -> Dict[str, str]:
        """Local names bound to jitted attributes: ``round_fn = self._round``."""
        aliases: Dict[str, str] = {}
        for stmt in linear.stmts:
            if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                continue
            tgt, val = stmt.targets[0], stmt.value
            pairs: List[Tuple[ast.expr, ast.expr]] = []
            if isinstance(tgt, ast.Tuple) and isinstance(val, ast.Tuple) and len(
                tgt.elts
            ) == len(val.elts):
                pairs = list(zip(tgt.elts, val.elts))
            else:
                pairs = [(tgt, val)]
            for t, v in pairs:
                if isinstance(t, ast.Name):
                    src = _expr_key(v)
                    if src and self._lookup(src) is not None:
                        aliases[t.id] = src
                    elif t.id in aliases:
                        del aliases[t.id]
        return aliases

    def _lookup(self, name: str) -> Optional[JitSite]:
        return self.registry.lookup(self.info.file.rel, self.info.qualname, name)

    def _donating_site(
        self, call: ast.Call, aliases: Dict[str, str]
    ) -> Optional[JitSite]:
        name = attr_chain(call.func)
        if not name:
            return None
        name = aliases.get(name, name)
        site = self._lookup(name)
        if site is not None and site.donate_argnums:
            return site
        return None

    def _check_call(
        self,
        linear: _Linear,
        idx: int,
        stmt: ast.stmt,
        call: ast.Call,
        site: JitSite,
    ) -> None:
        donated: Dict[str, ast.expr] = {}
        for argnum in site.donate_argnums:
            if argnum < len(call.args):
                key = _expr_key(call.args[argnum])
                if key:
                    donated[key] = call.args[argnum]
        if not donated:
            return
        live = set(donated)
        # the containing statement's own targets refresh bindings immediately
        live -= _stores(stmt)

        def scan(span: Sequence[ast.stmt], include_call_stmt_reads: bool = False):
            nonlocal live
            for s in span:
                if not live:
                    return
                for key, node in _reads(s, live):
                    if s is stmt and not include_call_stmt_reads:
                        continue
                    self.findings.append(
                        Finding(
                            RULE,
                            self.info.file.rel,
                            node.lineno,
                            node.col_offset,
                            f"`{key}` read after being donated to jitted call "
                            f"at line {call.lineno} (donate_argnums="
                            f"{site.donate_argnums} on {site.file_rel}:{site.lineno})",
                        )
                    )
                    live.discard(key)
                live -= _stores(s)

        scan(linear.stmts[idx + 1 :])
        # wrap-around: a donated carry must be refreshed before the loop repeats
        for loop, start, end in linear.loop_spans:
            if start <= idx < end and live:
                scan(linear.stmts[start : idx + 1], include_call_stmt_reads=True)

    # ------------------------------------------------------------------


def check(project: Project, registry: JitRegistry) -> List[Finding]:
    findings: List[Finding] = []
    for info in project.functions.values():
        findings.extend(_FunctionDonationCheck(project, registry, info).run())
    findings.sort(key=lambda f: (f.path, f.line, f.col))
    return findings
