"""Rule ``host-sync``: host synchronization inside the serving steady state.

QuantSpec's decode loop is only bandwidth-bound while the host never
blocks on device values mid-stream: the contract since the megastep PR
is **at most one host sync per megastep** (the harvest ``device_get``).
This rule finds every host-blocking materialization reachable from the
engine drive loops:

* ``jax.device_get(...)`` / ``jax.block_until_ready(...)`` — always flagged.
* ``<x>.item()`` — always flagged.
* ``int(x)`` / ``float(x)`` / ``np.asarray(x)`` / ``np.array(x)`` — flagged
  only when ``x`` is (heuristically) a device value: results of jitted
  calls or ``jnp`` ops, device-resident ``self`` attributes, and a small
  list of conventional device parameter names. Values already pulled to
  host via ``device_get`` are tracked and never re-flagged.

Reachability starts from the engine entry points (``Engine.generate``,
``ContinuousEngine.run/step``) and follows a conservative call graph,
including through ``jax.jit`` bindings, so a ``device_get`` added deep in
``core/spec_decode.py`` or ``core/host_tier.py`` still fires. Findings
are only reported inside the steady-state scope files; annotate the
deliberate boundary syncs with ``# lint: ok(host-sync, <reason>)``.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, FuncInfo, Project, attr_chain, call_name, walk_calls
from repro.analysis.jit_registry import JitRegistry

RULE = "host-sync"

#: calls that block the host unconditionally
SYNC_ALWAYS = {"jax.device_get", "jax.block_until_ready"}
#: conversions that block only when fed a device value
CONVERTERS = {"int", "float", "np.asarray", "np.array", "numpy.asarray", "numpy.array"}
#: call prefixes whose results live on device
DEVICE_CALL_PREFIXES = ("jnp.", "lax.", "jax.lax.", "jax.random.", "jax.numpy.")
DEVICE_CALLS = {"jax.device_put"}
#: conventional device-array parameter names in the engine/tier methods
DEVICE_PARAM_NAMES = {
    "state", "table", "last", "last_token", "planes", "logits", "packed",
    "slots", "slots_dev", "stream_pos", "generated", "budget", "meta",
    "k", "v", "key", "tokens_dev", "scratch",
}


@dataclass
class HostSyncConfig:
    #: (path suffix, qualname) pairs where steady-state execution starts
    roots: Tuple[Tuple[str, str], ...] = (
        ("serving/engine.py", "Engine.generate"),
        ("serving/engine.py", "ContinuousEngine.run"),
        ("serving/engine.py", "ContinuousEngine.step"),
    )
    #: only functions in these files produce findings
    scope: Tuple[str, ...] = (
        "serving/engine.py",
        "core/host_tier.py",
        "core/spec_decode.py",
    )


def _find_roots(project: Project, cfg: HostSyncConfig) -> List[FuncInfo]:
    roots = []
    for suffix, qual in cfg.roots:
        for (rel, q), info in project.functions.items():
            if rel.endswith(suffix) and q == qual:
                roots.append(info)
    return roots


def _jit_callees(project: Project, registry: JitRegistry, info: FuncInfo) -> List[FuncInfo]:
    """Edges through jit bindings: calls/refs to jitted callables reach their targets."""
    out: List[FuncInfo] = []
    rel, qual = info.file.rel, info.qualname
    # jit sites constructed inside this very function (e.g. Engine._mesh_fns)
    for site in registry.sites:
        if site.file_rel == rel and site.scope == qual:
            tgt = registry.resolve_target(site)
            if tgt is not None:
                out.append(tgt)
    # references to jitted bindings (self._mega, module-level fns, local aliases)
    for node in ast.walk(info.node):
        name = None
        if isinstance(node, ast.Attribute):
            name = attr_chain(node)
        elif isinstance(node, ast.Name):
            name = node.id
        if not name:
            continue
        site = registry.lookup(rel, qual, name)
        if site is not None:
            tgt = registry.resolve_target(site)
            if tgt is not None:
                out.append(tgt)
    return out


def _reachable(project: Project, registry: JitRegistry, roots: Sequence[FuncInfo]) -> List[FuncInfo]:
    seen: Dict[Tuple[str, str], FuncInfo] = {}
    stack = list(roots)
    while stack:
        cur = stack.pop()
        key = (cur.file.rel, cur.qualname)
        if key in seen:
            continue
        seen[key] = cur
        stack.extend(project.callees(cur))
        stack.extend(_jit_callees(project, registry, cur))
    return list(seen.values())


def _returns_device(info: FuncInfo) -> bool:
    """One-level summary: does this function's return expression build device values?"""
    for node in ast.walk(info.node):
        if isinstance(node, ast.Return) and node.value is not None:
            for call in walk_calls(node.value):
                name = call_name(call) or ""
                if name.startswith(DEVICE_CALL_PREFIXES) or name in DEVICE_CALLS:
                    return True
    return False


def _class_device_attrs(project: Project, registry: JitRegistry) -> Dict[Tuple[str, str], Set[str]]:
    """Fixpoint: which ``self.X`` attributes hold device values, per class."""
    attrs: Dict[Tuple[str, str], Set[str]] = {}
    summaries = {
        (f.file.rel, f.qualname): _returns_device(f) for f in project.functions.values()
    }
    for _ in range(3):
        changed = False
        for info in project.functions.values():
            if info.cls is None:
                continue
            key = (info.file.rel, info.cls)
            current = attrs.setdefault(key, set())
            analyzer = _FuncAnalyzer(
                project, registry, info, attrs, summaries, collect=False
            )
            for name in analyzer.device_attr_assignments():
                if name not in current:
                    current.add(name)
                    changed = True
        if not changed:
            break
    return attrs


class _FuncAnalyzer:
    """Single forward pass over one function: track host/device bindings, flag syncs."""

    def __init__(
        self,
        project: Project,
        registry: JitRegistry,
        info: FuncInfo,
        class_attrs: Dict[Tuple[str, str], Set[str]],
        summaries: Dict[Tuple[str, str], bool],
        collect: bool = True,
    ):
        self.project = project
        self.registry = registry
        self.info = info
        self.class_attrs = class_attrs
        self.summaries = summaries
        self.collect = collect
        self.findings: List[Finding] = []
        self.env: Dict[str, str] = {}  # name -> "device" | "host"
        for arg in self._all_args(info.node):
            if arg in DEVICE_PARAM_NAMES:
                self.env[arg] = "device"
        self._device_attr_writes: Set[str] = set()

    @staticmethod
    def _all_args(node: ast.FunctionDef) -> List[str]:
        a = node.args
        args = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            args.append(a.vararg.arg)
        return args

    # -- public entry points ----------------------------------------------

    def run(self) -> List[Finding]:
        self._visit_block(self.info.node.body)
        return self.findings

    def device_attr_assignments(self) -> Set[str]:
        self.collect = False
        self._visit_block(self.info.node.body)
        return self._device_attr_writes

    # -- statement walk ----------------------------------------------------

    def _visit_block(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self._visit_stmt(stmt)

    def _visit_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return  # nested defs analyzed via their own FuncInfo if reachable
        if isinstance(stmt, ast.Assign):
            dev = self._eval(stmt.value)
            for tgt in stmt.targets:
                self._bind(tgt, dev)
        elif isinstance(stmt, ast.AugAssign):
            self._eval(stmt.value)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._bind(stmt.target, self._eval(stmt.value))
        elif isinstance(stmt, (ast.Expr, ast.Return)):
            if getattr(stmt, "value", None) is not None:
                self._eval(stmt.value)
        elif isinstance(stmt, ast.For):
            dev = self._eval(stmt.iter)
            self._bind(stmt.target, dev)
            self._visit_block(stmt.body)
            self._visit_block(stmt.orelse)
        elif isinstance(stmt, (ast.While, ast.If)):
            self._eval(stmt.test)
            self._visit_block(stmt.body)
            self._visit_block(stmt.orelse)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self._eval(item.context_expr)
            self._visit_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            self._visit_block(stmt.body)
            for handler in stmt.handlers:
                self._visit_block(handler.body)
            self._visit_block(stmt.orelse)
            self._visit_block(stmt.finalbody)
        elif isinstance(stmt, (ast.Raise, ast.Assert)):
            for sub in ast.iter_child_nodes(stmt):
                if isinstance(sub, ast.expr):
                    self._eval(sub)

    def _bind(self, target: ast.expr, dev: Optional[bool]) -> None:
        if isinstance(target, ast.Name):
            if dev is True:
                self.env[target.id] = "device"
            elif dev is False:
                self.env[target.id] = "host"
            else:
                self.env.pop(target.id, None)
        elif isinstance(target, ast.Attribute):
            chain = attr_chain(target)
            if dev is True and chain and chain.startswith("self.") and "." not in chain[5:]:
                self._device_attr_writes.add(chain[5:])
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, dev)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, dev)

    # -- expression evaluation --------------------------------------------

    def _flag(self, node: ast.AST, message: str) -> None:
        if not self.collect:
            return
        self.findings.append(
            Finding(RULE, self.info.file.rel, node.lineno, node.col_offset, message)
        )

    def _eval(self, node: ast.expr) -> Optional[bool]:
        """Returns True (device), False (host), or None (unknown)."""
        if isinstance(node, ast.Call):
            return self._eval_call(node)
        if isinstance(node, ast.Name):
            state = self.env.get(node.id)
            return {"device": True, "host": False}.get(state)  # type: ignore[return-value]
        if isinstance(node, ast.Attribute):
            chain = attr_chain(node)
            if chain and chain.startswith("self.") and self.info.cls:
                attr = chain[5:].split(".")[0]
                cls_attrs = self.class_attrs.get((self.info.file.rel, self.info.cls), set())
                if attr in cls_attrs:
                    return True
            if node.attr in ("shape", "ndim", "dtype", "size"):
                self._eval(node.value)
                return False
            return self._eval(node.value)
        if isinstance(node, ast.Subscript):
            self._eval(node.slice)
            return self._eval(node.value)
        if isinstance(node, (ast.BinOp,)):
            left, right = self._eval(node.left), self._eval(node.right)
            return True if (left or right) else (False if (left is False and right is False) else None)
        if isinstance(node, ast.UnaryOp):
            return self._eval(node.operand)
        if isinstance(node, (ast.BoolOp,)):
            vals = [self._eval(v) for v in node.values]
            return True if any(v is True for v in vals) else None
        if isinstance(node, ast.Compare):
            vals = [self._eval(node.left)] + [self._eval(c) for c in node.comparators]
            return True if any(v is True for v in vals) else None
        if isinstance(node, ast.IfExp):
            self._eval(node.test)
            a, b = self._eval(node.body), self._eval(node.orelse)
            return True if (a is True or b is True) else None
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            vals = [self._eval(e) for e in node.elts]
            if any(v is True for v in vals):
                return True
            if vals and all(v is False for v in vals):
                return False
            return None
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if k is not None:
                    self._eval(k)
            for v in node.values:
                self._eval(v)
            return None
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return self._eval_comp(node.elt, node.generators)
        if isinstance(node, ast.DictComp):
            self._eval_comp(node.key, node.generators)
            return self._eval_comp(node.value, node.generators)
        if isinstance(node, ast.Starred):
            return self._eval(node.value)
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    self._eval(v.value)
            return False
        if isinstance(node, ast.Lambda):
            return None
        return None

    def _eval_comp(self, elt: ast.expr, generators) -> Optional[bool]:
        saved = dict(self.env)
        for gen in generators:
            dev = self._eval(gen.iter)
            self._bind(gen.target, dev)
            for cond in gen.ifs:
                self._eval(cond)
        result = self._eval(elt)
        self.env = saved
        return result

    def _eval_call(self, node: ast.Call) -> Optional[bool]:
        name = call_name(node) or ""
        arg_dev = [self._eval(a) for a in node.args]
        for kw in node.keywords:
            arg_dev.append(self._eval(kw.value))

        if name in SYNC_ALWAYS:
            self._flag(node, f"`{name}` blocks the host on device work")
            # device_get materializes to host; block_until_ready returns device values
            return name == "jax.block_until_ready"
        if name.endswith(".item") and name not in CONVERTERS:
            self._flag(node, "`.item()` forces a device-to-host transfer")
            return False
        if name in CONVERTERS:
            if any(v is True for v in arg_dev):
                self._flag(
                    node,
                    f"`{name}(...)` on a device value blocks until the result is ready",
                )
            return False
        if name.startswith(DEVICE_CALL_PREFIXES) or name in DEVICE_CALLS:
            return True
        # calls through jitted bindings produce device values
        site = self.registry.lookup(self.info.file.rel, self.info.qualname, name)
        if site is not None:
            return True
        # one-level return summaries for project-local functions
        target = self._resolve_local(name)
        if target is not None and self.summaries.get((target.file.rel, target.qualname)):
            return True
        if name in ("len", "range", "enumerate", "zip", "min", "max", "sum", "time.time",
                    "time.perf_counter", "sorted", "list", "tuple", "dict", "set", "str", "bool"):
            return False
        # unknown call: propagate deviceness from its arguments
        return True if any(v is True for v in arg_dev) else None

    def _resolve_local(self, name: str) -> Optional[FuncInfo]:
        if not name:
            return None
        if name.startswith("self.") and self.info.cls:
            return self.project.functions.get(
                (self.info.file.rel, f"{self.info.cls}.{name[5:]}")
            )
        info = self.project.functions.get((self.info.file.rel, name))
        if info is not None:
            return info
        cands = [f for f in self.project.by_name.get(name.split(".")[-1], ())]
        if len(cands) == 1:
            return cands[0]
        return None


def check(
    project: Project,
    registry: JitRegistry,
    cfg: Optional[HostSyncConfig] = None,
) -> List[Finding]:
    cfg = cfg or HostSyncConfig()
    roots = _find_roots(project, cfg)
    if not roots:
        return []
    reachable = _reachable(project, registry, roots)
    class_attrs = _class_device_attrs(project, registry)
    summaries = {
        (f.file.rel, f.qualname): _returns_device(f) for f in project.functions.values()
    }
    findings: List[Finding] = []
    for info in reachable:
        if not any(info.file.rel.endswith(sfx) for sfx in cfg.scope):
            continue
        findings.extend(
            _FuncAnalyzer(project, registry, info, class_attrs, summaries).run()
        )
    findings.sort(key=lambda f: (f.path, f.line, f.col))
    return findings
