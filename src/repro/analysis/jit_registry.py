"""Registry of ``jax.jit`` construction sites across the project.

Shared by the donation-safety, recompile-hazard, and host-sync checkers.
For every ``<binding> = jax.jit(target, donate_argnums=..., static_*=...)``
assignment we record the binding name (``self._mega`` inside a class, or
a plain local/module name), the resolved target function when it lives in
the analyzed tree, and the static/donated argument positions.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.common import FuncInfo, Project, attr_chain

JIT_NAMES = ("jax.jit", "jit", "api.jit")


@dataclass
class JitSite:
    file_rel: str
    lineno: int
    #: "Class.method" / "func" scope the assignment appears in, "" at module level
    scope: str
    #: binding the jitted callable is stored under ("self._mega", "round_fn", ...)
    binding: Optional[str]
    #: dotted name of the traced target ("megastep", "self._chunk_step", ...)
    target: Optional[str]
    donate_argnums: Tuple[int, ...] = ()
    static_argnums: Tuple[int, ...] = ()
    static_argnames: Tuple[str, ...] = ()
    #: positional args bound via functools.partial before jit sees the fn
    partial_bound: int = 0
    partial_kwargs: Tuple[str, ...] = ()
    #: ast node of the jit(...) call itself
    call: ast.Call = None  # type: ignore[assignment]


def _literal_int_tuple(node: ast.AST) -> Tuple[int, ...]:
    try:
        val = ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return ()
    if isinstance(val, int):
        return (val,)
    if isinstance(val, (tuple, list)) and all(isinstance(v, int) for v in val):
        return tuple(val)
    return ()


def _literal_str_tuple(node: ast.AST) -> Tuple[str, ...]:
    try:
        val = ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return ()
    if isinstance(val, str):
        return (val,)
    if isinstance(val, (tuple, list)) and all(isinstance(v, str) for v in val):
        return tuple(val)
    return ()


def _unwrap_partial(node: ast.AST) -> Tuple[Optional[str], int, Tuple[str, ...]]:
    """Resolve the traced target through ``functools.partial`` wrappers."""
    if isinstance(node, ast.Call):
        name = attr_chain(node.func)
        if name in ("partial", "functools.partial") and node.args:
            inner, bound, kw = _unwrap_partial(node.args[0])
            return inner, bound + len(node.args) - 1, kw + tuple(
                k.arg for k in node.keywords if k.arg
            )
        return name, 0, ()
    return attr_chain(node), 0, ()


class JitRegistry:
    """All jit sites in a project, queryable by binding name."""

    def __init__(self, project: Project):
        self.project = project
        self.sites: List[JitSite] = []
        # (file_rel, scope, binding) -> JitSite
        self.by_binding: Dict[Tuple[str, str, str], JitSite] = {}
        self._scan()

    def _scan(self) -> None:
        for sf in self.project.files:
            self._visit_body(sf, sf.tree.body, "")

    def _visit_body(self, sf, body: Sequence[ast.stmt], scope: str) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                sub = f"{scope}.{stmt.name}" if scope else stmt.name
                self._visit_body(sf, stmt.body, sub)
            elif isinstance(stmt, (ast.If, ast.For, ast.While, ast.With, ast.Try)):
                for attr in ("body", "orelse", "finalbody"):
                    self._visit_body(sf, getattr(stmt, attr, None) or [], scope)
                for handler in getattr(stmt, "handlers", None) or []:
                    self._visit_body(sf, handler.body, scope)
            else:
                self._scan_stmt(sf.rel, scope, stmt)

    def _scan_stmt(self, rel: str, scope: str, stmt: ast.stmt) -> None:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            if attr_chain(node.func) not in JIT_NAMES:
                continue
            site = self._site_from_call(rel, scope, node)
            # binding: the assignment target if the jit call is the RHS
            if (
                isinstance(stmt, ast.Assign)
                and stmt.value is node
                and len(stmt.targets) == 1
            ):
                site.binding = attr_chain(stmt.targets[0])
            self.sites.append(site)
            if site.binding:
                self.by_binding[(rel, scope, site.binding)] = site

    def _site_from_call(self, rel: str, scope: str, call: ast.Call) -> JitSite:
        target, bound, pkw = (None, 0, ())
        if call.args:
            target, bound, pkw = _unwrap_partial(call.args[0])
        donate: Tuple[int, ...] = ()
        static_nums: Tuple[int, ...] = ()
        static_names: Tuple[str, ...] = ()
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                donate = _literal_int_tuple(kw.value)
            elif kw.arg == "static_argnums":
                static_nums = _literal_int_tuple(kw.value)
            elif kw.arg == "static_argnames":
                static_names = _literal_str_tuple(kw.value)
        return JitSite(
            file_rel=rel,
            lineno=call.lineno,
            scope=scope,
            binding=None,
            target=target,
            donate_argnums=donate,
            static_argnums=static_nums,
            static_argnames=static_names,
            partial_bound=bound,
            partial_kwargs=pkw,
            call=call,
        )

    # -- queries -----------------------------------------------------------

    def lookup(self, rel: str, scope: str, binding: str) -> Optional[JitSite]:
        """Find the jit site a binding refers to, searching enclosing scopes.

        A method referring to ``self._mega`` matches an assignment made in
        any method of the same class (``__init__`` typically).
        """
        site = self.by_binding.get((rel, scope, binding))
        if site is not None:
            return site
        if binding.startswith("self."):
            cls = scope.split(".", 1)[0] if scope else ""
            for (f, sc, b), s in self.by_binding.items():
                if f == rel and b == binding and sc.split(".", 1)[0] == cls:
                    return s
        # module-level binding
        return self.by_binding.get((rel, "", binding))

    def jitted_bindings(self, rel: str) -> List[str]:
        return [b for (f, _sc, b), _s in self.by_binding.items() if f == rel]

    def resolve_target(self, site: JitSite) -> Optional[FuncInfo]:
        """Map a jit site's traced target back to a FuncInfo when local."""
        target = site.target
        if target is None:
            return None
        if target.startswith("self."):
            cls = site.scope.split(".", 1)[0] if site.scope else ""
            return self.project.functions.get(
                (site.file_rel, f"{cls}.{target[len('self.'):]}")
            )
        info = self.project.functions.get((site.file_rel, target))
        if info is not None:
            return info
        # fall back to a unique by-name match anywhere in the project
        cands = [
            f
            for f in self.project.by_name.get(target.split(".")[-1], ())
            if f.cls is None
        ]
        if len(cands) == 1:
            return cands[0]
        return None
