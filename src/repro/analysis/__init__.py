"""repro-lint: AST-based static analysis of the hot-path invariants.

Five rules, run via ``python -m repro.analysis [paths...]``:

* ``host-sync``     — host blocking on device values in the serving steady state
* ``donation``      — use-after-donate of ``donate_argnums`` buffers
* ``sharding-spec`` — pytree containers without placement-spec coverage
* ``pallas``        — grid divisibility, VMEM budgets, index_map hygiene
* ``recompile``     — unstable static args, python branches on traced values

Suppress a deliberate site with ``# lint: ok(<rule>, <reason>)`` on the
line (or the line above). See ``docs/static_analysis.md``.
"""

from repro.analysis.common import Finding, Project  # noqa: F401
from repro.analysis.runner import ALL_RULES, analyze_paths, main  # noqa: F401
