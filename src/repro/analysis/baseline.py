"""Committed-baseline support: known findings that don't fail the gate.

The baseline is a JSON file mapping finding fingerprints (rule + path +
line-text hash, line-number independent) to the rendered message at the
time it was recorded. ``python -m repro.analysis --write-baseline``
records the current findings; subsequent runs subtract them. The repo
policy is an **empty baseline** — deliberate sites carry inline
``# lint: ok(rule, reason)`` annotations instead — but the mechanism
exists so a future PR can land a checker tightening without fixing the
whole tree in the same change.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List, Tuple

from repro.analysis.common import Finding, Project

DEFAULT_BASELINE = ".repro-lint-baseline.json"


def load(path: Path) -> Dict[str, str]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text(encoding="utf-8"))
    if not isinstance(data, dict):
        raise ValueError(f"baseline {path} must be a JSON object")
    return {str(k): str(v) for k, v in data.items()}


def save(path: Path, project: Project, findings: Iterable[Finding]) -> int:
    entries = {}
    for f in findings:
        sf = project.by_path.get(f.path)
        text = sf.line_text(f.line) if sf else ""
        entries[f.fingerprint(text)] = f.render()
    path.write_text(
        json.dumps(entries, indent=2, sort_keys=True) + "\n", encoding="utf-8"
    )
    return len(entries)


def subtract(
    project: Project, findings: List[Finding], baseline: Dict[str, str]
) -> Tuple[List[Finding], int]:
    """Split findings into (new, n_baselined)."""
    fresh: List[Finding] = []
    matched = 0
    for f in findings:
        sf = project.by_path.get(f.path)
        text = sf.line_text(f.line) if sf else ""
        if f.fingerprint(text) in baseline:
            matched += 1
        else:
            fresh.append(f)
    return fresh, matched
