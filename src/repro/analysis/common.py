"""Shared infrastructure for the repro-lint static analysis suite.

Everything here is pure-Python ``ast`` tooling: no jax import, so the
analyzer runs in any environment (CI lint job, pre-commit, dev boxes
without an accelerator runtime).

Key pieces:

* :class:`Finding` — one diagnostic, with a stable fingerprint used by
  the committed baseline file.
* :class:`SourceFile` — parsed module + per-line ``# lint: ok(rule,
  reason)`` suppressions.
* :class:`Project` — an index of every analyzed module: functions,
  classes, methods, imports, plus a conservative call graph used by the
  host-sync reachability pass.
"""

from __future__ import annotations

import ast
import hashlib
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*ok\(\s*(?P<rule>[A-Za-z0-9_-]+)\s*,\s*(?P<reason>[^)]+)\)"
)


@dataclass(frozen=True)
class Finding:
    """A single diagnostic emitted by one rule."""

    rule: str
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str

    def fingerprint(self, line_text: str = "") -> str:
        """Stable id for baselining: rule + path + hash of the line text.

        Deliberately excludes the line *number* so pure line moves do not
        invalidate the baseline; the text hash keeps it anchored to the
        offending statement.
        """
        digest = hashlib.sha1(line_text.strip().encode("utf-8")).hexdigest()[:12]
        return f"{self.rule}:{self.path}:{digest}"

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}"


class SourceFile:
    """A parsed python module plus its lint suppressions."""

    def __init__(self, path: Path, root: Path):
        self.path = path
        self.rel = path.relative_to(root).as_posix() if root in path.parents or path == root else path.as_posix()
        self.text = path.read_text(encoding="utf-8")
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))
        # line -> list of (rule, reason)
        self.suppressions: Dict[int, List[Tuple[str, str]]] = {}
        self._collect_suppressions()

    def _collect_suppressions(self) -> None:
        for i, line in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(line)
            if not m:
                continue
            rule = m.group("rule")
            reason = m.group("reason").strip()
            entry = (rule, reason)
            code = line.split("#", 1)[0]
            if code.strip():
                # trailing comment: applies to this line
                self.suppressions.setdefault(i, []).append(entry)
            else:
                # comment-only line: applies to the next line
                self.suppressions.setdefault(i + 1, []).append(entry)

    def is_suppressed(self, rule: str, line: int) -> bool:
        for sup_rule, _reason in self.suppressions.get(line, ()):
            if sup_rule == rule or sup_rule == "all":
                return True
        return False

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""


def iter_py_files(paths: Sequence[Path]) -> Iterable[Path]:
    for p in paths:
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def attr_chain(node: ast.AST) -> Optional[str]:
    """Render ``a.b.c`` attribute chains as a dotted string, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return attr_chain(call.func)


def walk_calls(node: ast.AST) -> Iterable[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            yield sub


@dataclass
class FuncInfo:
    """One function or method definition."""

    file: "SourceFile"
    node: ast.FunctionDef
    qualname: str  # "Class.method" or "func"
    cls: Optional[str] = None

    @property
    def name(self) -> str:
        return self.node.name


@dataclass
class Project:
    """Index over every analyzed module."""

    files: List[SourceFile] = field(default_factory=list)
    # rel path -> SourceFile
    by_path: Dict[str, SourceFile] = field(default_factory=dict)
    # (rel path, qualname) -> FuncInfo
    functions: Dict[Tuple[str, str], FuncInfo] = field(default_factory=dict)
    # bare method/function name -> [FuncInfo]
    by_name: Dict[str, List[FuncInfo]] = field(default_factory=dict)

    @classmethod
    def load(cls, paths: Sequence[Path], root: Path) -> "Project":
        proj = cls()
        for fp in iter_py_files(paths):
            try:
                sf = SourceFile(fp, root)
            except (SyntaxError, UnicodeDecodeError):
                continue
            proj.files.append(sf)
            proj.by_path[sf.rel] = sf
            proj._index(sf)
        return proj

    def _index(self, sf: SourceFile) -> None:
        for node in sf.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_func(sf, node, node.name, None)
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        self._add_func(sf, sub, f"{node.name}.{sub.name}", node.name)

    def _add_func(self, sf: SourceFile, node, qualname: str, clsname: Optional[str]) -> None:
        info = FuncInfo(file=sf, node=node, qualname=qualname, cls=clsname)
        self.functions[(sf.rel, qualname)] = info
        self.by_name.setdefault(node.name, []).append(info)

    # -- conservative call graph -------------------------------------------

    #: method names too generic to resolve by-name across the project
    GENERIC_METHODS = frozenset(
        {
            "get", "put", "pop", "append", "extend", "items", "keys", "values",
            "update", "join", "split", "add", "remove", "clear", "copy", "sort",
            "read", "write", "close", "open", "index", "count", "insert",
            "format", "strip", "startswith", "endswith", "encode", "decode",
            "popleft", "appendleft", "result", "done", "submit", "replace",
        }
    )

    def callees(self, info: FuncInfo) -> List[FuncInfo]:
        """Heuristic out-edges of a function for reachability analysis."""
        out: List[FuncInfo] = []
        for call in walk_calls(info.node):
            fn = call.func
            if isinstance(fn, ast.Name):
                # bare call: module-level function in the same file first
                hit = self.functions.get((info.file.rel, fn.id))
                if hit is not None:
                    out.append(hit)
                else:
                    out.extend(f for f in self.by_name.get(fn.id, ()) if f.cls is None)
            elif isinstance(fn, ast.Attribute):
                meth = fn.attr
                if isinstance(fn.value, ast.Name) and fn.value.id == "self" and info.cls:
                    hit = self.functions.get((info.file.rel, f"{info.cls}.{meth}"))
                    if hit is not None:
                        out.append(hit)
                        continue
                if meth in self.GENERIC_METHODS:
                    continue
                # obj.m(...): link every project method with that name
                out.extend(f for f in self.by_name.get(meth, ()) if f.cls is not None)
        return out

    def reachable(self, roots: Sequence[FuncInfo]) -> List[FuncInfo]:
        seen: Dict[Tuple[str, str], FuncInfo] = {}
        stack = list(roots)
        while stack:
            cur = stack.pop()
            key = (cur.file.rel, cur.qualname)
            if key in seen:
                continue
            seen[key] = cur
            stack.extend(self.callees(cur))
        return list(seen.values())


def apply_suppressions(project: Project, findings: Iterable[Finding]) -> List[Finding]:
    kept = []
    for f in findings:
        sf = project.by_path.get(f.path)
        if sf is not None and sf.is_suppressed(f.rule, f.line):
            continue
        kept.append(f)
    return kept
