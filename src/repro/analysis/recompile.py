"""Rule ``recompile``: silent recompilation and trace-error hazards.

Two families:

* **unstable static args** — call sites of jitted bindings that pass an
  unhashable value (list/dict/set/array literal or constructor) or a
  call-to-call-unstable value (``time.*``, ``random.*``, ``id()``) in a
  ``static_argnums``/``static_argnames`` position. Unhashables raise at
  call time; unstable hashables compile a fresh executable per call.
* **python branches on traced values** — ``if``/``while``/``range``
  driven by a traced argument (or a value derived from one) inside a
  function that is a ``jax.jit`` target. These either fail at trace time
  or, worse, bake one branch into the compiled program. Branches on
  static configuration (``self``/``model``/``cfg``/annotated int/str
  params, ``.shape``, ``is None`` checks) are fine and ignored.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.common import Finding, FuncInfo, Project, attr_chain, call_name
from repro.analysis.jit_registry import JitRegistry, JitSite

RULE = "recompile"

#: parameter names conventionally carrying static python objects
STATIC_PARAM_NAMES = {"self", "cls", "model", "cfg", "config", "mesh", "policy", "tier"}
#: annotation fragments that mark a parameter static-safe to branch on
STATIC_ANN_FRAGMENTS = (
    "int", "str", "bool", "float", "Mesh", "Config", "Model", "Callable",
    "Tuple", "tuple", "Sequence", "List", "Dict", "Optional",
)

UNSTABLE_CALL_PREFIXES = ("time.", "random.", "np.random.", "uuid.", "id")
UNHASHABLE_CTORS = {"list", "dict", "set", "bytearray"}
ARRAYISH_PREFIXES = ("np.", "jnp.", "numpy.", "jax.")


def _is_unhashable_expr(node: ast.expr) -> Optional[str]:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.SetComp,
                         ast.DictComp, ast.GeneratorExp)):
        return "unhashable literal"
    if isinstance(node, ast.Call):
        name = call_name(node) or ""
        if name in UNHASHABLE_CTORS:
            return f"unhashable `{name}(...)`"
        if name.startswith(ARRAYISH_PREFIXES):
            return f"array-valued `{name}(...)` (unhashable)"
        if name == "id" or name.startswith(tuple(p for p in UNSTABLE_CALL_PREFIXES if p != "id")):
            return f"call-to-call-unstable `{name}(...)`"
    return None


def _static_positions(site: JitSite, call: ast.Call) -> List[Tuple[ast.expr, str]]:
    """(expr, why-static) pairs for the static args at a call site."""
    out: List[Tuple[ast.expr, str]] = []
    for num in site.static_argnums:
        if num < len(call.args):
            out.append((call.args[num], f"static_argnums={site.static_argnums}"))
    for kw in call.keywords:
        if kw.arg in site.static_argnames:
            out.append((kw.value, f"static_argnames={site.static_argnames}"))
    return out


def _check_call_sites(project: Project, registry: JitRegistry) -> List[Finding]:
    findings: List[Finding] = []
    static_sites = [s for s in registry.sites if s.static_argnums or s.static_argnames]
    if not static_sites:
        return findings
    for info in project.functions.values():
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            name = attr_chain(node.func)
            if not name:
                continue
            site = registry.lookup(info.file.rel, info.qualname, name)
            if site is None or not (site.static_argnums or site.static_argnames):
                continue
            for expr, why in _static_positions(site, node):
                problem = _is_unhashable_expr(expr)
                if problem:
                    findings.append(
                        Finding(
                            RULE,
                            info.file.rel,
                            expr.lineno,
                            expr.col_offset,
                            f"{problem} passed in a static position ({why}) "
                            f"of jitted `{name}` — unhashables raise, fresh "
                            "objects recompile every call",
                        )
                    )
    return findings


# ---------------------------------------------------------------------------
# traced-branch analysis inside jit targets
# ---------------------------------------------------------------------------


class _TracedBranchCheck:
    def __init__(self, info: FuncInfo, site: JitSite):
        self.info = info
        self.site = site
        self.findings: List[Finding] = []
        self.traced: Set[str] = set()
        self.static: Set[str] = set()
        self._classify_params()

    def _classify_params(self) -> None:
        a = self.info.node.args
        static_idx = set(self.site.static_argnums)
        for i, arg in enumerate(a.posonlyargs + a.args):
            name = arg.arg
            ann = ast.unparse(arg.annotation) if arg.annotation else ""
            if (
                i < self.site.partial_bound
                or i in static_idx
                or name in self.site.static_argnames
                or name in self.site.partial_kwargs
                or name in STATIC_PARAM_NAMES
                or any(frag in ann for frag in STATIC_ANN_FRAGMENTS)
            ):
                self.static.add(name)
            else:
                self.traced.add(name)
        for arg in a.kwonlyargs:
            name = arg.arg
            ann = ast.unparse(arg.annotation) if arg.annotation else ""
            if (
                name in self.site.static_argnames
                or name in self.site.partial_kwargs
                or name in STATIC_PARAM_NAMES
                or any(frag in ann for frag in STATIC_ANN_FRAGMENTS)
                or arg.annotation is None  # kw-only w/o annotation: config knob
            ):
                self.static.add(name)
            else:
                self.traced.add(name)

    # -- tracking ----------------------------------------------------------

    def _involves_traced(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Attribute):
            if node.attr in ("shape", "ndim", "dtype", "size"):
                return False
            return self._involves_traced(node.value)
        if isinstance(node, ast.Subscript):
            return self._involves_traced(node.value) or self._involves_traced(node.slice)
        if isinstance(node, ast.Call):
            name = call_name(node) or ""
            if name in ("len", "isinstance", "getattr", "hasattr", "type", "range"):
                return False
            if name.startswith(("jnp.", "lax.", "jax.lax.", "jax.numpy.")):
                return True
            return any(self._involves_traced(a) for a in node.args) or any(
                self._involves_traced(k.value) for k in node.keywords
            )
        if isinstance(node, ast.Compare):
            ops_are_identity = all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)
            if ops_are_identity:
                return False
            return self._involves_traced(node.left) or any(
                self._involves_traced(c) for c in node.comparators
            )
        if isinstance(node, ast.BoolOp):
            return any(self._involves_traced(v) for v in node.values)
        if isinstance(node, ast.UnaryOp):
            return self._involves_traced(node.operand)
        if isinstance(node, ast.BinOp):
            return self._involves_traced(node.left) or self._involves_traced(node.right)
        if isinstance(node, ast.IfExp):
            return (
                self._involves_traced(node.test)
                or self._involves_traced(node.body)
                or self._involves_traced(node.orelse)
            )
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self._involves_traced(e) for e in node.elts)
        return False

    def _flag(self, node: ast.AST, kind: str, text: str) -> None:
        self.findings.append(
            Finding(
                RULE,
                self.info.file.rel,
                node.lineno,
                node.col_offset,
                f"python {kind} on traced value `{text}` inside jitted "
                f"`{self.info.qualname}` (jit at {self.site.file_rel}:"
                f"{self.site.lineno}) — use lax.cond/select or mark the "
                "argument static",
            )
        )

    def run(self) -> List[Finding]:
        self._walk(self.info.node.body)
        return self.findings

    def _walk(self, body) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested defs (scan bodies, pl.when branches, local helpers):
                # closure captures keep their outer tracedness; the nested
                # def's own params are *unknown* (scan carries are traced but
                # helper closures routinely take static bools), so branches on
                # them are not flagged — precision over recall here.
                inner = _TracedBranchCheck(self.info, self.site)
                params = {
                    a.arg
                    for a in stmt.args.posonlyargs + stmt.args.args + stmt.args.kwonlyargs
                }
                inner.traced = self.traced - params
                inner.static = self.static | params
                inner._walk(stmt.body)
                self.findings.extend(inner.findings)
                continue
            if isinstance(stmt, ast.Assign):
                traced = self._involves_traced(stmt.value)
                for tgt in stmt.targets:
                    self._bind(tgt, traced)
            elif isinstance(stmt, ast.AugAssign):
                if self._involves_traced(stmt.value):
                    key = attr_chain(stmt.target)
                    if key:
                        self.traced.add(key)
            elif isinstance(stmt, (ast.If, ast.While)):
                if self._involves_traced(stmt.test):
                    kind = "branch" if isinstance(stmt, ast.If) else "loop condition"
                    self._flag(stmt, kind, ast.unparse(stmt.test))
                self._walk(stmt.body)
                self._walk(stmt.orelse)
                continue
            elif isinstance(stmt, ast.For):
                it = stmt.iter
                if (
                    isinstance(it, ast.Call)
                    and (call_name(it) or "") == "range"
                    and any(self._involves_traced(a) for a in it.args)
                ):
                    self._flag(stmt, "loop bound", ast.unparse(it))
                self._bind(stmt.target, False)
                self._walk(stmt.body)
                self._walk(stmt.orelse)
                continue
            elif isinstance(stmt, (ast.With,)):
                self._walk(stmt.body)
                continue
            elif isinstance(stmt, ast.Try):
                self._walk(stmt.body)
                for h in stmt.handlers:
                    self._walk(h.body)
                self._walk(stmt.orelse)
                self._walk(stmt.finalbody)
                continue
            # assert on traced values inside jit is also a trace error
            if isinstance(stmt, ast.Assert) and self._involves_traced(stmt.test):
                self._flag(stmt, "assert", ast.unparse(stmt.test))

    def _bind(self, target: ast.expr, traced: bool) -> None:
        if isinstance(target, ast.Name):
            if traced:
                self.traced.add(target.id)
            else:
                self.traced.discard(target.id)
                self.static.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, traced)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, traced)


def check(project: Project, registry: JitRegistry) -> List[Finding]:
    findings = _check_call_sites(project, registry)
    seen_targets: Set[Tuple[str, str]] = set()
    for site in registry.sites:
        target = registry.resolve_target(site)
        if target is None:
            continue
        key = (target.file.rel, target.qualname)
        if key in seen_targets:
            continue
        seen_targets.add(key)
        findings.extend(_TracedBranchCheck(target, site).run())
    findings.sort(key=lambda f: (f.path, f.line, f.col))
    return findings
