"""Rule ``sharding-spec``: pytree containers vs the placement spec walkers.

The mesh path places every long-lived pytree via the walkers in
``distributed/specs.py`` (and the ``out_shardings`` constructions in
``serving/engine.py``). Those walkers rebuild containers **field by
field** — so adding a field to, say, ``PagedKVPool`` without updating
``_cache_spec`` is a guaranteed runtime crash the first time a mesh run
exercises it. This rule makes that a lint error instead:

* every ``NamedTuple`` container defined under ``core/``, ``serving/``
  or ``models/`` must be *mentioned* in a spec module (constructed
  field-wise, isinstance-dispatched, or handled by a blanket
  ``jax.tree.map`` walker) — transient jit-internal plan values are
  annotated ``# lint: ok(sharding-spec, ...)`` on their class line;
* every field-wise construction of a known container inside a spec
  module must pass **exactly** the container's fields: a missing field
  or an unknown/stale kwarg is an error at the construction site.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import List, Optional, Set, Tuple

from repro.analysis.common import Finding, Project, SourceFile, attr_chain

RULE = "sharding-spec"


@dataclass
class ShardingSpecConfig:
    #: containers defined under these path fragments need spec coverage
    container_dirs: Tuple[str, ...] = ("core/", "serving/", "models/")
    #: modules whose constructions/mentions count as spec coverage
    spec_files: Tuple[str, ...] = ("distributed/specs.py", "serving/engine.py")


@dataclass
class Container:
    file: SourceFile
    node: ast.ClassDef
    name: str
    fields: List[str] = field(default_factory=list)


def _is_namedtuple(node: ast.ClassDef) -> bool:
    for base in node.bases:
        name = attr_chain(base) or ""
        if name.split(".")[-1] == "NamedTuple":
            return True
    return False


def _collect_containers(project: Project, cfg: ShardingSpecConfig) -> List[Container]:
    out: List[Container] = []
    for sf in project.files:
        if not any(frag in sf.rel for frag in cfg.container_dirs):
            continue
        for node in sf.tree.body:
            if not (isinstance(node, ast.ClassDef) and _is_namedtuple(node)):
                continue
            c = Container(file=sf, node=node, name=node.name)
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
                    c.fields.append(stmt.target.id)
            out.append(c)
    return out


def check(project: Project, cfg: Optional[ShardingSpecConfig] = None) -> List[Finding]:
    cfg = cfg or ShardingSpecConfig()
    containers = _collect_containers(project, cfg)
    by_name = {c.name: c for c in containers}

    spec_files = [
        sf for sf in project.files if any(sf.rel.endswith(sfx) for sfx in cfg.spec_files)
    ]
    findings: List[Finding] = []
    mentioned: Set[str] = set()

    for sf in spec_files:
        for name, c in by_name.items():
            if re.search(rf"\b{re.escape(name)}\b", sf.text):
                mentioned.add(name)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            cname = (attr_chain(node.func) or "").split(".")[-1]
            c = by_name.get(cname)
            if c is None:
                continue
            kwargs = {kw.arg for kw in node.keywords if kw.arg is not None}
            has_star = any(kw.arg is None for kw in node.keywords)
            npos = len(node.args)
            if has_star and not kwargs and npos == 0:
                continue  # Container(**spec_dict): opaque, skip field check
            covered = set(c.fields[:npos]) | kwargs
            for missing in [f for f in c.fields if f not in covered]:
                if has_star:
                    continue
                findings.append(
                    Finding(
                        RULE,
                        sf.rel,
                        node.lineno,
                        node.col_offset,
                        f"spec construction of `{cname}` is missing field "
                        f"`{missing}` (defined at {c.file.rel}:{c.node.lineno})",
                    )
                )
            for unknown in sorted(kwargs - set(c.fields)):
                findings.append(
                    Finding(
                        RULE,
                        sf.rel,
                        node.lineno,
                        node.col_offset,
                        f"spec construction of `{cname}` passes unknown field "
                        f"`{unknown}` — stale after a container refactor?",
                    )
                )

    for c in containers:
        if c.name not in mentioned:
            findings.append(
                Finding(
                    RULE,
                    c.file.rel,
                    c.node.lineno,
                    c.node.col_offset,
                    f"pytree container `{c.name}` has no placement rule in "
                    f"{'/'.join(cfg.spec_files)} — add a spec walker or annotate "
                    "the class as a transient value",
                )
            )

    findings.sort(key=lambda f: (f.path, f.line, f.col))
    return findings
