"""Mamba-1 selective-SSM mixer (Jamba's recurrent layers).

Prefill/train run a `lax.scan` over time with per-step discretization —
nothing of shape [B, T, d_inner, d_state] is ever materialized, so memory
stays O(B·d_inner·d_state) regardless of sequence length (this is what
makes `long_500k` native for SSM/hybrid archs).

Decode keeps a `MambaCache` (conv tail + SSM state) and returns per-token
state snapshots so the speculative-decoding engine can commit the state at
the acceptance point (SSM analogue of KV-cache rollback; see DESIGN.md §6).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig


class MambaCache(NamedTuple):
    conv: jnp.ndarray  # [B, d_conv-1, din] — trailing conv inputs
    h: jnp.ndarray     # [B, din, d_state]  — SSM state (float32)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    din = cfg.ssm_expand * cfg.d_model
    return MambaCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, din), dtype),
        h=jnp.zeros((batch, din, cfg.d_state), jnp.float32),
    )


def init_mamba_params(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    ds = cfg.d_state
    dt_rank = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 6)
    s = cfg.init_scale
    dt = jnp.dtype(cfg.dtype)
    # S4D-real initialization for A
    a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (din, ds))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * din)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, din)) * s).astype(dt),
        "conv_b": jnp.zeros((din,), dt),
        "x_proj": (jax.random.normal(ks[2], (din, dt_rank + 2 * ds)) * s).astype(dt),
        "dt_w": (jax.random.normal(ks[3], (dt_rank, din)) * s).astype(dt),
        "dt_bias": jnp.full((din,), -4.6, dt),  # softplus^-1(0.01)
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (din, d)) * s).astype(dt),
    }


def _conv_causal(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 history: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x [B, T, din]; history [B, K-1, din]."""
    K = w.shape[0]
    xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out + b


def _ssm_scan(p: dict, xc: jnp.ndarray, dtv: jnp.ndarray, Bm: jnp.ndarray,
              Cm: jnp.ndarray, h0: jnp.ndarray):
    """Selective scan. xc,dtv [B,T,din]; Bm,Cm [B,T,ds]; h0 [B,din,ds].
    Returns (y [B,T,din], h_all [T,B,din,ds])."""
    A = -jnp.exp(p["a_log"])  # [din, ds]

    def step(h, inp):
        xc_t, dt_t, B_t, C_t = inp  # [B,din],[B,din],[B,ds],[B,ds]
        dA = jnp.exp(dt_t[..., None] * A)                     # [B,din,ds]
        dBx = (dt_t * xc_t)[..., None] * B_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, (y, h)

    xs = (jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dtv, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cm, 1, 0).astype(jnp.float32))
    h_last, (ys, h_all) = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + p["d_skip"] * xc.astype(jnp.float32)
    return y, h_all


def apply_mamba(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                cache: MambaCache | None = None, collect: bool = False):
    """x [B, T, d] -> (y [B, T, d], new_cache, snapshots|None).

    cache=None → train/prefill from zero state (cache returned if collect is
    False but a final state is still needed: pass an initialized cache).
    collect=True → also return per-token MambaCache snapshots (decode).
    """
    B, T, d = x.shape
    dt_rank = max(1, math.ceil(d / 16))
    if cache is None:
        cache = init_mamba_cache(cfg, B, x.dtype)

    xz = x @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, "batch", "seq", "ssm_inner")
    xc = jax.nn.silu(_conv_causal(x_in, p["conv_w"].astype(x.dtype),
                                  p["conv_b"].astype(x.dtype), cache.conv))
    proj = xc @ p["x_proj"].astype(x.dtype)
    dt_r = proj[..., :dt_rank]
    Bm = proj[..., dt_rank:dt_rank + cfg.d_state]
    Cm = proj[..., dt_rank + cfg.d_state:]
    dtv = jax.nn.softplus(dt_r @ p["dt_w"].astype(x.dtype)
                          + p["dt_bias"].astype(x.dtype))

    y, h_all = _ssm_scan(p, xc, dtv, Bm, Cm, cache.h)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"].astype(x.dtype)

    # conv history for the next call: last K-1 raw inputs
    K = cfg.d_conv
    hist = jnp.concatenate([cache.conv.astype(x.dtype), x_in], axis=1)[:, -(K - 1):]
    new_cache = MambaCache(conv=hist, h=h_all[-1])

    snaps = None
    if collect:
        # conv history after each token t: inputs [t-K+2 .. t]
        xp = jnp.concatenate([cache.conv.astype(x.dtype), x_in], axis=1)
        idx = jnp.arange(T)[:, None] + jnp.arange(K - 1)[None, :] + 1
        conv_snaps = xp[:, idx]                        # [B, T, K-1, din]
        snaps = MambaCache(conv=jnp.moveaxis(conv_snaps, 1, 0),  # [T,B,K-1,din]
                           h=h_all)                               # [T,B,din,ds]
    return out, new_cache, snaps


def select_snapshot(snaps: MambaCache, idx) -> MambaCache:
    """Commit the state after input token `idx` (0-based)."""
    return MambaCache(conv=jax.lax.dynamic_index_in_dim(snaps.conv, idx, 0, False),
                      h=jax.lax.dynamic_index_in_dim(snaps.h, idx, 0, False))
