"""Mixture-of-Experts channel mixer (qwen3-moe, deepseek-moe, jamba).

Capacity-based **scatter dispatch**: top-k routing, position-in-expert via a
token-axis cumsum, then a unique-index scatter into the `[E, cap, d]` expert
buffer and a gather back. Unlike the classic Mesh-TF one-hot-einsum
dispatch, this costs O(N·K·d) data movement and no fake O(N·E·cap·d) FLOPs,
so roofline numbers from the compiled HLO stay honest at 128-expert scale.

Experts shard over the `model` mesh axis (expert parallelism), the capacity
axis over `data`; under pjit the scatter/gather pair lowers to the
dispatch/return collectives. (A shard_map ragged all-to-all variant is the
documented §Perf follow-up for collective-bound MoE shapes.)

DeepSeek-MoE fine-grained variant: `num_shared_experts` always-on experts
run densely on every token alongside the routed ones.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import apply_mlp, init_mlp_params
from repro.models.config import ModelConfig


def init_moe_params(key, cfg: ModelConfig) -> dict:
    E, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    s = cfg.init_scale

    def init_expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "w_gate": (jax.random.normal(k1, (d, f)) * s).astype(dt),
            "w_up": (jax.random.normal(k2, (d, f)) * s).astype(dt),
            "w_down": (jax.random.normal(k3, (f, d)) * s).astype(dt),
        }

    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * s).astype(jnp.float32),
        "experts": jax.vmap(init_expert)(jax.random.split(ks[1], E)),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp_params(
            ks[2], cfg, d_ff=f * cfg.num_shared_experts)
    return p


def _expert_ffn(p, x):
    g = jax.nn.silu(x @ p["w_gate"])
    return (g * (x @ p["w_up"])) @ p["w_down"]


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.num_experts) + 1
    # large-scale runs round to 128 so the capacity axis shards cleanly
    mult = 128 if n_tokens >= 16384 else 4
    return max(mult, -(-cap // mult) * mult)


def apply_moe(p: dict, cfg: ModelConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, T, d] -> (y [B, T, d], load-balance aux loss scalar)."""
    if cfg.moe_impl == "shard_map":
        from repro.distributed.sharding import current_mesh
        mesh = current_mesh()
        if (mesh is not None and "model" in mesh.axis_names
                and cfg.num_experts % mesh.shape["model"] == 0):
            return _apply_moe_shard_map(p, cfg, x, mesh)
    return _apply_moe_scatter(p, cfg, x)


def _apply_moe_scatter(p: dict, cfg: ModelConfig, x: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, T, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    N = B * T
    cap = moe_capacity(cfg, N)
    xf = x.reshape(N, d)

    logits = xf.astype(jnp.float32) @ p["router"]             # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                    # [N, K]
    top_p = (top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
             ).astype(x.dtype)

    # position-in-expert: top_k experts are distinct within a token, so the
    # slot of pick (n, k) is just the count of earlier tokens routed to e.
    counts = jnp.zeros((N, E), jnp.int32).at[
        jnp.arange(N)[:, None], top_e].add(1)
    cum_excl = jnp.cumsum(counts, axis=0) - counts
    pos = jnp.take_along_axis(cum_excl, top_e, axis=1)        # [N, K]
    keep = pos < cap
    slot = jnp.where(keep, pos, cap - 1)

    vals = xf[:, None, :] * keep[..., None].astype(x.dtype)   # [N, K, d]
    expert_in = jnp.zeros((E, cap, d), x.dtype).at[top_e, slot].add(vals)
    expert_in = constrain(expert_in, "experts", "expert_cap", "embed")

    expert_out = jax.vmap(_expert_ffn)(p["experts"], expert_in)
    expert_out = constrain(expert_out, "experts", "expert_cap", "embed")

    ys = expert_out[top_e, slot]                               # [N, K, d]
    y = jnp.sum(ys * (top_p * keep.astype(x.dtype))[..., None], axis=1)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], xf[None])[0]

    # Switch-style load-balance aux: E * sum_e f_e * P_e
    frac_tokens = jnp.mean(counts.astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(frac_tokens / K * frac_probs)
    return y.reshape(B, T, d), aux


# ---------------------------------------------------------------------------
# explicit expert parallelism (§Perf iteration for collective-bound MoE)
# ---------------------------------------------------------------------------

def _apply_moe_shard_map(p: dict, cfg: ModelConfig, x: jnp.ndarray, mesh
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map expert parallelism.

    Tokens stay sharded over the data axes and x is replicated over `model`,
    so *dispatch needs no communication at all*: each model shard locally
    gathers the tokens routed to its E/m experts, runs them, and a single
    psum over `model` combines per-token outputs. Replaces the baseline's
    all-reduce of the whole [E, cap, d] expert buffer with an all-reduce of
    [N_local, d] — an ~E/K-fold collective-byte reduction.

    Expert weights keep their FSDP sharding over `data` in train mode; the
    local matmul all-gathers them (tiled) like any FSDP layer.
    """
    try:
        from jax import shard_map  # jax >= 0.5
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, T, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    m_size = mesh.shape["model"]
    E_l = E // m_size
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    d_size = 1
    for a in data_axes:
        d_size *= mesh.shape[a]

    batch_axes = data_axes if (d_size > 1 and B % d_size == 0) else ()
    N_l = (B // d_size if batch_axes else B) * T
    cap_l = moe_capacity(cfg, N_l)  # per-expert capacity for local tokens

    # expert weights [E, d_in, d_out]: E over model; FSDP d_in over data
    experts = p["experts"]

    def wspec(leaf):
        fsdp = data_axes if (data_axes and leaf.shape[1] % d_size == 0) else ()
        fs = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
        return P("model", fs, None), bool(fsdp)

    especs = {k: wspec(v) for k, v in experts.items()}
    bspec = batch_axes if len(batch_axes) > 1 else \
        (batch_axes[0] if batch_axes else None)
    xspec = P(bspec, None, None)

    def local_fn(router, w_gate, w_up, w_down, x_l):
        Bl, Tl, _ = x_l.shape
        Nl = Bl * Tl
        xf = x_l.reshape(Nl, d)
        logits = xf.astype(jnp.float32) @ router               # [Nl, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = (top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
                 ).astype(x_l.dtype)

        m_idx = jax.lax.axis_index("model")
        local_e = top_e - m_idx * E_l
        mine = (local_e >= 0) & (local_e < E_l)
        local_e = jnp.clip(local_e, 0, E_l - 1)

        counts = jnp.zeros((Nl, E_l), jnp.int32).at[
            jnp.arange(Nl)[:, None], local_e].add(mine.astype(jnp.int32))
        cum_excl = jnp.cumsum(counts, axis=0) - counts
        pos = jnp.take_along_axis(cum_excl, local_e, axis=1)
        keep = mine & (pos < cap_l)
        slot = jnp.where(keep, pos, cap_l - 1)

        vals = xf[:, None, :] * keep[..., None].astype(x_l.dtype)
        expert_in = jnp.zeros((E_l, cap_l, d), x_l.dtype
                              ).at[local_e, slot].add(vals)

        # FSDP weight all-gather (tiled) where d_in was data-sharded
        def gather(w, was_sharded):
            return jax.lax.all_gather(w, data_axes, axis=1, tiled=True) \
                if was_sharded else w

        wg = gather(w_gate, especs["w_gate"][1])
        wu = gather(w_up, especs["w_up"][1])
        wd = gather(w_down, especs["w_down"][1])
        expert_out = jax.vmap(
            lambda g, u, dn, xi: (jax.nn.silu(xi @ g) * (xi @ u)) @ dn
        )(wg, wu, wd, expert_in)

        ys = expert_out[local_e, slot]
        y_part = jnp.sum(ys * (top_p * keep.astype(x_l.dtype))[..., None],
                         axis=1)
        y = jax.lax.psum(y_part, "model")         # combine across experts

        # load-balance aux from global fractions
        ft_l = jnp.mean(counts.astype(jnp.float32), axis=0)    # [E_l]
        ft = jax.lax.psum(
            jax.lax.dynamic_update_slice(jnp.zeros((E,), jnp.float32),
                                         ft_l, (m_idx * E_l,)), "model")
        fp = jnp.mean(probs, axis=0)
        aux = cfg.router_aux_coef * E * jnp.sum(ft / K * fp)
        if data_axes:
            aux = jax.lax.pmean(aux, data_axes)
        return y.reshape(Bl, Tl, d), aux

    smap_kw = dict(
        mesh=mesh,
        in_specs=(P(), especs["w_gate"][0], especs["w_up"][0],
                  especs["w_down"][0], xspec),
        out_specs=(xspec, P()),
    )
    try:
        smapped = shard_map(local_fn, check_vma=False, **smap_kw)
    except TypeError:  # older jax: the kwarg is check_rep
        smapped = shard_map(local_fn, check_rep=False, **smap_kw)
    y, aux = smapped(
        p["router"], experts["w_gate"], experts["w_up"], experts["w_down"], x)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x.reshape(B * T, d)[None])[0].reshape(
            B, T, d)
    return y, aux
