"""Model configuration schema.

Every architecture is described as a *layer pattern*: an optional head (un-
scanned leading layers), a super-block of `LayerSpec`s scanned `n_repeats`
times, and an optional tail. This lets heterogeneous stacks (gemma3's 5:1
local:global, jamba's 1:7 attn:mamba with alternating MoE, llama-vision's
4:1 self:cross) compile as a single `lax.scan` over super-blocks — compile
time stays O(pattern), not O(depth).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# sequence-mixer kinds
ATTN_FULL = "full"        # causal full attention, hierarchical-quant cache
ATTN_WINDOW = "window"    # sliding-window causal attention, ring cache
ATTN_CROSS = "cross"      # cross-attention to static (image/text) memory
MIX_MAMBA = "mamba"       # selective SSM (jamba)
MIX_RWKV = "rwkv"         # RWKV6 time-mix

# channel-mixer kinds
MLP_DENSE = "dense"
MLP_MOE = "moe"
MLP_RWKV = "rwkv_cm"      # RWKV channel-mix
MLP_NONE = "none"


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = ATTN_FULL
    mlp: str = MLP_DENSE


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # layer pattern ---------------------------------------------------------
    pattern: Tuple[LayerSpec, ...]
    n_repeats: int
    head_layers: Tuple[LayerSpec, ...] = ()
    tail_layers: Tuple[LayerSpec, ...] = ()
    head_dim: Optional[int] = None      # default d_model // num_heads
    # attention -------------------------------------------------------------
    window: int = 1024                  # for ATTN_WINDOW layers
    n_sink: int = 4
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    logit_softcap: float = 0.0
    # MoE ---------------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM ---------------------------------------------------------------------
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    # VLM / audio ---------------------------------------------------------------
    num_image_tokens: int = 0           # cross-attn memory slots (stub frontend)
    num_codebooks: int = 0              # musicgen EnCodec codebooks
    # QuantSpec ---------------------------------------------------------------
    group_size: int = 128               # quant group G (== double-buffer half)
    weight_quant_group: int = 128
    # MoE dispatch implementation:
    #   'scatter'   — pjit scatter into the global [E, cap, d] buffer
    #                 (baseline; SPMD lowers the combine to an all-reduce of
    #                 the full expert buffer)
    #   'shard_map' — explicit expert parallelism: tokens stay data-sharded,
    #                 each model shard dispatches locally to its E/16 experts,
    #                 one psum over `model` combines (§Perf iteration)
    moe_impl: str = "scatter"
    # decode-attention implementation over the hierarchical cache:
    #   'flat'    — dequantize + flatten [NB,G]→[S] (baseline; reshapes a
    #               sharded axis → SPMD involuntary reshard)
    #   'blocked' — keep [NB, G] axes through softmax (§Perf iteration)
    hier_attn_impl: str = "flat"
    hier_deq_dtype: str = "float32"     # dequantized cache dtype (§Perf)
    # numerics ------------------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "float32"
    init_scale: float = 0.02
    # citation (assigned-architecture provenance)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def layers(self) -> Tuple[LayerSpec, ...]:
        return (self.head_layers + self.pattern * self.n_repeats
                + self.tail_layers)

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    @property
    def is_attention_free(self) -> bool:
        kinds = {s.mixer for s in self.layers}
        return not (kinds & {ATTN_FULL, ATTN_WINDOW, ATTN_CROSS})

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for roofline MODEL_FLOPS = 6·N·D) -----------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.hd
        n = self.vocab_size * d * 2  # embed + unembed
        for spec in self.layers:
            if spec.mixer in (ATTN_FULL, ATTN_WINDOW, ATTN_CROSS):
                n += d * hd * (self.num_heads + 2 * self.num_kv_heads)
                n += self.num_heads * hd * d
            elif spec.mixer == MIX_MAMBA:
                din = self.ssm_expand * d
                n += d * din * 2 + din * self.d_conv
                n += din * (2 * self.d_state + 1) + din  # B,C,dt proj + A,D
                n += din * d
            elif spec.mixer == MIX_RWKV:
                n += 4 * d * d + d * d  # r,k,v,g,o projections (approx)
            if spec.mlp == MLP_DENSE:
                n += 3 * d * self.d_ff
            elif spec.mlp == MLP_MOE:
                e = self.top_k if active_only else self.num_experts
                n += 3 * d * self.moe_d_ff * (e + self.num_shared_experts)
                n += d * self.num_experts  # router
            elif spec.mlp == MLP_RWKV:
                n += 2 * d * self.d_ff
        return n
