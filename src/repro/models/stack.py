"""Pattern-stack decoder: one engine for all assigned architectures.

A model is `head_layers + pattern × n_repeats + tail_layers` of `LayerSpec`s
(see models/config.py). The repeated pattern compiles as a single `lax.scan`
over super-blocks with stacked params/caches, so a 100-layer model costs the
same compile time as its pattern.

Three execution modes:
  train   — full-sequence causal forward, no caches (remat-able).
  prefill — forward that also fills the serving caches (hierarchical
            quantization of all but the last G..2G tokens).  Three serve
            shapes: legacy full-sequence, bucket-padded one-shot
            (`RunCtx.prefill_len` — length-masked, compiles per bucket),
            and chunked paged admission (`RunCtx.prefill_chunk` — band
            attention + fused quantize-to-pool, one chunk at a time).
  decode  — T new tokens against the caches; `kv_mode` selects the
            QuantSpec draft (upper-4-bit) or target (INT8) view, or the
            sparse-KV baseline draft caches.

Serving cache policies: 'quantspec' (hierarchical cache, the paper),
'fp' (FP16 autoregressive baseline), 'streaming' (StreamingLLM sink+window
draft over an FP target cache), 'snapkv' (SnapKV selected draft over an FP
target cache).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hier_kv_cache as HC
from repro.core import paged_kv_cache as PC
from repro.distributed.sharding import constrain
from repro.models import common as L
from repro.models import mamba as M
from repro.models import rwkv6 as R
from repro.models.config import (
    ATTN_CROSS,
    ATTN_FULL,
    ATTN_WINDOW,
    MIX_MAMBA,
    MIX_RWKV,
    MLP_DENSE,
    MLP_MOE,
    MLP_NONE,
    MLP_RWKV,
    LayerSpec,
    ModelConfig,
)
from repro.models.moe import apply_moe, init_moe_params


# ---------------------------------------------------------------------------
# per-layer state containers
# ---------------------------------------------------------------------------

class CrossKV(NamedTuple):
    k: jnp.ndarray  # [B, n_mem, Hkv, hd]
    v: jnp.ndarray


class SnapKVCache(NamedTuple):
    """SnapKV draft cache: prefill-selected important tokens + recent ring."""
    sel_k: jnp.ndarray    # [B, budget, H, hd]
    sel_v: jnp.ndarray
    sel_pos: jnp.ndarray  # [B, budget] absolute positions
    recent: HC.WindowKVCache


class AttnState(NamedTuple):
    """Serving state of one attention layer: the primary (target) cache and
    an optional sparse draft cache (baselines only)."""
    primary: Any          # HierKVCache | FullKVCache | WindowKVCache
    draft: Any            # None | WindowKVCache | SnapKVCache


@dataclasses.dataclass(frozen=True)
class RunCtx:
    mode: str                    # 'train' | 'prefill' | 'decode'
    kv_mode: str = "target"      # 'draft' | 'target' (decode only)
    policy: str = "quantspec"    # cache policy
    collect: bool = False        # collect per-token snapshots (decode)
    memory: Optional[jnp.ndarray] = None   # [B, n_mem, d] cross-attn stub
    draft_window: int = 256
    draft_budget: int = 256
    obs_window: int = 32
    # paged policy (continuous batching): pool size for init, and the
    # per-step paging plan (PagedPlan: flush/append decisions + post-step
    # table) computed once by the engine and applied by every layer
    pool_blocks: int = 0
    plan: Optional[PC.PagedPlan] = None
    # precision governor (core/spec_decode.py): per-slot [R] bool lane flag
    # escalating a draft decode's KV read from INT4 (upper nibble) to INT8
    # (both planes); only meaningful when kv_mode == 'draft'
    draft_bits: Optional[jnp.ndarray] = None
    # serve-time prefill:
    #  prefill_len   — valid prompt length of a bucket-padded one-shot
    #                  prefill (quantspec/fp policies); padding past it is
    #                  position-masked, so one compile serves a bucket
    #  prefill_chunk — chunked paged prefill: this chunk's admission plan
    #                  (PrefillChunkStep), computed once by the engine and
    #                  executed by every attention layer
    #  prefill_hist  — dense cached-prefix admission (static int, quantspec
    #                  policy): the first `prefill_hist` tokens' fp K/V are
    #                  pre-seeded in a PrefillScratch riding in state.draft;
    #                  only the prompt suffix runs through the stack (band
    #                  attention over seeded history), and the scratch comes
    #                  back filled for prefix-index capture
    prefill_len: Optional[jnp.ndarray] = None
    prefill_chunk: Optional[PC.PrefillChunkStep] = None
    prefill_hist: Optional[int] = None
    # KV-quantization simulation in full-sequence forward (quality benches):
    # (key_axis, value_axis, bits, residual) e.g. ('channel','token',4,256)
    kv_sim: Optional[tuple] = None


# ---------------------------------------------------------------------------
# layer init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": L.init_norm(cfg)}
    if spec.mixer in (ATTN_FULL, ATTN_WINDOW):
        p["attn"] = L.init_attn_params(k1, cfg)
    elif spec.mixer == ATTN_CROSS:
        p["attn"] = L.init_attn_params(k1, cfg, cross=True)
    elif spec.mixer == MIX_MAMBA:
        p["mamba"] = M.init_mamba_params(k1, cfg)
    elif spec.mixer == MIX_RWKV:
        p["rwkv_tm"] = R.init_tm_params(k1, cfg)
    if spec.mlp != MLP_NONE:
        p["ln2"] = L.init_norm(cfg)
    if spec.mlp == MLP_DENSE:
        p["mlp"] = L.init_mlp_params(k2, cfg)
    elif spec.mlp == MLP_MOE:
        p["moe"] = init_moe_params(k2, cfg)
    elif spec.mlp == MLP_RWKV:
        p["rwkv_cm"] = R.init_cm_params(k2, cfg)
    return p


def init_layer_state(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_blocks: int, ctx: RunCtx, dtype) -> Tuple[Any, Any]:
    """(mixer_state, mlp_state) for serving."""
    H, hd, G = cfg.num_kv_heads, cfg.hd, cfg.group_size
    if ctx.policy == "paged" and spec.mixer != ATTN_FULL:
        raise NotImplementedError(
            "continuous batching (policy='paged') requires a pure "
            f"full-attention stack; got mixer {spec.mixer!r} — window/"
            "recurrent layers keep scalar stream positions")
    mixer: Any = None
    if spec.mixer == ATTN_FULL:
        if ctx.policy == "quantspec":
            primary = HC.init_cache(batch, max_blocks, G, H, hd, dtype)
            draft = None
        elif ctx.policy == "paged":
            # batch = request slots; the shared PageTable lives in the
            # engine (one table serves every layer)
            primary = PC.init_pool(batch, ctx.pool_blocks, G, H, hd, dtype)
            draft = None
        elif ctx.policy == "streaming_only":
            # long-context sub-quadratic mode for pure full-attention archs:
            # the *only* cache is a StreamingLLM sink+window ring
            primary = HC.init_window_cache(
                batch, ctx.draft_window, H, hd, cfg.n_sink, dtype)
            draft = None
        else:
            primary = HC.init_full_cache(
                batch, max_blocks * G + 2 * G, H, hd, dtype)
            if ctx.policy == "streaming":
                draft = HC.init_window_cache(
                    batch, ctx.draft_window, H, hd, cfg.n_sink, dtype)
            elif ctx.policy == "snapkv":
                draft = SnapKVCache(
                    sel_k=jnp.zeros((batch, ctx.draft_budget, H, hd), dtype),
                    sel_v=jnp.zeros((batch, ctx.draft_budget, H, hd), dtype),
                    sel_pos=jnp.zeros((batch, ctx.draft_budget), jnp.int32),
                    recent=HC.init_window_cache(
                        batch, ctx.draft_window, H, hd, 0, dtype))
            else:
                draft = None
        mixer = AttnState(primary=primary, draft=draft)
    elif spec.mixer == ATTN_WINDOW:
        mixer = AttnState(primary=HC.init_window_cache(
            batch, cfg.window, H, hd, cfg.n_sink, dtype), draft=None)
    elif spec.mixer == ATTN_CROSS:
        n_mem = max(cfg.num_image_tokens, 1)
        mixer = CrossKV(k=jnp.zeros((batch, n_mem, H, hd), dtype),
                        v=jnp.zeros((batch, n_mem, H, hd), dtype))
    elif spec.mixer == MIX_MAMBA:
        mixer = M.init_mamba_cache(cfg, batch, dtype)
    elif spec.mixer == MIX_RWKV:
        mixer = R.init_tm_state(cfg, batch, dtype)
    mlp_state = R.init_cm_state(cfg, batch, dtype) if spec.mlp == MLP_RWKV else None
    return (mixer, mlp_state)


# ---------------------------------------------------------------------------
# layer apply
# ---------------------------------------------------------------------------

def _snapkv_select(q, k, v, budget: int, obs: int):
    """SnapKV: score keys by attention mass from the last `obs` queries."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qo = q[:, -obs:].reshape(B, obs, Hkv, g, hd)
    logits = jnp.einsum("bohgd,bshd->bhos", qo.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(float(hd))
    mass = jax.nn.softmax(logits, axis=-1).sum(axis=(1, 2))   # [B, S]
    _, top_idx = jax.lax.top_k(mass, min(budget, S))
    top_idx = jnp.sort(top_idx, axis=-1)
    if top_idx.shape[1] < budget:
        top_idx = jnp.pad(top_idx, ((0, 0), (0, budget - top_idx.shape[1])),
                          constant_values=0)
    sel_k = jnp.take_along_axis(k, top_idx[:, :, None, None], axis=1)
    sel_v = jnp.take_along_axis(v, top_idx[:, :, None, None], axis=1)
    return sel_k, sel_v, top_idx


def _attend_snapkv(q, cache: SnapKVCache, stream_pos, softcap):
    T = q.shape[1]
    q_pos = stream_pos + jnp.arange(T)
    # selected (static) part
    mask_sel = cache.sel_pos[:, None, :] <= q_pos[None, :, None]
    # recent ring part
    W = cache.recent.ring_k.shape[1]
    P = cache.recent.pos
    s = jnp.arange(W)
    ring_pos = P - 1 - ((P - 1 - s) % W)
    ring_valid = (ring_pos >= 0) & (ring_pos < P)
    k = jnp.concatenate([cache.sel_k, cache.recent.ring_k], 1)
    v = jnp.concatenate([cache.sel_v, cache.recent.ring_v], 1)
    mask_ring = (ring_valid[None, :] &
                 (ring_pos[None, None, :] <= q_pos[None, :, None]))
    mask = jnp.concatenate(
        [jnp.broadcast_to(mask_sel, (q.shape[0], T, cache.sel_k.shape[1])),
         jnp.broadcast_to(mask_ring, (q.shape[0], T, W))], axis=-1)
    return L.gqa_attention(q, k.astype(q.dtype), v.astype(q.dtype), mask,
                           softcap)


def apply_mixer(spec: LayerSpec, p: dict, cfg: ModelConfig, h: jnp.ndarray,
                state, ctx: RunCtx, stream_pos):
    """h is post-ln1. Returns (mixer_out, new_state, snaps)."""
    B, T, _ = h.shape
    sc = cfg.logit_softcap

    if spec.mixer in (ATTN_FULL, ATTN_WINDOW, ATTN_CROSS):
        if spec.mixer == ATTN_CROSS:
            q, _, _ = L.project_qkv(p["attn"], cfg, h, None, use_rope=False)
            if ctx.mode == "decode":
                att = L.gqa_attention(q, state.k.astype(q.dtype),
                                      state.v.astype(q.dtype), None, sc)
                return L.attn_out(p["attn"], att), state, None
            mem = ctx.memory
            _, mk, mv = L.project_qkv(p["attn"], cfg, mem, None, use_rope=False)
            att = L.gqa_attention(q, mk, mv, None, sc)
            new_state = CrossKV(mk, mv) if ctx.mode == "prefill" else state
            return L.attn_out(p["attn"], att), new_state, None

        if ctx.mode == "decode":
            sp = jnp.asarray(stream_pos)
            # scalar stream_pos → [T]; per-slot vector [B] → [B, T]
            # (continuous batching: every request at its own position)
            positions = sp[..., None] + jnp.arange(T) if sp.ndim \
                else sp + jnp.arange(T)
        elif ctx.mode == "prefill" and ctx.prefill_chunk is not None:
            positions = ctx.prefill_chunk.pos + jnp.arange(T)
        elif ctx.mode == "prefill" and ctx.prefill_hist is not None:
            # cached-prefix suffix: absolute stream positions past the hit
            positions = ctx.prefill_hist + jnp.arange(T)
        else:
            positions = jnp.arange(T)
        q, k, v = L.project_qkv(p["attn"], cfg, h, positions)
        q = constrain(q, "batch", "seq", "heads", "head_dim")
        # anchor K/V on the kv-head axis so cache writes (dense appends,
        # paged pool flushes, prefill-scratch updates) stay shard-local
        k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
        v = constrain(v, "batch", "seq", "kv_heads", "head_dim")

        if ctx.mode == "train" and ctx.kv_sim is not None:
            from repro.core.quantization import simulate_cache_quant
            k_axis, v_axis, bits, residual = ctx.kv_sim
            k = simulate_cache_quant(k, group=cfg.group_size,
                                     residual=residual, axis=k_axis, bits=bits)
            v = simulate_cache_quant(v, group=cfg.group_size,
                                     residual=residual, axis=v_axis, bits=bits)

        if ctx.mode == "train":
            if spec.mixer == ATTN_WINDOW:
                att = L.window_attention_chunked(q, k, v, cfg.window, sc)
            else:
                att = L.causal_full_attention(q, k, v, sc)
            return L.attn_out(p["attn"], att), None, None

        if ctx.mode == "prefill":
            if spec.mixer == ATTN_WINDOW:
                att = L.window_attention_chunked(q, k, v, cfg.window, sc)
                new = HC.window_append(state.primary, k, v)
                return L.attn_out(p["attn"], att), state._replace(primary=new), None
            if ctx.policy == "paged":
                # chunked paged prefill: this chunk's K/V join the fp
                # scratch, attention runs over the causal band (history
                # from the scratch — numerics match one-shot dense
                # prefill), and the groups the chunk completes are
                # quantized straight into pool blocks (no dense
                # intermediate, no adopt copy)
                step = ctx.prefill_chunk
                if step is None:
                    raise NotImplementedError(
                        "paged prefill is chunked: pass a PrefillChunkStep "
                        "via ctx_kw['prefill_chunk'] (see "
                        "serving.engine.ContinuousEngine)")
                scratch: PC.PrefillScratch = state.draft
                zero = jnp.zeros((), jnp.int32)
                sk = jax.lax.dynamic_update_slice(
                    scratch.k, k.astype(scratch.k.dtype),
                    (zero, step.pos, zero, zero))
                sv = jax.lax.dynamic_update_slice(
                    scratch.v, v.astype(scratch.v.dtype),
                    (zero, step.pos, zero, zero))
                scratch = PC.PrefillScratch(sk, sv)
                att = L.prefill_band_attention(q, sk, sv, step.pos,
                                               step.pos + step.valid, sc)
                pool = PC.apply_prefill_chunk(state.primary, step, scratch)
                return (L.attn_out(p["attn"], att),
                        AttnState(pool, scratch), None)
            if ctx.policy == "quantspec" and ctx.prefill_hist is not None:
                # dense cached-prefix admission (static engine, prefix
                # caching): the scratch in state.draft carries the cached
                # prefix fp K/V in [0, hist); this call sees only the
                # uncached suffix.  Suffix K/V join the scratch, attention
                # runs over the causal band (history included — numerics
                # match a cold full-prompt prefill exactly), and the cache
                # is built from the full fp stream, so the quantized blocks
                # are bit-identical to the cold path's.  The filled scratch
                # rides back in .draft for prefix-index capture.
                hist = ctx.prefill_hist
                scratch: PC.PrefillScratch = state.draft
                sk = scratch.k.at[:, hist:hist + T].set(
                    k.astype(scratch.k.dtype))
                sv = scratch.v.at[:, hist:hist + T].set(
                    v.astype(scratch.v.dtype))
                att = L.prefill_band_attention(q, sk, sv, hist, hist + T, sc)
                new_primary = HC.prefill(state.primary, sk, sv)
                return (L.attn_out(p["attn"], att),
                        AttnState(new_primary, PC.PrefillScratch(sk, sv)),
                        None)
            if ctx.policy in ("quantspec", "fp"):
                # serve-time prefill fast path: flash-prefill kernel on
                # TPU, chunked jnp (the parity oracle) elsewhere; with
                # prefill_len the prompt is bucket-padded + position-masked
                att = L.serve_prefill_attention(q, k, v, ctx.prefill_len, sc)
            else:
                if ctx.prefill_len is not None:
                    raise NotImplementedError(
                        "bucket-padded prefill supports the quantspec/fp "
                        f"policies, not {ctx.policy!r}")
                att = L.causal_full_attention(q, k, v, sc)
            if ctx.policy == "quantspec":
                new_primary = (HC.prefill(state.primary, k, v)
                               if ctx.prefill_len is None else
                               HC.prefill_dynamic(state.primary, k, v,
                                                  ctx.prefill_len))
            elif ctx.policy == "streaming_only":
                new_primary = HC.window_append(state.primary, k, v)
            elif ctx.prefill_len is not None:
                new_primary = HC.full_prefill(state.primary, k, v,
                                              ctx.prefill_len)
            else:
                new_primary = HC.full_append(state.primary, k, v)
            new_draft = state.draft
            if ctx.policy == "streaming":
                new_draft = HC.window_append(state.draft, k, v)
            elif ctx.policy == "snapkv":
                sk, sv, spos = _snapkv_select(q, k, v, ctx.draft_budget,
                                              ctx.obs_window)
                new_draft = SnapKVCache(
                    sel_k=sk, sel_v=sv, sel_pos=spos,
                    recent=HC.window_append(state.draft.recent,
                                            k[:, -1:], v[:, -1:]))
            return (L.attn_out(p["attn"], att),
                    AttnState(new_primary, new_draft), None)

        # ---- decode -------------------------------------------------------
        if spec.mixer == ATTN_WINDOW:
            new = HC.window_append(state.primary, k, v)
            att = L.attend_window(q, new, stream_pos, sc)
            return L.attn_out(p["attn"], att), state._replace(primary=new), None

        if ctx.policy == "quantspec":
            cache = HC.maybe_flush(state.primary, headroom=T)
            cache = HC.append(cache, k, v)
            att = L.attend_hier(q, cache, stream_pos, ctx.kv_mode, sc,
                                impl=cfg.hier_attn_impl,
                                deq_dtype=jnp.dtype(cfg.hier_deq_dtype))
            return L.attn_out(p["attn"], att), AttnState(cache, None), None

        if ctx.policy == "paged":
            # the engine planned this step once (flush decisions + block
            # allocation); each layer executes it on its own pool
            plan = ctx.plan
            pool = PC.apply_step(state.primary, plan.step, k, v)
            att = L.attend_hier_paged(
                q, pool, plan.table, stream_pos, ctx.kv_mode, sc,
                impl=cfg.hier_attn_impl,
                deq_dtype=jnp.dtype(cfg.hier_deq_dtype),
                draft_bits=ctx.draft_bits if ctx.kv_mode == "draft"
                else None)
            return L.attn_out(p["attn"], att), AttnState(pool, None), None

        if ctx.policy == "streaming_only":
            new = HC.window_append(state.primary, k, v)
            att = L.attend_window(q, new, stream_pos, sc)
            return L.attn_out(p["attn"], att), AttnState(new, None), None

        # baselines: target cache always appended; draft cache too
        new_primary = HC.full_append(state.primary, k, v)
        new_draft = state.draft
        if ctx.policy == "streaming":
            new_draft = HC.window_append(state.draft, k, v)
        elif ctx.policy == "snapkv":
            new_draft = state.draft._replace(
                recent=HC.window_append(state.draft.recent, k, v))
        if ctx.kv_mode == "draft" and ctx.policy == "streaming":
            att = L.attend_window(q, new_draft, stream_pos, sc)
        elif ctx.kv_mode == "draft" and ctx.policy == "snapkv":
            att = _attend_snapkv(q, new_draft, stream_pos, sc)
        else:
            att = L.attend_full(q, new_primary, stream_pos, sc)
        return (L.attn_out(p["attn"], att),
                AttnState(new_primary, new_draft), None)

    if spec.mixer == MIX_MAMBA:
        cache = None if ctx.mode == "train" else state
        y, new_state, snaps = M.apply_mamba(p["mamba"], cfg, h, cache,
                                            collect=ctx.collect)
        return y, (None if ctx.mode == "train" else new_state), snaps

    if spec.mixer == MIX_RWKV:
        st = None if ctx.mode == "train" else state
        y, new_state, snaps = R.apply_time_mix(p["rwkv_tm"], cfg, h, st,
                                               collect=ctx.collect)
        return y, (None if ctx.mode == "train" else new_state), snaps

    raise ValueError(spec.mixer)


def apply_layer(spec: LayerSpec, p: dict, cfg: ModelConfig, x: jnp.ndarray,
                state, ctx: RunCtx, stream_pos):
    """Returns (x, new_state, snaps, aux)."""
    mixer_state, mlp_state = state if state is not None else (None, None)
    h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    mix, new_mixer, mix_snaps = apply_mixer(spec, p, cfg, h, mixer_state,
                                            ctx, stream_pos)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    new_mlp, mlp_snaps = mlp_state, None
    if spec.mlp != MLP_NONE:
        h2 = L.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        if spec.mlp == MLP_DENSE:
            x = x + L.apply_mlp(p["mlp"], h2)
        elif spec.mlp == MLP_MOE:
            y, aux = apply_moe(p["moe"], cfg, h2)
            x = x + y
        elif spec.mlp == MLP_RWKV:
            st = None if ctx.mode == "train" else mlp_state
            y, new_cm, mlp_snaps = R.apply_channel_mix(
                p["rwkv_cm"], cfg, h2, st, collect=ctx.collect)
            x = x + y
            new_mlp = None if ctx.mode == "train" else new_cm
    x = constrain(x, "batch", "seq", "embed")
    return x, (new_mixer, new_mlp), (mix_snaps, mlp_snaps), aux


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class StackModel:
    def __init__(self, cfg: ModelConfig, remat: bool = False,
                 scan_unroll: int = 1):
        self.cfg = cfg
        self.remat = remat  # checkpoint each super-block in train mode
        # dry-run sets scan_unroll=n_repeats so XLA cost_analysis (which
        # counts a while body once) sees every layer's FLOPs/bytes
        self.scan_unroll = scan_unroll

    # ---- params -------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        k_embed, k_head, k_blocks, k_tail, k_lm = jax.random.split(key, 5)
        V = cfg.vocab_size
        if cfg.num_codebooks:
            embed = (jax.random.normal(
                k_embed, (cfg.num_codebooks, V, cfg.d_model)) * cfg.init_scale
            ).astype(dt)
            lm_head = (jax.random.normal(
                k_lm, (cfg.d_model, cfg.num_codebooks * V)) * cfg.init_scale
            ).astype(dt)
        else:
            embed = (jax.random.normal(k_embed, (V, cfg.d_model))
                     * cfg.init_scale).astype(dt)
            lm_head = (jax.random.normal(k_lm, (cfg.d_model, V))
                       * cfg.init_scale).astype(dt)
        params = {
            "embed": embed,
            "lm_head": lm_head,
            "final_norm": L.init_norm(cfg),
            "head": tuple(
                init_layer(k, cfg, s) for k, s in
                zip(jax.random.split(k_head, max(len(cfg.head_layers), 1)),
                    cfg.head_layers)),
            "tail": tuple(
                init_layer(k, cfg, s) for k, s in
                zip(jax.random.split(k_tail, max(len(cfg.tail_layers), 1)),
                    cfg.tail_layers)),
            "blocks": tuple(
                jax.vmap(lambda kk, s=spec: init_layer(kk, cfg, s))(
                    jax.random.split(jax.random.fold_in(k_blocks, j),
                                     cfg.n_repeats))
                for j, spec in enumerate(cfg.pattern)
            ) if cfg.n_repeats > 0 else (),
        }
        return params

    # ---- embedding ----------------------------------------------------------
    def embed(self, params, tokens):
        cfg = self.cfg
        if cfg.num_codebooks:
            # tokens [B, T, K] -> sum of codebook embeddings
            embs = jax.vmap(lambda e, t: jnp.take(e, t, axis=0))(
                params["embed"], jnp.moveaxis(tokens, -1, 0))  # [K,B,T,d]
            x = embs.sum(0)
        else:
            x = jnp.take(params["embed"], tokens, axis=0)
        return constrain(x, "batch", "seq", "embed")

    def unembed(self, params, x):
        cfg = self.cfg
        from repro.core.weight_quant import matmul
        logits = matmul(x, params["lm_head"], tp="col")
        if cfg.num_codebooks:
            B, T, _ = logits.shape
            logits = logits.reshape(B, T, cfg.num_codebooks, cfg.vocab_size)
        return constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")

    # ---- stack runner ---------------------------------------------------------
    def _run(self, params, x, states, ctx: RunCtx, stream_pos):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        # "blocks" defaults to () (not None) so a 0-repeat stack's state
        # keeps the init_serve_state structure — decode loops scan over the
        # state and lax.scan requires an exactly matching carry pytree
        new_states = {"head": [], "blocks": (), "tail": []}
        snaps_out = {"head": [], "blocks": None, "tail": []}

        def run_flat(x, layers, specs, lstates, aux_total):
            outs, snps = [], []
            for p, s, st in zip(layers, specs, lstates):
                x, ns, sn, aux = apply_layer(s, p, cfg, x, st, ctx, stream_pos)
                outs.append(ns)
                snps.append(sn)
                aux_total = aux_total + aux
            return x, outs, snps, aux_total

        head_states = (states["head"] if states is not None
                       else [None] * len(cfg.head_layers))
        x, hs, hsn, aux_total = run_flat(
            x, params["head"], cfg.head_layers, head_states, aux_total)
        new_states["head"], snaps_out["head"] = hs, hsn

        if cfg.n_repeats > 0:
            block_states = states["blocks"] if states is not None else None

            def body(carry, xs):
                xc, auxc = carry
                bp = xs[0]
                bst = xs[1] if states is not None else None
                new_bst, new_snp = [], []
                for j, spec in enumerate(cfg.pattern):
                    st = bst[j] if bst is not None else None
                    xc, ns, sn, aux = apply_layer(spec, bp[j], cfg, xc, st,
                                                  ctx, stream_pos)
                    new_bst.append(ns)
                    new_snp.append(sn)
                return (xc, auxc + aux), (tuple(new_bst), tuple(new_snp))

            xs = (params["blocks"], block_states) if states is not None \
                else (params["blocks"],)
            if ctx.mode == "train" and self.remat:
                body = jax.checkpoint(body)
            (x, aux_total), (nbs, nsn) = jax.lax.scan(
                body, (x, aux_total), xs,
                unroll=min(self.scan_unroll, cfg.n_repeats))
            new_states["blocks"] = nbs
            snaps_out["blocks"] = nsn

        tail_states = (states["tail"] if states is not None
                       else [None] * len(cfg.tail_layers))
        x, ts, tsn, aux_total = run_flat(
            x, params["tail"], cfg.tail_layers, tail_states, aux_total)
        new_states["tail"], snaps_out["tail"] = ts, tsn

        x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        return x, new_states, snaps_out, aux_total

    # ---- public entry points ---------------------------------------------------
    def train_logits(self, params, tokens, memory=None, kv_sim=None):
        ctx = RunCtx(mode="train", memory=memory, kv_sim=kv_sim)
        x = self.embed(params, tokens)
        x, _, _, aux = self._run(params, x, None, ctx, 0)
        return self.unembed(params, x), aux

    def init_serve_state(self, batch: int, max_seq: int, policy: str,
                         ctx_kw: Optional[dict] = None, dtype=jnp.float32):
        cfg = self.cfg
        ctx = RunCtx(mode="prefill", policy=policy, **(ctx_kw or {}))
        max_blocks = max(1, -(-max_seq // cfg.group_size))

        def make(spec):
            return init_layer_state(cfg, spec, batch, max_blocks, ctx, dtype)

        state = {
            "head": [make(s) for s in cfg.head_layers],
            "tail": [make(s) for s in cfg.tail_layers],
            "blocks": tuple(
                jax.tree.map(lambda y: jnp.stack([y] * cfg.n_repeats),
                             make(spec))
                for spec in cfg.pattern
            ) if cfg.n_repeats > 0 else (),
        }
        return state

    def prefill(self, params, tokens, state, policy: str = "quantspec",
                memory=None, ctx_kw: Optional[dict] = None):
        ctx = RunCtx(mode="prefill", policy=policy, memory=memory,
                     **(ctx_kw or {}))
        x = self.embed(params, tokens)
        x, new_states, _, _ = self._run(params, x, state, ctx, 0)
        if ctx.prefill_chunk is not None:
            # chunked paged prefill: only the chunk's last *valid* position
            # is ever sampled (by the final chunk), so unembed just that one
            # — not C positions × vocab per chunk
            idx = jnp.maximum(ctx.prefill_chunk.valid - 1, 0)
            xl = jax.lax.dynamic_slice_in_dim(x, idx, 1, axis=1)
            return self.unembed(params, xl), new_states
        if ctx.prefill_len is not None:
            # bucket-padded prompt: the last valid token, not the last slot
            idx = jnp.maximum(jnp.asarray(ctx.prefill_len, jnp.int32) - 1, 0)
            xl = jax.lax.dynamic_slice_in_dim(x, idx, 1, axis=1)
            return self.unembed(params, xl), new_states
        return self.unembed(params, x[:, -1:]), new_states

    def decode(self, params, tokens, state, stream_pos, kv_mode: str,
               policy: str = "quantspec", collect: bool = False,
               ctx_kw: Optional[dict] = None):
        ctx = RunCtx(mode="decode", kv_mode=kv_mode, policy=policy,
                     collect=collect, **(ctx_kw or {}))
        x = self.embed(params, tokens)
        x, new_states, snaps, _ = self._run(params, x, state, ctx, stream_pos)
        return self.unembed(params, x), new_states, snaps

    # ---- speculative-decoding commit ----------------------------------------
    def commit(self, states, snaps, n_accepted, total_appended):
        """After a target verify pass that appended `total_appended` tokens,
        keep the first `n_accepted`+1 of them: attention caches roll back
        `total_appended - n_accepted - 1` entries; recurrent states commit
        the snapshot taken after input `n_accepted`."""
        cfg = self.cfg
        rb = total_appended - n_accepted - 1
        idx = n_accepted

        def commit_one(spec, st, sn, stacked):
            mixer, mlp = st
            msn = sn[0] if sn is not None else None
            lsn = sn[1] if sn is not None else None
            if isinstance(mixer, AttnState):
                primary = mixer.primary
                if isinstance(primary, HC.HierKVCache):
                    primary = HC.rollback(primary, rb)
                elif isinstance(primary, HC.FullKVCache):
                    primary = HC.full_rollback(primary, rb)
                elif isinstance(primary, HC.WindowKVCache):
                    primary = HC.window_rollback(primary, rb)
                draft = mixer.draft
                if isinstance(draft, HC.WindowKVCache):
                    draft = HC.window_rollback(draft, rb)
                elif isinstance(draft, SnapKVCache):
                    draft = draft._replace(
                        recent=HC.window_rollback(draft.recent, rb))
                mixer = AttnState(primary, draft)
            elif isinstance(mixer, HC.WindowKVCache):
                mixer = HC.window_rollback(mixer, rb)
            elif isinstance(mixer, M.MambaCache):
                sel = M.select_snapshot
                mixer = (jax.vmap(sel, in_axes=(0, None))(msn, idx)
                         if stacked else sel(msn, idx))
            elif isinstance(mixer, R.RWKVTMState):
                sel = R.select_tm_snapshot
                mixer = (jax.vmap(sel, in_axes=(0, None))(msn, idx)
                         if stacked else sel(msn, idx))
            if isinstance(mlp, R.RWKVCMState):
                sel = R.select_cm_snapshot
                mlp = (jax.vmap(sel, in_axes=(0, None))(lsn, idx)
                       if stacked else sel(lsn, idx))
            return (mixer, mlp)

        new = {"head": [], "tail": [], "blocks": None}
        for i, spec in enumerate(cfg.head_layers):
            new["head"].append(commit_one(
                spec, states["head"][i], snaps["head"][i], False))
        for i, spec in enumerate(cfg.tail_layers):
            new["tail"].append(commit_one(
                spec, states["tail"][i], snaps["tail"][i], False))
        if cfg.n_repeats > 0:
            new["blocks"] = tuple(
                commit_one(spec, states["blocks"][j],
                           (snaps["blocks"][j] if snaps["blocks"] is not None
                            else None), True)
                for j, spec in enumerate(cfg.pattern))
        return new
