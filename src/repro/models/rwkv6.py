"""RWKV6 ("Finch") — attention-free mixer with data-dependent decay.

Time-mix recurrence per head (state S in R^{hd×hd}):

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u) · k_tᵀ v_t)

with per-channel decay w_t = exp(-exp(w0 + tanh(x̄_t A_w) B_w)) — the
data-dependent decay that distinguishes RWKV6 from RWKV4/5. Token shift is
the learned static lerp μ (the full data-dependent-shift LoRA stack of the
paper is simplified; noted in DESIGN.md).

QuantSpec applicability: no KV cache exists — the paper's hierarchical KV
technique is inapplicable (DESIGN.md §Arch-applicability); self-speculation
still works through INT4 draft weights, and the engine snapshots/commits the
recurrent state exactly like Mamba.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

_LORA_RANK = 64


class RWKVTMState(NamedTuple):
    x_prev: jnp.ndarray  # [B, d] — previous token's input (token shift)
    S: jnp.ndarray       # [B, H, hd, hd] — wkv state (float32)


class RWKVCMState(NamedTuple):
    x_prev: jnp.ndarray  # [B, d]


def init_tm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, hd = cfg.num_heads, cfg.hd
    return RWKVTMState(x_prev=jnp.zeros((batch, cfg.d_model), dtype),
                       S=jnp.zeros((batch, H, hd, hd), jnp.float32))


def init_cm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return RWKVCMState(x_prev=jnp.zeros((batch, cfg.d_model), dtype))


def init_tm_params(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, hd = cfg.num_heads, cfg.hd
    r = _LORA_RANK
    ks = jax.random.split(key, 8)
    s = cfg.init_scale
    dt = jnp.dtype(cfg.dtype)
    n = lambda k, sh: (jax.random.normal(k, sh) * s).astype(dt)
    return {
        "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt), "mu_g": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "wr": n(ks[0], (d, d)), "wk": n(ks[1], (d, d)),
        "wv": n(ks[2], (d, d)), "wg": n(ks[3], (d, d)),
        "wo": n(ks[4], (d, d)),
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w_lora_a": n(ks[5], (d, r)).astype(jnp.float32),
        "w_lora_b": n(ks[6], (r, d)).astype(jnp.float32),
        "u": (jax.random.normal(ks[7], (H, hd)) * s).astype(jnp.float32),
        "ln_scale": jnp.ones((d,), dt),
    }


def init_cm_params(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    s = cfg.init_scale
    dt = jnp.dtype(cfg.dtype)
    n = lambda k, sh: (jax.random.normal(k, sh) * s).astype(dt)
    return {
        "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
        "wr_cm": n(ks[0], (d, d)), "wk_cm": n(ks[1], (d, f)),
        "wv_cm": n(ks[2], (f, d)),
    }


def _shift(x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    """[B,T,d] -> previous-token stream with carried x_prev at t=0."""
    return jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def _lerp(x, x_shift, mu):
    return x + (x_shift - x) * mu.astype(x.dtype)


def apply_time_mix(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                   state: RWKVTMState | None = None, collect: bool = False):
    """x [B, T, d] -> (y, new_state, snapshots|None)."""
    B, T, d = x.shape
    H, hd = cfg.num_heads, cfg.hd
    if state is None:
        state = init_tm_state(cfg, B, x.dtype)
    xs = _shift(x, state.x_prev)

    def heads(t):
        return t.reshape(B, T, H, hd)

    r = heads(_lerp(x, xs, p["mu_r"]) @ p["wr"].astype(x.dtype))
    k = heads(_lerp(x, xs, p["mu_k"]) @ p["wk"].astype(x.dtype))
    v = heads(_lerp(x, xs, p["mu_v"]) @ p["wv"].astype(x.dtype))
    g = _lerp(x, xs, p["mu_g"]) @ p["wg"].astype(x.dtype)
    xw = _lerp(x, xs, p["mu_w"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]))
    w = w.reshape(B, T, H, hd)

    u = p["u"]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,hd] each, float32
        kv = k_t[..., :, None] * v_t[..., None, :]           # [B,H,hd,hd]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, (y, S)

    xs_scan = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                    for t in (r, k, v, w))
    S_last, (ys, S_all) = jax.lax.scan(step, state.S, xs_scan)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, d)

    # per-head group norm then gate
    y = y * jax.lax.rsqrt(jnp.mean(
        y.reshape(B, T, H, hd) ** 2, -1, keepdims=True) + cfg.norm_eps
    ).reshape(B, T, H, 1).repeat(hd, -1).reshape(B, T, d)
    y = (y * p["ln_scale"].astype(jnp.float32)).astype(x.dtype)
    out = (y * jax.nn.silu(g)) @ p["wo"].astype(x.dtype)

    new_state = RWKVTMState(x_prev=x[:, -1], S=S_last)
    snaps = None
    if collect:
        snaps = RWKVTMState(x_prev=jnp.moveaxis(x, 1, 0), S=S_all)
    return out, new_state, snaps


def apply_channel_mix(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                      state: RWKVCMState | None = None, collect: bool = False):
    B, T, d = x.shape
    if state is None:
        state = init_cm_state(cfg, B, x.dtype)
    xs = _shift(x, state.x_prev)
    kk = jnp.square(jax.nn.relu(_lerp(x, xs, p["mu_k"]) @ p["wk_cm"].astype(x.dtype)))
    out = jax.nn.sigmoid(_lerp(x, xs, p["mu_r"]) @ p["wr_cm"].astype(x.dtype)) \
        * (kk @ p["wv_cm"].astype(x.dtype))
    new_state = RWKVCMState(x_prev=x[:, -1])
    snaps = RWKVCMState(x_prev=jnp.moveaxis(x, 1, 0)) if collect else None
    return out, new_state, snaps


def select_tm_snapshot(snaps: RWKVTMState, idx) -> RWKVTMState:
    return RWKVTMState(
        x_prev=jax.lax.dynamic_index_in_dim(snaps.x_prev, idx, 0, False),
        S=jax.lax.dynamic_index_in_dim(snaps.S, idx, 0, False))


def select_cm_snapshot(snaps: RWKVCMState, idx) -> RWKVCMState:
    return RWKVCMState(
        x_prev=jax.lax.dynamic_index_in_dim(snaps.x_prev, idx, 0, False))
