"""Shared layers: norms, RoPE, linear, attention (train + decode over every
cache type), dense MLP.

Attention conventions: activations are ``[B, T, d]``; per-head tensors are
``[B, T, H, hd]``; caches are batch-first (see core/hier_kv_cache.py).
Softmax and norms compute in float32 regardless of model dtype.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hier_kv_cache as HC
from repro.core import paged_kv_cache as PC
from repro.core.weight_quant import matmul as quant_matmul
from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def linear(x: jnp.ndarray, w, b=None, tp=None) -> jnp.ndarray:
    # Int4 draft weights dispatch through weight_quant.matmul — the fused
    # Pallas dequant×matmul on TPU, dequant()+dot elsewhere. `tp` is the
    # weight's serve-mode tensor-parallel role ("col" | "row"), which lets
    # the fused kernel run sharded via its shard_map entry instead of
    # falling back to dequant+dot under a model-parallel mesh.
    y = quant_matmul(x, w, tp=tp)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def rope_cos_sin(positions: jnp.ndarray, dim: int, theta: float):
    """positions [...,T] -> cos/sin [...,T, dim//2] (float32)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x [B, T, H, D]; cos/sin [T, D//2] or [B, T, D//2]."""
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------

def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None,
                  softcap: float = 0.0) -> jnp.ndarray:
    """Grouped-query attention. q [B,T,Hq,D]; k,v [B,S,Hkv,D];
    mask broadcastable to [B, T, S] (True = attend).

    Attention logits are sharding-constrained: kv-heads → `model` when the
    head count divides, otherwise the kv-sequence axis takes `model`
    (sequence-parallel attention — the fallback that keeps 36/40-head archs
    sharded; SPMD inserts the partial-softmax combine)."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, T, Hkv, g, D)
    logits = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(jnp.float32)
    logits = constrain(logits, "batch", "kv_heads", None, None, "kv_seq")
    logits = logits / math.sqrt(D)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask is not None:
        m = jnp.broadcast_to(mask, (B, T, k.shape[1]))
        logits = jnp.where(m[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v)
    return out.reshape(B, T, Hq, D)


def causal_full_attention(q, k, v, softcap=0.0, q_chunk: int = 512):
    """Causal attention, query-chunked: a Python-unrolled loop over query
    chunks where chunk i only reads keys[: end_i]. Peak temp memory is one
    chunk's logits (XLA liveness reuses the buffer across chunks) and FLOPs
    follow the true causal triangle — both matter for the 32k-prefill
    dry-run's memory/cost analysis."""
    B, T, Hq, D = q.shape
    S = k.shape[1]
    if T <= q_chunk:
        mask = jnp.tril(jnp.ones((T, S), bool), k=S - T)
        return gqa_attention(q, k, v, mask[None], softcap)
    assert T == S, "chunked path expects self-attention"
    outs = []
    for start in range(0, T, q_chunk):
        end = min(start + q_chunk, T)
        qc = q[:, start:end]
        kc, vc = k[:, :end], v[:, :end]
        mask = jnp.tril(jnp.ones((end - start, end), bool), k=start)
        outs.append(gqa_attention(qc, kc, vc, mask[None], softcap))
    return jnp.concatenate(outs, axis=1)


def prefill_impl() -> str:
    """Which attention runs in serve-time prefill (quantspec/paged/fp
    policies): 'pallas' (kernels/prefill_attention.py flash kernel) or
    'jnp' (the chunked jnp path — also the train-mode implementation and
    the kernel's parity oracle).  REPRO_PREFILL_ATTN ∈ {auto, pallas,
    jnp}; 'auto' → the flash kernel on TPU only."""
    from repro.kernels import resolve_impl

    return resolve_impl("REPRO_PREFILL_ATTN", "pallas", "jnp")


def serve_prefill_attention(q, k, v, valid_len=None, softcap: float = 0.0,
                            q_chunk: int = 512):
    """One-shot serve-prefill attention over a (possibly bucket-padded)
    prompt: causal over the first ``valid_len`` tokens; padded queries
    produce garbage rows the caller masks by position.

    ``valid_len=None`` (unpadded) reduces to :func:`causal_full_attention`.
    The jnp path keeps the same query-chunk structure as the unpadded path
    so a padded prefill is numerically identical on the valid prefix.
    """
    if prefill_impl() == "pallas" and softcap == 0.0:
        from repro.kernels import ops as kops
        S = k.shape[1]
        return kops.prefill_attention(q, k, v, 0,
                                      S if valid_len is None else valid_len)
    if valid_len is None:
        return causal_full_attention(q, k, v, softcap, q_chunk)
    B, T, Hq, D = q.shape
    S = k.shape[1]
    valid = jnp.asarray(valid_len, jnp.int32)
    if T <= q_chunk:
        mask = jnp.tril(jnp.ones((T, S), bool), k=S - T) & \
            (jnp.arange(S)[None, :] < valid)
        return gqa_attention(q, k, v, mask[None], softcap)
    assert T == S, "chunked path expects self-attention"
    outs = []
    for start in range(0, T, q_chunk):
        end = min(start + q_chunk, T)
        mask = jnp.tril(jnp.ones((end - start, end), bool), k=start) & \
            (jnp.arange(end)[None, :] < valid)
        outs.append(gqa_attention(q[:, start:end], k[:, :end], v[:, :end],
                                  mask[None], softcap))
    return jnp.concatenate(outs, axis=1)


def prefill_band_attention(q, k, v, q_start, kv_len, softcap: float = 0.0):
    """Chunked-prefill attention: chunk queries ``[B, T]`` at stream
    positions ``q_start + [0, T)`` over the full key stream so far
    (``[B, S]``, first ``kv_len`` valid) — a rectangular causal band.
    Both scalars are traced, so one compiled program serves every chunk."""
    if prefill_impl() == "pallas" and softcap == 0.0:
        from repro.kernels import ops as kops
        return kops.prefill_attention(q, k, v, q_start, kv_len)
    T = q.shape[1]
    S = k.shape[1]
    q_pos = jnp.asarray(q_start, jnp.int32) + jnp.arange(T)
    k_pos = jnp.arange(S)
    mask = (k_pos[None, :] <= q_pos[:, None]) & \
        (k_pos[None, :] < jnp.asarray(kv_len, jnp.int32))
    return gqa_attention(q, k.astype(q.dtype), v.astype(q.dtype),
                         mask[None], softcap)


def window_attention_chunked(q, k, v, window: int, softcap=0.0):
    """Sliding-window causal attention with banded (chunked) compute:
    each W-chunk of queries attends to its own + previous key chunk, so
    FLOPs are O(S·2W) instead of O(S²)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    W = window
    if S <= W:
        return causal_full_attention(q, k, v, softcap)
    pad = (-S) % W
    if pad:
        zq = jnp.zeros((B, pad, Hq, D), q.dtype)
        zk = jnp.zeros((B, pad, Hkv, D), k.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zk], 1)
        v = jnp.concatenate([v, zk], 1)
    Sp = S + pad
    nc = Sp // W
    qc = q.reshape(B, nc, W, Hq, D)
    kc = k.reshape(B, nc, W, Hkv, D)
    vc = v.reshape(B, nc, W, Hkv, D)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], 1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], 1)
    k2 = jnp.concatenate([kprev, kc], axis=2)  # [B, nc, 2W, Hkv, D]
    v2 = jnp.concatenate([vprev, vc], axis=2)
    # mask: query i (local) vs key j in [-W, W): attend iff 0 <= i-(j-W) < W
    qi = jnp.arange(W)[:, None]
    kj = jnp.arange(2 * W)[None, :] - W
    mask = (qi >= kj) & (qi - kj < W)
    first_chunk = jnp.concatenate(
        [jnp.zeros((1, W, W), bool), jnp.broadcast_to(mask[None, :, W:],
                                                      (1, W, W))], axis=-1)
    rest = jnp.broadcast_to(mask[None], (nc - 1, W, 2 * W))
    full_mask = jnp.concatenate([first_chunk, rest], axis=0)  # [nc, W, 2W]

    def chunk_attn(qc_, k2_, v2_, m_):
        return gqa_attention(qc_, k2_, v2_, m_[None], softcap)

    out = jax.vmap(chunk_attn, in_axes=(1, 1, 1, 0), out_axes=1)(
        qc, k2, v2, full_mask)
    out = out.reshape(B, Sp, Hq, D)
    return out[:, :S]


# ---------------------------------------------------------------------------
# attention layer params
# ---------------------------------------------------------------------------

def init_attn_params(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    s = cfg.init_scale
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": (jax.random.normal(ks[0], (d, Hq * hd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, Hkv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, Hkv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (Hq * hd, d)) * s).astype(dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((Hq * hd,), dt)
        p["bk"] = jnp.zeros((Hkv * hd,), dt)
        p["bv"] = jnp.zeros((Hkv * hd,), dt)
    return p


def project_qkv(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                positions: Optional[jnp.ndarray], use_rope: bool = True):
    """x [B, T, d] -> q [B,T,Hq,hd], k/v [B,T,Hkv,hd]; RoPE on q,k."""
    B, T, _ = x.shape
    hd = cfg.hd
    q = linear(x, p["wq"], p.get("bq"), tp="col").reshape(
        B, T, cfg.num_heads, hd)
    k = linear(x, p["wk"], p.get("bk"), tp="col").reshape(
        B, T, cfg.num_kv_heads, hd)
    v = linear(x, p["wv"], p.get("bv"), tp="col").reshape(
        B, T, cfg.num_kv_heads, hd)
    if use_rope and positions is not None:
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attn_out(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    B, T, H, D = x.shape
    return linear(x.reshape(B, T, H * D), p["wo"], tp="row")


# ---------------------------------------------------------------------------
# decode-time attention over caches
# ---------------------------------------------------------------------------

def attend_hier(q, cache: HC.HierKVCache, stream_pos, mode: str,
                softcap=0.0, impl: str = "flat", deq_dtype=jnp.float32):
    """Attend q [B,T,H,hd] (new tokens already appended to `cache`) over the
    hierarchical cache. mode: 'draft' (upper-4) | 'target' (INT8 recon)."""
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.hier_attention(q, cache, stream_pos, mode, softcap)
    if impl == "blocked":
        return _attend_hier_blocked(q, cache, stream_pos, mode, softcap,
                                    deq_dtype)
    k, v, valid, quant_len = HC.materialize(cache, mode, deq_dtype)
    Sq = k.shape[1] - cache.buf_k.shape[1]
    pos_keys = jnp.concatenate(
        [jnp.arange(Sq), quant_len + jnp.arange(cache.buf_k.shape[1])])
    T = q.shape[1]
    q_pos = stream_pos + jnp.arange(T)
    mask = valid[None, None, :] & (pos_keys[None, None, :] <= q_pos[None, :, None])
    return gqa_attention(q, k.astype(q.dtype), v.astype(q.dtype), mask, softcap)


def _attend_hier_blocked(q, cache: HC.HierKVCache, stream_pos, mode: str,
                         softcap, deq_dtype):
    """Blocked hierarchical attention: the quantized region keeps its
    [NB, G] structure through dequant → logits → softmax → PV, so the
    sharded block axis is never reshaped away (no SPMD reshard), and the
    FP buffer is merged as one extra flash chunk (paper App. E).

    §Perf iteration for decode shapes; numerically identical to 'flat'
    (same masks, f32 softmax) up to summation order."""
    if softcap != 0.0:
        raise NotImplementedError("blocked impl assumes softcap=0")
    B, T, Hq, D = q.shape
    H = cache.buf_k.shape[2]
    g = Hq // H
    G = cache.group
    kq, vq = HC.dequant_region(cache, mode, deq_dtype)   # [B, NB*G, H, D]
    NB = cache.k_upper.shape[1]
    kb = kq.reshape(B, NB, G, H, D)
    vb = vq.reshape(B, NB, G, H, D)
    qg = q.reshape(B, T, H, g, D)

    # --- quantized region (all blocks < cache.blocks are fully attendable)
    # keep operands in deq_dtype; accumulate f32 on the MXU
    logits = jnp.einsum("bthgd,bnshd->bhgtns", qg.astype(deq_dtype), kb,
                        preferred_element_type=jnp.float32) / math.sqrt(D)
    logits = constrain(logits, "batch", "kv_heads", None, None, "kv_seq")
    block_ok = jnp.arange(NB) < cache.blocks
    logits = jnp.where(block_ok[None, None, None, None, :, None],
                       logits, -jnp.inf)
    m_q = jnp.max(logits, axis=(-2, -1))                     # [B,H,g,T]
    m_safe = jnp.where(jnp.isfinite(m_q), m_q, 0.0)
    p = jnp.exp(logits - m_safe[..., None, None])
    p = jnp.where(block_ok[None, None, None, None, :, None], p, 0.0)
    l_q = jnp.sum(p, axis=(-2, -1))
    acc_q = jnp.einsum("bhgtns,bnshd->bhgtd", p.astype(deq_dtype),
                       vb).astype(jnp.float32)

    # --- FP buffer chunk
    quant_len = cache.blocks * G
    S_buf = cache.buf_k.shape[1]
    q_pos = stream_pos + jnp.arange(T)
    j = jnp.arange(S_buf)
    buf_mask = (j[None, :] < cache.buf_len) & \
               (quant_len + j[None, :] <= q_pos[:, None])     # [T, S_buf]
    lb = jnp.einsum("bthgd,bshd->bhgts", qg.astype(cache.buf_k.dtype),
                    cache.buf_k, preferred_element_type=jnp.float32
                    ) / math.sqrt(D)
    lb = jnp.where(buf_mask[None, None, None], lb, -jnp.inf)
    m_b = jnp.max(lb, axis=-1)
    mb_safe = jnp.where(jnp.isfinite(m_b), m_b, 0.0)
    pb = jnp.where(buf_mask[None, None, None], jnp.exp(lb - mb_safe[..., None]),
                   0.0)
    l_b = jnp.sum(pb, axis=-1)
    acc_b = jnp.einsum("bhgts,bshd->bhgtd", pb.astype(cache.buf_v.dtype),
                       cache.buf_v).astype(jnp.float32)

    # --- flash combine
    m_tot = jnp.maximum(m_safe, mb_safe)
    w_q = jnp.exp(m_safe - m_tot) * jnp.where(l_q > 0, 1.0, 0.0)
    w_b = jnp.exp(mb_safe - m_tot) * jnp.where(l_b > 0, 1.0, 0.0)
    denom = jnp.maximum(l_q * w_q + l_b * w_b, 1e-30)
    out = (acc_q * w_q[..., None] + acc_b * w_b[..., None]) / denom[..., None]
    out = out.astype(q.dtype)                                  # [B,H,g,T,D]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, T, Hq, D)


def attend_hier_paged(q, pool: PC.PagedKVPool, table: PC.PageTable,
                      stream_pos, mode: str, softcap=0.0, impl: str = "flat",
                      deq_dtype=jnp.float32, draft_bits=None):
    """Attend q ``[R, T, Hq, hd]`` over a paged hierarchical cache (new
    tokens already applied via ``apply_step``). ``stream_pos`` is per-slot
    ``[R]`` — under continuous batching every request is at its own
    position. mode: 'draft' (upper-4) | 'target' (INT8 recon).

    ``draft_bits`` (bool ``[R]``, draft mode only) is the precision
    governor's per-slot escalation flag: flagged slots read the INT8
    both-plane reconstruction while the rest of the batch stays on the
    upper-nibble draft view — one program, per-slot lane selection."""
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.paged_hier_attention(q, pool, table, stream_pos, mode,
                                         softcap, draft_bits=draft_bits)
    k, v, valid, quant_len = PC.materialize_slots(pool, table, mode,
                                                  deq_dtype,
                                                  draft_bits=draft_bits)
    Sq = k.shape[1] - pool.buf_k.shape[1]
    s = jnp.arange(k.shape[1])
    # stream position of key s: block region is absolute; buffer keys start
    # at each slot's quantized length
    pos_keys = jnp.where(s[None, :] < Sq, s[None, :],
                         quant_len[:, None] + (s[None, :] - Sq))   # [R, S]
    T = q.shape[1]
    q_pos = jnp.asarray(stream_pos, jnp.int32)[:, None] + jnp.arange(T)
    mask = valid[:, None, :] & \
        (pos_keys[:, None, :] <= q_pos[:, :, None])                # [R, T, S]
    return gqa_attention(q, k.astype(q.dtype), v.astype(q.dtype), mask,
                         softcap)


def attend_full(q, cache: HC.FullKVCache, stream_pos, softcap=0.0):
    S = cache.k.shape[1]
    pos_keys = jnp.arange(S)
    T = q.shape[1]
    q_pos = stream_pos + jnp.arange(T)
    mask = (pos_keys[None, None, :] < cache.length) & \
           (pos_keys[None, None, :] <= q_pos[None, :, None])
    return gqa_attention(q, cache.k.astype(q.dtype), cache.v.astype(q.dtype),
                         mask, softcap)


def attend_window(q, cache: HC.WindowKVCache, stream_pos, softcap=0.0):
    """Attend over sink + ring. Ring slot s holds the most recent stream
    position ≡ s (mod W) that is < cache.pos."""
    n_sink = cache.sink_k.shape[1]
    W = cache.ring_k.shape[1]
    P = cache.pos  # stream length after append
    s = jnp.arange(W)
    ring_pos = P - 1 - ((P - 1 - s) % W)
    ring_valid = (ring_pos >= n_sink) & (ring_pos >= 0) & (ring_pos < P)
    sink_pos = jnp.arange(n_sink)
    sink_valid = sink_pos < P
    k = jnp.concatenate([cache.sink_k, cache.ring_k], 1)
    v = jnp.concatenate([cache.sink_v, cache.ring_v], 1)
    pos_keys = jnp.concatenate([sink_pos, ring_pos])
    valid = jnp.concatenate([sink_valid, ring_valid])
    T = q.shape[1]
    q_pos = stream_pos + jnp.arange(T)
    mask = valid[None, None, :] & (pos_keys[None, None, :] <= q_pos[None, :, None])
    return gqa_attention(q, k.astype(q.dtype), v.astype(q.dtype), mask, softcap)


# ---------------------------------------------------------------------------
# dense MLP (gated SiLU)
# ---------------------------------------------------------------------------

def init_mlp_params(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s = cfg.init_scale
    dt = jnp.dtype(cfg.dtype)
    return {
        "w_gate": (jax.random.normal(ks[0], (d, f)) * s).astype(dt),
        "w_up": (jax.random.normal(ks[1], (d, f)) * s).astype(dt),
        "w_down": (jax.random.normal(ks[2], (f, d)) * s).astype(dt),
    }


def apply_mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(linear(x, p["w_gate"], tp="col"))
    return linear(g * linear(x, p["w_up"], tp="col"), p["w_down"], tp="row")


def init_norm(cfg: ModelConfig) -> dict:
    return {"scale": jnp.ones((cfg.d_model,), jnp.dtype(cfg.dtype))}
