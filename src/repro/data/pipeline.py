"""Synthetic LM data pipeline (offline container → no real corpora).

The generator produces sequences with *learnable structure* at two ranges:
  * local: a fixed random bigram transition table (entropy well below
    uniform, so a small model's CE visibly drops during training);
  * long-range: periodic copy segments — a random earlier span of the
    sequence is repeated later, so models that exploit long context (and
    caches that preserve it!) measurably beat local-only predictors. This
    is what makes KV-cache fidelity (FP16 vs INT8 vs INT4) show up in
    eval perplexity, mirroring the paper's Table 2 protocol.

Deterministic, jit-friendly, infinitely streaming; also provides packing
into fixed [B, S] batches with next-token labels implied.
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticCorpus:
    def __init__(self, vocab_size: int, seed: int = 0,
                 copy_prob: float = 0.5, copy_len: int = 32,
                 bigram_temp: float = 0.7):
        self.vocab_size = vocab_size
        key = jax.random.PRNGKey(seed)
        k1, _ = jax.random.split(key)
        # low-entropy bigram table
        self.bigram_logits = jnp.asarray(
            jax.random.normal(k1, (vocab_size, vocab_size)) / bigram_temp)
        self.copy_prob = copy_prob
        self.copy_len = copy_len

    def sample(self, key, batch: int, seq: int) -> jnp.ndarray:
        """Returns tokens [batch, seq] int32."""
        k_init, k_scan, k_copy = jax.random.split(key, 3)
        first = jax.random.randint(k_init, (batch,), 0, self.vocab_size)

        def step(tok, k):
            nxt = jax.random.categorical(k, self.bigram_logits[tok], axis=-1)
            return nxt, nxt

        keys = jax.random.split(k_scan, seq - 1)
        _, rest = jax.lax.scan(step, first, keys)
        tokens = jnp.concatenate([first[None], rest], axis=0).T  # [B, S]

        # long-range copy: paste tokens[src:src+L] at dst for some rows
        L = min(self.copy_len, seq // 4)
        if L > 0 and seq >= 4 * L:
            kc1, kc2, kc3 = jax.random.split(k_copy, 3)
            src = jax.random.randint(kc1, (batch,), 0, seq // 2 - L)
            dst = jax.random.randint(kc2, (batch,), seq // 2, seq - L)
            do = jax.random.uniform(kc3, (batch,)) < self.copy_prob

            pos = jnp.arange(seq)
            in_dst = (pos[None] >= dst[:, None]) & (pos[None] < dst[:, None] + L)
            src_idx = jnp.clip(pos[None] - dst[:, None] + src[:, None],
                               0, seq - 1)
            copied = jnp.take_along_axis(tokens, src_idx, axis=1)
            tokens = jnp.where(in_dst & do[:, None], copied, tokens)
        return tokens.astype(jnp.int32)

    def sample_with_mask(self, key, batch: int, seq: int):
        """Like sample(), but also returns the copy-destination mask
        [batch, seq] — positions whose prediction requires reading the
        distant source span. Quality benches report CE restricted to these
        positions: that's where KV-cache fidelity of the *quantized region*
        shows up (the local bigram part is predictable from the FP buffer)."""
        k_base, k_copy = jax.random.split(key)
        tokens = self.sample(k_base, batch, seq)
        L = max(16, seq // 8)
        kc1, kc2 = jax.random.split(k_copy)
        src = jax.random.randint(kc1, (batch,), 4, max(5, seq // 4 - L))
        dst = jax.random.randint(kc2, (batch,), seq - seq // 4, seq - L)
        pos = jnp.arange(seq)
        in_dst = (pos[None] >= dst[:, None]) & (pos[None] < dst[:, None] + L)
        src_idx = jnp.clip(pos[None] - dst[:, None] + src[:, None], 0, seq - 1)
        copied = jnp.take_along_axis(tokens, src_idx, axis=1)
        tokens = jnp.where(in_dst, copied, tokens)
        # predicting position t needs t-1's label context; skip the first
        # copied token (not predictable) — mask marks predictable dst tokens
        mask = in_dst & (pos[None] > dst[:, None])
        return tokens, mask

    def sample_induction(self, key, batch: int, prompt_len: int,
                         lead: int = 24):
        """Prompts that END mid-copy: the last `lead` tokens replicate an
        early span, so the natural continuation keeps copying from a region
        far outside any recent-token window. Drafts that dropped the distant
        context (StreamingLLM/SnapKV) mispredict here; a quantized-but-
        complete cache (QuantSpec) doesn't — the discriminative setting of
        the paper's summarization-task acceptance gap."""
        k_base, k_src = jax.random.split(key)
        tokens = self.sample(k_base, batch, prompt_len)
        src = jax.random.randint(k_src, (batch,), 4, prompt_len // 4)
        dst = prompt_len - lead
        pos = jnp.arange(prompt_len)
        src_idx = jnp.clip(pos[None] - dst + src[:, None], 0, prompt_len - 1)
        copied = jnp.take_along_axis(tokens, src_idx, axis=1)
        tokens = jnp.where(pos[None] >= dst, copied, tokens)
        return tokens, src

    def batches(self, batch: int, seq: int, seed: int = 1,
                codebooks: int = 0) -> Iterator[dict]:
        i = 0
        while True:
            key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
            toks = self.sample(key, batch, seq)
            if codebooks:
                ks = jax.random.split(jax.random.fold_in(key, 7), codebooks)
                toks = jnp.stack(
                    [self.sample(k, batch, seq) for k in ks], axis=-1)
            yield {"tokens": toks}
            i += 1

    def entropy_floor(self) -> float:
        """Per-token entropy of the bigram process (nats) — the CE a
        perfect local model converges to (ignoring copy segments)."""
        p = np.asarray(jax.nn.softmax(self.bigram_logits, -1))
        h_cond = -(p * np.log(p + 1e-12)).sum(-1)
        # stationary distribution via power iteration
        pi = np.ones(p.shape[0]) / p.shape[0]
        for _ in range(200):
            pi = pi @ p
        return float((pi * h_cond).sum())
