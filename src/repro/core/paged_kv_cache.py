"""Paged hierarchical quantized KV cache: a global block pool indexed by
per-request block tables (paged-attention-style memory management for the
QuantSpec cache).

The contiguous :class:`~repro.core.hier_kv_cache.HierKVCache` stores each
request's quantized region as one dense ``[NB, G]`` buffer — capacity is
reserved per request up-front and ragged batches waste HBM. Here the
quantized INT4 upper/lower planes live in a **pool** of ``P`` fixed-size
blocks shared by all requests; request ``r`` owns the blocks listed in row
``r`` of a block table. The recent-token FP double buffer stays per-slot
(it is small: ``2G`` tokens).

Two pytrees, split so that bookkeeping is computed once per step while the
(per-layer) plane data is updated layer-by-layer:

``PageTable`` — **shared across layers.** Block table, per-slot block/buffer
    lengths, committed stream positions, active mask, and the free stack.
    Every attention layer sees the same admission/flush/append schedule, so
    one table serves the whole stack.

``PagedKVPool`` — **one per attention layer.** The packed plane arrays
    (``[P+1, G, H, D//2]`` — block ``P`` is a scratch block that absorbs
    masked-out writes) plus the per-slot FP buffers ``[R, 2G, H, D]``.

Sharding contract (distributed/specs.py): the pool-block axis is shared by
every request and stays replicated; the kv-head axis ``H`` of every plane
(packed INT4 upper/lower, scales, zeros) shards over the tensor-parallel
``model`` mesh axis and the FP-buffer slot axis over ``data``. The
``PageTable`` is tiny shared bookkeeping and is replicated — every step
primitive below (plan/apply/rollback/commit/prefill-chunk) is elementwise
or gather/scatter along *unsharded* axes of the planes, so the whole step
protocol partitions without collectives.

Step protocol (all jittable):
  1. ``plan_step(table, T, group)`` → ``(new_table, PageStep)`` decides,
     per slot, whether C_F1 flushes to a freshly allocated pool block and
     where the ``T`` new tokens land in the FP buffer.
  2. every layer calls ``apply_step(pool, step, k, v)`` to execute the plan
     on its own planes/buffers.
  3. after verification, ``rollback(table, rb)`` shrinks each slot's C_F2 by
     its own rejected-tail length ``rb[r]`` and ``commit(table, n_new)``
     advances the committed positions — both per-sequence.

Admission (jittable, one chunk per engine iteration): the chunked-prefill
protocol — ``plan_prefill_chunk`` pops pool blocks for the groups a prompt
chunk completes, every layer runs ``apply_prefill_chunk`` (quantize straight
into pool blocks, fp history in a transient :class:`PrefillScratch`), and
``write_prefill_buffer`` + ``activate_slot`` finalize the slot.  Retirement:
``free_slot`` returns a retired slot's blocks to the pool.  The legacy
``alloc_blocks`` + ``adopt_hier`` dense-copy path is kept only as a test
oracle.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.hier_kv_cache import HierKVCache
from repro.core.quantization import (HierQuant, dequant_full, dequant_slots,
                                     dequant_upper, quantize_kv_block_pair)


class PageTable(NamedTuple):
    """Shared paging state: one row per request slot (R slots, P pool blocks).

    ``free_stack[:free_top]`` holds the ids of free pool blocks; allocation
    pops from the top, freeing pushes back. Block ids are in ``[0, P)``;
    id ``P`` is the layers' scratch block and never appears in the table.

    ``refcount[b]`` counts live references to block ``b``: one per slot
    whose table row lists it, plus one when the prefix index retains it
    (cross-request prefix sharing — see core/prefix_index.py).  A block
    returns to the free stack only when its count hits zero, so aliased
    blocks survive the retirement of any one owner.  Every push site
    (:func:`release_slot`, :func:`free_slot`, :func:`evict_blocks`) is a
    masked decrement-then-push, keeping the whole protocol jit-clean.
    """

    block_table: jnp.ndarray  # i32 [R, NBmax] — pool ids, first blocks[r] valid
    blocks: jnp.ndarray       # i32 [R] — quantized blocks owned by slot r
    buf_len: jnp.ndarray      # i32 [R] — tokens in slot r's FP buffer
    pos: jnp.ndarray          # i32 [R] — committed stream length of slot r
    active: jnp.ndarray       # bool [R]
    free_stack: jnp.ndarray   # i32 [P]
    free_top: jnp.ndarray     # i32 scalar — number of free pool blocks
    refcount: jnp.ndarray     # i32 [P] — live references per pool block

    @property
    def seq_len(self) -> jnp.ndarray:
        """Per-slot committed stream length."""
        return self.pos

    @property
    def num_slots(self) -> int:
        return self.block_table.shape[0]

    @property
    def max_blocks_per_seq(self) -> int:
        return self.block_table.shape[1]


class PagedKVPool(NamedTuple):
    """One attention layer's plane pool + per-slot FP buffers.

    Plane layouts match the contiguous cache block-for-block (see
    docs/kv_cache_format.md); the leading axis is the pool block id. Index
    ``P`` (the last block) is write-scratch for masked flushes.
    """

    k_upper: jnp.ndarray  # u8 [P+1, G, H, D//2]
    k_lower: jnp.ndarray  # u8 [P+1, G, H, D//2]
    k_scale: jnp.ndarray  # f32 [P+1, 1, H, D]
    k_zero: jnp.ndarray   # f32 [P+1, 1, H, D]
    v_upper: jnp.ndarray  # u8 [P+1, G, H, D//2]
    v_lower: jnp.ndarray  # u8 [P+1, G, H, D//2]
    v_scale: jnp.ndarray  # f32 [P+1, G, H, 1]
    v_zero: jnp.ndarray   # f32 [P+1, G, H, 1]
    buf_k: jnp.ndarray    # [R, 2G, H, D] compute dtype
    buf_v: jnp.ndarray    # [R, 2G, H, D]

    @property
    def group(self) -> int:
        return self.buf_k.shape[1] // 2

    @property
    def kv_heads(self) -> int:
        return self.buf_k.shape[2]


# lint: ok(sharding-spec, transient per-step paging plan computed and consumed inside one jitted step)
class PageStep(NamedTuple):
    """One decode step's paging plan, shared by every layer."""

    do_flush: jnp.ndarray   # bool [R] — quantize C_F1 this step
    flush_dst: jnp.ndarray  # i32 [R] — pool block receiving C_F1 (P = scratch)
    append_at: jnp.ndarray  # i32 [R] — FP-buffer offset for the new tokens
    active: jnp.ndarray     # bool [R]


# lint: ok(sharding-spec, transient jit-internal plan value; never placed on a mesh)
class PagedPlan(NamedTuple):
    """What attention layers need for one paged decode step: the executed
    bookkeeping (``step``) and the post-step table to mask against."""

    step: PageStep
    table: PageTable


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_table(num_slots: int, max_blocks_per_seq: int,
               pool_blocks: int) -> PageTable:
    R, NBmax, P = num_slots, max_blocks_per_seq, pool_blocks
    return PageTable(
        block_table=jnp.zeros((R, NBmax), jnp.int32),
        blocks=jnp.zeros((R,), jnp.int32),
        buf_len=jnp.zeros((R,), jnp.int32),
        pos=jnp.zeros((R,), jnp.int32),
        active=jnp.zeros((R,), bool),
        free_stack=jnp.arange(P, dtype=jnp.int32),
        free_top=jnp.asarray(P, jnp.int32),
        refcount=jnp.zeros((P,), jnp.int32),
    )


def init_pool(num_slots: int, pool_blocks: int, group: int, heads: int,
              head_dim: int, dtype=jnp.float32) -> PagedKVPool:
    R, P, G, H, D = num_slots, pool_blocks, group, heads, head_dim
    u8 = partial(jnp.zeros, dtype=jnp.uint8)
    f32 = partial(jnp.zeros, dtype=jnp.float32)
    return PagedKVPool(
        k_upper=u8((P + 1, G, H, D // 2)),
        k_lower=u8((P + 1, G, H, D // 2)),
        k_scale=f32((P + 1, 1, H, D)),
        k_zero=f32((P + 1, 1, H, D)),
        v_upper=u8((P + 1, G, H, D // 2)),
        v_lower=u8((P + 1, G, H, D // 2)),
        v_scale=f32((P + 1, G, H, 1)),
        v_zero=f32((P + 1, G, H, 1)),
        buf_k=jnp.zeros((R, 2 * G, H, D), dtype),
        buf_v=jnp.zeros((R, 2 * G, H, D), dtype),
    )


# ---------------------------------------------------------------------------
# the jittable step protocol
# ---------------------------------------------------------------------------

def plan_step(table: PageTable, T: int, group: int
              ) -> Tuple[PageTable, PageStep]:
    """Plan appending ``T`` tokens to every **active** slot.

    Per slot: if the FP buffer cannot absorb ``T`` more tokens, C_F1 is
    flushed into a pool block popped off the free stack (allocation is a
    masked cumulative-rank pop, so any subset of slots can flush in one
    step); the new tokens then land at the (possibly shifted) buffer end.
    Inactive slots are ignored: no flush, no length advance.
    """
    G = group
    P = table.free_stack.shape[0]
    act = table.active
    need = act & (table.buf_len + T > 2 * G - 1)

    # masked stack pop: the i-th flushing slot takes free_stack[free_top-i-1]
    rank = jnp.cumsum(need.astype(jnp.int32)) - need.astype(jnp.int32)
    pop_idx = table.free_top - 1 - rank
    dst = jnp.where(need,
                    table.free_stack[jnp.clip(pop_idx, 0, P - 1)],
                    jnp.asarray(P, jnp.int32))
    new_free_top = table.free_top - jnp.sum(need.astype(jnp.int32))

    # record the new block at column blocks[r] of each flushing row
    NBmax = table.max_blocks_per_seq
    col = jnp.arange(NBmax)[None, :] == jnp.clip(
        table.blocks, 0, NBmax - 1)[:, None]
    bt = jnp.where(col & need[:, None], dst[:, None], table.block_table)

    blocks = table.blocks + need.astype(jnp.int32)
    buf_after_flush = table.buf_len - G * need.astype(jnp.int32)
    buf_len = buf_after_flush + jnp.where(act, T, 0)

    # freshly popped blocks are owned by exactly their flushing slot
    # (non-flushing lanes carry dst = P and drop out of range)
    refcount = table.refcount.at[dst].set(1, mode="drop")
    new_table = table._replace(block_table=bt, blocks=blocks,
                               buf_len=buf_len, free_top=new_free_top,
                               refcount=refcount)
    step = PageStep(do_flush=need, flush_dst=dst,
                    append_at=buf_after_flush, active=act)
    return new_table, step


def apply_step(pool: PagedKVPool, step: PageStep, k: jnp.ndarray,
               v: jnp.ndarray) -> PagedKVPool:
    """Execute a :class:`PageStep` on one layer's pool. k/v ``[R, T, H, D]``.

    Quantization of C_F1 runs for every slot and is masked into the pool by
    scattering non-flushing slots to the scratch block — the work is
    O(R · G) regardless of how many slots flush, which keeps the step a
    single fused program (no per-slot control flow).
    """
    G = pool.group
    # Pallas quantize+pack on TPU, jnp fallback elsewhere — [R, ...]
    kq, vq = quantize_kv_block_pair(pool.buf_k[:, :G], pool.buf_v[:, :G])
    dst = step.flush_dst

    new = pool._replace(
        k_upper=pool.k_upper.at[dst].set(kq.upper),
        k_lower=pool.k_lower.at[dst].set(kq.lower),
        k_scale=pool.k_scale.at[dst].set(kq.scale),
        k_zero=pool.k_zero.at[dst].set(kq.zero),
        v_upper=pool.v_upper.at[dst].set(vq.upper),
        v_lower=pool.v_lower.at[dst].set(vq.lower),
        v_scale=pool.v_scale.at[dst].set(vq.scale),
        v_zero=pool.v_zero.at[dst].set(vq.zero),
    )

    # shift C_F2 → C_F1 on flushed slots
    m = step.do_flush[:, None, None, None]
    shift = lambda b: jnp.where(
        m, jnp.concatenate([b[:, G:], jnp.zeros_like(b[:, :G])], axis=1), b)
    buf_k, buf_v = shift(new.buf_k), shift(new.buf_v)

    # ragged append: each slot writes its T tokens at its own offset
    upd = jax.vmap(lambda b, x, s: jax.lax.dynamic_update_slice(
        b, x.astype(b.dtype), (s, 0, 0)))
    buf_k = upd(buf_k, k, step.append_at)
    buf_v = upd(buf_v, v, step.append_at)
    return new._replace(buf_k=buf_k, buf_v=buf_v)


def rollback(table: PageTable, n: jnp.ndarray) -> PageTable:
    """Per-sequence flexible discard: drop slot r's last ``n[r]`` buffer
    tokens (the rejected speculative tail). Quantized blocks are never
    touched — the engine invariant guarantees the tail lives in C_F2."""
    n = jnp.where(table.active, jnp.asarray(n, jnp.int32), 0)
    return table._replace(buf_len=table.buf_len - n)


def commit(table: PageTable, n_new: jnp.ndarray) -> PageTable:
    """Advance each active slot's committed stream position by ``n_new[r]``."""
    n = jnp.where(table.active, jnp.asarray(n_new, jnp.int32), 0)
    return table._replace(pos=table.pos + n)


# ---------------------------------------------------------------------------
# chunked prefill: the prompt enters the pool chunk-by-chunk, quantized
# groups written straight into blocks — no dense intermediate cache and no
# adopt copy.  Everything is jittable with a traced slot id, so one program
# per (chunk size, scratch bucket) serves every admission.
# ---------------------------------------------------------------------------

class PrefillScratch(NamedTuple):
    """Transient per-layer fp K/V of the prompt being admitted.

    Sized to the prompt's chunk bucket (``+2G`` slack for the final buffer
    window) — *not* ``max_seq`` — and freed when admission completes.  Chunk
    attention reads history from here, so chunked prefill is numerically
    identical to one-shot dense prefill (the paged engine stays
    token-identical to the static engine); the quantized planes stream into
    pool blocks incrementally and are never duplicated."""

    k: jnp.ndarray  # [1, S_scratch, H, D] compute dtype
    v: jnp.ndarray


# lint: ok(sharding-spec, transient per-chunk admission plan consumed inside one jitted prefill step)
class PrefillChunkStep(NamedTuple):
    """One prompt chunk's admission plan, shared by every layer."""

    slot: jnp.ndarray         # i32 — request slot being prefilled
    pos: jnp.ndarray          # i32 — tokens admitted before this chunk
    valid: jnp.ndarray        # i32 — valid tokens in this (padded) chunk
    blocks_prev: jnp.ndarray  # i32 — quantized blocks before this chunk
    n_flush: jnp.ndarray      # i32 — groups this chunk completes
    flush_dst: jnp.ndarray    # i32 [LANES] — pool block per lane (P = scratch)


def init_prefill_scratch(bucket: int, group: int, heads: int, head_dim: int,
                         dtype=jnp.float32) -> PrefillScratch:
    """Scratch for one admission: bucket tokens + a 2G window of slack so
    the finalize slice (``blocks*G .. +2G``) never clamps."""
    S = bucket + 2 * group
    return PrefillScratch(k=jnp.zeros((1, S, heads, head_dim), dtype),
                          v=jnp.zeros((1, S, heads, head_dim), dtype))


def plan_prefill_chunk(table: PageTable, slot, valid, chunk: int, group: int
                       ) -> Tuple[PageTable, PrefillChunkStep]:
    """Plan admitting one ``chunk``-sized prompt chunk (``valid`` ≤ chunk
    tokens real) into ``slot``.

    Groups completed by this chunk (the prefix rule: after ``P`` tokens,
    ``blocks = max(0, (P-G)//G)``, the trailing ``[G, 2G)`` stay fp) get
    pool blocks popped off the free stack — a masked multi-lane pop, so the
    whole plan jits with a traced slot/progress.  Capacity is guaranteed by
    the scheduler's worst-case reservation at admission time.
    """
    G = group
    P = table.free_stack.shape[0]
    R, NBmax = table.block_table.shape
    LANES = chunk // G + 1                     # ≥ ceil(valid/G) groups/chunk
    slot = jnp.asarray(slot, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)
    pos_prev = table.pos[slot]
    blocks_prev = table.blocks[slot]
    pos_new = pos_prev + valid
    blocks_new = jnp.maximum(0, (pos_new - G) // G)
    n_flush = blocks_new - blocks_prev

    lanes = jnp.arange(LANES, dtype=jnp.int32)
    pop_idx = table.free_top - 1 - lanes
    dst = jnp.where(lanes < n_flush,
                    table.free_stack[jnp.clip(pop_idx, 0, P - 1)],
                    jnp.asarray(P, jnp.int32))

    # record lane l at column blocks_prev + l of the slot's table row;
    # masked/overflow lanes scatter into a dummy column that is sliced off
    cols = blocks_prev + lanes
    safe = jnp.where((lanes < n_flush) & (cols < NBmax), cols, NBmax)
    padded = jnp.concatenate(
        [table.block_table, jnp.zeros((R, 1), jnp.int32)], axis=1)
    padded = padded.at[slot, safe].set(dst)
    new_table = table._replace(
        block_table=padded[:, :NBmax],
        blocks=table.blocks.at[slot].set(blocks_new),
        buf_len=table.buf_len.at[slot].set(pos_new - blocks_new * G),
        pos=table.pos.at[slot].set(pos_new),
        free_top=table.free_top - n_flush,
        refcount=table.refcount.at[dst].set(1, mode="drop"),
    )
    return new_table, PrefillChunkStep(slot=slot, pos=pos_prev, valid=valid,
                                       blocks_prev=blocks_prev,
                                       n_flush=n_flush, flush_dst=dst)


def apply_prefill_chunk(pool: PagedKVPool, step: PrefillChunkStep,
                        scratch: PrefillScratch) -> PagedKVPool:
    """Execute a :class:`PrefillChunkStep` on one layer's pool: quantize the
    groups this chunk completed straight from the fp scratch (which already
    holds the chunk's K/V) into their pool blocks.  Masked lanes write the
    scratch block ``P``, so the work per chunk is a static LANES groups."""
    G = pool.group
    LANES = step.flush_dst.shape[0]
    _, _, H, D = scratch.k.shape
    zero = jnp.zeros((), jnp.int32)
    new = pool
    for l in range(LANES):
        start = (step.blocks_prev + l) * G
        kb = jax.lax.dynamic_slice(scratch.k, (zero, start, zero, zero),
                                   (1, G, H, D))[0]
        vb = jax.lax.dynamic_slice(scratch.v, (zero, start, zero, zero),
                                   (1, G, H, D))[0]
        kq, vq = quantize_kv_block_pair(kb, vb)       # [G, H, ...] planes
        dst = step.flush_dst[l]
        new = new._replace(
            k_upper=new.k_upper.at[dst].set(kq.upper),
            k_lower=new.k_lower.at[dst].set(kq.lower),
            k_scale=new.k_scale.at[dst].set(kq.scale),
            k_zero=new.k_zero.at[dst].set(kq.zero),
            v_upper=new.v_upper.at[dst].set(vq.upper),
            v_lower=new.v_lower.at[dst].set(vq.lower),
            v_scale=new.v_scale.at[dst].set(vq.scale),
            v_zero=new.v_zero.at[dst].set(vq.zero),
        )
    return new


def write_prefill_buffer(pool: PagedKVPool, slot, blocks, buf_len,
                         scratch: PrefillScratch) -> PagedKVPool:
    """Admission finalize (per layer): move the trailing fp window
    ``[blocks*G, blocks*G + buf_len)`` from the scratch into the slot's
    double buffer (invalid tail zeroed), after which the scratch is freed
    and decode proceeds exactly as if the request had been dense-prefilled."""
    G = pool.group
    _, _, H, D = scratch.k.shape
    start = jnp.asarray(blocks, jnp.int32) * G
    zero = jnp.zeros((), jnp.int32)
    bk = jax.lax.dynamic_slice(scratch.k, (zero, start, zero, zero),
                               (1, 2 * G, H, D))[0]
    bv = jax.lax.dynamic_slice(scratch.v, (zero, start, zero, zero),
                               (1, 2 * G, H, D))[0]
    live = (jnp.arange(2 * G) < jnp.asarray(buf_len, jnp.int32))[:, None, None]
    return pool._replace(
        buf_k=pool.buf_k.at[slot].set(
            jnp.where(live, bk.astype(pool.buf_k.dtype), 0)),
        buf_v=pool.buf_v.at[slot].set(
            jnp.where(live, bv.astype(pool.buf_v.dtype), 0)),
    )


def activate_slot(table: PageTable, slot) -> PageTable:
    """Mark a fully-prefilled slot live for decode rounds (its blocks,
    buffer length and stream position were maintained by the chunk plans)."""
    return table._replace(active=table.active.at[slot].set(True))


# ---------------------------------------------------------------------------
# admission / retirement (eager; called between jitted rounds)
# ---------------------------------------------------------------------------

def alloc_blocks(table: PageTable, slot: int, n: int
                 ) -> Tuple[PageTable, jnp.ndarray]:
    """Pop ``n`` blocks for ``slot`` and point its table row at them."""
    top = int(table.free_top)
    if n > top:
        raise RuntimeError(f"pool exhausted: want {n} blocks, {top} free")
    if n > table.max_blocks_per_seq:
        raise RuntimeError(f"request needs {n} blocks > NBmax "
                           f"{table.max_blocks_per_seq}")
    ids = table.free_stack[top - n:top]
    bt = table.block_table.at[slot, :n].set(ids) if n else table.block_table
    return table._replace(
        block_table=bt,
        blocks=table.blocks.at[slot].set(n),
        free_top=jnp.asarray(top - n, jnp.int32),
        refcount=table.refcount.at[ids].set(1) if n else table.refcount,
    ), ids


def adopt_hier(pool: PagedKVPool, slot: int, ids: jnp.ndarray,
               hier: HierKVCache) -> PagedKVPool:
    """Copy a batch-1 contiguous prefill cache into pool blocks ``ids`` and
    buffer row ``slot``.

    This was how admissions entered the paged world before the chunked
    prefill pipeline (``plan_prefill_chunk``/``apply_prefill_chunk``) wrote
    pool blocks directly; the serving engine no longer calls it.  Kept as
    the oracle for chunked-vs-dense cache-identity tests."""
    n = ids.shape[0]
    new = pool
    if n:
        new = new._replace(
            k_upper=new.k_upper.at[ids].set(hier.k_upper[0, :n]),
            k_lower=new.k_lower.at[ids].set(hier.k_lower[0, :n]),
            k_scale=new.k_scale.at[ids].set(hier.k_scale[0, :n]),
            k_zero=new.k_zero.at[ids].set(hier.k_zero[0, :n]),
            v_upper=new.v_upper.at[ids].set(hier.v_upper[0, :n]),
            v_lower=new.v_lower.at[ids].set(hier.v_lower[0, :n]),
            v_scale=new.v_scale.at[ids].set(hier.v_scale[0, :n]),
            v_zero=new.v_zero.at[ids].set(hier.v_zero[0, :n]),
        )
    return new._replace(
        buf_k=new.buf_k.at[slot].set(hier.buf_k[0].astype(new.buf_k.dtype)),
        buf_v=new.buf_v.at[slot].set(hier.buf_v[0].astype(new.buf_v.dtype)),
    )


def admit_slot(table: PageTable, slot: int, seq_len: int,
               buf_len: int) -> PageTable:
    """Mark ``slot`` live after adoption (blocks set by alloc_blocks)."""
    return table._replace(
        buf_len=table.buf_len.at[slot].set(buf_len),
        pos=table.pos.at[slot].set(seq_len),
        active=table.active.at[slot].set(True),
    )


def release_slot(table: PageTable, slot) -> PageTable:
    """Jittable :func:`free_slot` (traced slot id): drop one reference from
    each of the retired slot's blocks and push the ones that hit refcount
    zero back onto the free stack, entirely on device — the megastep driver
    retires slots without ever syncing on the table (``free_slot`` below
    reads ``int(table.blocks[slot])``, which would block the host on the
    in-flight megastep).  Blocks the prefix index (or another slot) still
    references stay allocated — the masked cumulative-rank push only takes
    lanes whose count reaches zero."""
    P = table.free_stack.shape[0]
    NBmax = table.max_blocks_per_seq
    slot = jnp.asarray(slot, jnp.int32)
    n = table.blocks[slot]
    lanes = jnp.arange(NBmax, dtype=jnp.int32)
    owned = lanes < n
    ids = table.block_table[slot]
    ref = table.refcount[jnp.clip(ids, 0, P - 1)]
    push = owned & (ref <= 1)
    rank = jnp.cumsum(push.astype(jnp.int32)) - push.astype(jnp.int32)
    # non-pushed lanes scatter out of range and are dropped
    idx = jnp.where(push, table.free_top + rank, P)
    free_stack = table.free_stack.at[idx].set(ids, mode="drop")
    safe_ids = jnp.where(owned, ids, P)
    refcount = table.refcount.at[safe_ids].add(-1, mode="drop")
    return table._replace(
        block_table=table.block_table.at[slot].set(0),
        blocks=table.blocks.at[slot].set(0),
        buf_len=table.buf_len.at[slot].set(0),
        pos=table.pos.at[slot].set(0),
        active=table.active.at[slot].set(False),
        free_stack=free_stack,
        free_top=table.free_top + jnp.sum(push.astype(jnp.int32)),
        refcount=jnp.maximum(refcount, 0),
    )


def adopt_blocks(table: PageTable, slot, n, buf_len, pos
                 ) -> Tuple[PageTable, jnp.ndarray]:
    """Jittable resume allocation (traced slot/n): pop ``n`` fresh pool
    blocks into ``slot``'s table row and re-activate the row at stream
    position ``pos`` with ``buf_len`` fp-buffer tokens — the allocation
    half of a host-tier swap-in (core/host_tier.py scatters the saved
    plane bytes into the popped blocks).  A masked multi-lane pop over all
    ``NBmax`` lanes, so one compiled program serves any resume size.
    Capacity must be guaranteed by the caller (``free_top >= n``), exactly
    like the scheduler's reservation before a prefill-chunk plan.

    Returns ``(table, ids)`` where ``ids`` is i32 ``[NBmax]`` — the popped
    block id per lane, with the scratch id ``P`` on lanes ``>= n`` so a
    plane scatter through ``ids`` lands masked lanes in the write-scratch
    block."""
    P = table.free_stack.shape[0]
    NBmax = table.max_blocks_per_seq
    slot = jnp.asarray(slot, jnp.int32)
    n = jnp.asarray(n, jnp.int32)
    lanes = jnp.arange(NBmax, dtype=jnp.int32)
    take = lanes < n
    pop_idx = table.free_top - 1 - lanes
    ids = jnp.where(take, table.free_stack[jnp.clip(pop_idx, 0, P - 1)],
                    jnp.asarray(P, jnp.int32))
    new_table = table._replace(
        block_table=table.block_table.at[slot].set(jnp.where(take, ids, 0)),
        blocks=table.blocks.at[slot].set(n),
        buf_len=table.buf_len.at[slot].set(jnp.asarray(buf_len, jnp.int32)),
        pos=table.pos.at[slot].set(jnp.asarray(pos, jnp.int32)),
        active=table.active.at[slot].set(True),
        free_top=table.free_top - n,
        refcount=table.refcount.at[ids].set(1, mode="drop"),
    )
    return new_table, ids


def free_slot(table: PageTable, slot: int) -> PageTable:
    """Retire ``slot``: drop one reference per owned block, pushing the
    blocks that reach refcount zero back onto the free stack (host ints)."""
    n = int(table.blocks[slot])
    top = int(table.free_top)
    free_stack = table.free_stack
    refcount = table.refcount
    if n:
        ids = table.block_table[slot, :n]
        ref = refcount[ids]
        push = ref <= 1
        rank = jnp.cumsum(push.astype(jnp.int32)) - push.astype(jnp.int32)
        idx = jnp.where(push, top + rank, free_stack.shape[0])
        free_stack = free_stack.at[idx].set(ids, mode="drop")
        refcount = jnp.maximum(refcount.at[ids].add(-1), 0)
        top += int(jnp.sum(push.astype(jnp.int32)))
    return table._replace(
        block_table=table.block_table.at[slot].set(0),
        blocks=table.blocks.at[slot].set(0),
        buf_len=table.buf_len.at[slot].set(0),
        pos=table.pos.at[slot].set(0),
        active=table.active.at[slot].set(False),
        free_stack=free_stack,
        free_top=jnp.asarray(top, jnp.int32),
        refcount=refcount,
    )


# ---------------------------------------------------------------------------
# prefix sharing: block aliasing + index retention (core/prefix_index.py
# decides *which* blocks to share/evict; these primitives execute it)
# ---------------------------------------------------------------------------

def share_blocks(table: PageTable, slot: int, ids, cut: int,
                 group: int) -> PageTable:
    """Alias ``ids`` (index-owned pool blocks covering the first
    ``len(ids)`` quant groups of a cached prompt prefix) into ``slot``'s
    table row and bump their refcounts — no free-stack pop, the blocks stay
    where they are.

    ``cut`` is the cached-prefix length in tokens (a multiple of ``group``).
    Per the prefix rule (after S tokens, ``blocks = max(0, (S-G)//G)``) the
    row resumes with ``cut//G - 1`` quantized blocks and a full ``G``-token
    fp window — the *last* matched group is not aliased: chunked prefill
    re-packs it privately from the seeded fp scratch (copy-on-write at the
    ragged tail), so the slot's later decode flushes never touch a shared
    block."""
    G = group
    n = int(len(ids))
    assert cut == (n + 1) * G, "cut must cover the aliased blocks + fp window"
    ids = jnp.asarray(ids, jnp.int32)
    bt = table.block_table.at[slot, :n].set(ids) if n else table.block_table
    return table._replace(
        block_table=bt,
        blocks=table.blocks.at[slot].set(n),
        buf_len=table.buf_len.at[slot].set(cut - n * G),
        pos=table.pos.at[slot].set(cut),
        refcount=table.refcount.at[ids].add(1) if n else table.refcount,
    )


def retain_blocks(table: PageTable, ids) -> PageTable:
    """The prefix index takes one reference on each of ``ids`` (newly
    indexed blocks stay allocated after their producing slot retires)."""
    if len(ids) == 0:
        return table
    return table._replace(
        refcount=table.refcount.at[jnp.asarray(ids, jnp.int32)].add(1))


def evict_blocks(table: PageTable, ids) -> PageTable:
    """Drop the index's reference on ``ids`` (evicted from the prefix
    index), pushing blocks that reach refcount zero back onto the free
    stack.  Blocks still aliased by a live slot keep a positive count and
    are *not* pushed — eviction can never free memory a request is reading."""
    if len(ids) == 0:
        return table
    P = table.free_stack.shape[0]
    ids = jnp.asarray(ids, jnp.int32)
    ref = table.refcount[ids]
    push = ref <= 1
    rank = jnp.cumsum(push.astype(jnp.int32)) - push.astype(jnp.int32)
    idx = jnp.where(push, table.free_top + rank, P)
    return table._replace(
        free_stack=table.free_stack.at[idx].set(ids, mode="drop"),
        free_top=table.free_top + jnp.sum(push.astype(jnp.int32)),
        refcount=jnp.maximum(table.refcount.at[ids].add(-1), 0),
    )


# ---------------------------------------------------------------------------
# gather views (reference path; the Pallas kernel reads the pool in place)
# ---------------------------------------------------------------------------

def gather_quant(pool: PagedKVPool, table: PageTable) -> Tuple[HierQuant,
                                                               HierQuant]:
    """Gather each slot's blocks into contiguous HierQuants
    ``[R, NBmax, G, H, ...]`` — the paged analogue of the dense cache's
    quantized region. Rows beyond ``blocks[r]`` gather block-table padding
    (id 0) and must be masked by the caller."""
    bt = table.block_table
    kq = HierQuant(pool.k_upper[bt], pool.k_lower[bt],
                   pool.k_scale[bt], pool.k_zero[bt])
    vq = HierQuant(pool.v_upper[bt], pool.v_lower[bt],
                   pool.v_scale[bt], pool.v_zero[bt])
    return kq, vq


def materialize_slots(pool: PagedKVPool, table: PageTable, mode: str,
                      dtype=jnp.float32, draft_bits=None):
    """Full logical K/V ``[R, NBmax*G + 2G, H, D]`` + validity mask — the
    oracle used by tests and the flat jnp attention path.

    ``draft_bits`` (bool ``[R]``, draft mode only) per-slot escalates the
    dequantization to the INT8 both-plane reconstruction — the flat-path
    mirror of the Pallas kernel's governor lane flag."""
    G = pool.group
    if mode == "draft" and draft_bits is not None:
        bits = jnp.asarray(draft_bits, bool)

        # Escalation is the exception: while every slot is healthy the
        # governor's bits are all-zero, and dequant_slots with bits off is
        # bit-identical to dequant_upper — so branch at runtime and let the
        # common case skip the lower-plane gather + unpack entirely.  The
        # gathers live inside the branches so XLA can dead-code the lower
        # plane out of the cheap one.
        def _esc(_):
            kq, vq = gather_quant(pool, table)
            return (dequant_slots(kq, bits, dtype),
                    dequant_slots(vq, bits, dtype))

        def _flat(_):
            kq, vq = gather_quant(pool, table)
            return dequant_upper(kq, dtype), dequant_upper(vq, dtype)

        k, v = jax.lax.cond(jnp.any(bits), _esc, _flat, None)
    else:
        kq, vq = gather_quant(pool, table)
        deq = dequant_upper if mode == "draft" else dequant_full
        k = deq(kq, dtype)
        v = deq(vq, dtype)
    R, NB, G_, H, D = k.shape
    k = k.reshape(R, NB * G_, H, D)
    v = v.reshape(R, NB * G_, H, D)
    k = jnp.concatenate([k, pool.buf_k.astype(dtype)], axis=1)
    v = jnp.concatenate([v, pool.buf_v.astype(dtype)], axis=1)
    quant_len = table.blocks * G
    Sq = NB * G_
    s = jnp.arange(k.shape[1])
    valid = jnp.where(s[None, :] < Sq,
                      s[None, :] < quant_len[:, None],
                      s[None, :] - Sq < table.buf_len[:, None])
    return k, v, valid, quant_len
