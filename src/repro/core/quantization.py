"""Hierarchical INT4+INT4 = INT8 quantization (QuantSpec §4.2).

The target model's INT8 KV cache is decomposed into two INT4 planes:

    C_INT8 = 16 * C_U + C_L,       C_U in [0, 15],  C_L in [-8, 7]

obtained by (1) asymmetric round-to-nearest 4-bit quantization of the FP
tensor (upper plane), then (2) *symmetric* 4-bit quantization of the upper
plane's quantization error (lower plane) — the error distribution is
symmetric around zero, so no zero-point is stored for the lower plane.

Dequantization:
    draft  (4-bit):  x ~ C_U * S4 + Z4
    target (8-bit):  x ~ C_U * S4 + C_L * (S4 / 16) + Z4
                       = (16*C_U + C_L) * S8 + Z8,   S4 = 16*S8, Z4 = Z8.

Quantization axes (QuantSpec §4.3.1 / App. D):
    keys   — per-CHANNEL: within a block of G tokens, one (scale, zero) per
             channel, reduced over the token axis.
    values — per-TOKEN:  one (scale, zero) per token, reduced over the
             channel (head_dim) axis (group size G == head_dim).

Both planes are nibble-packed two-elements-per-byte along the head_dim axis
so the draft model physically loads 4 bits/element (the lower plane lives in
a separate array that only the target model touches).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

_EPS = 1e-8
_QMAX4 = 15  # unsigned 4-bit max


# lint: ok(sharding-spec, intermediate quantization pair; unpacked into cache planes before any placement)
class HierQuant(NamedTuple):
    """A hierarchically quantized tensor (both planes nibble-packed)."""

    upper: jnp.ndarray  # uint8, packed 2-per-byte along last axis
    lower: jnp.ndarray  # uint8, packed 2-per-byte, values biased by +8
    scale: jnp.ndarray  # S4 (upper-plane scale), fp32
    zero: jnp.ndarray   # Z4 (= Z8), fp32


# ---------------------------------------------------------------------------
# nibble packing
# ---------------------------------------------------------------------------

def pack_nibbles(x: jnp.ndarray) -> jnp.ndarray:
    """Pack int values in [0, 15] two-per-byte along the last axis.

    Halves layout (TPU-friendly): byte d packs elements (d, d + D/2), so
    unpacking is `concat([p >> 4, p & 15], axis=-1)` — a lane concatenation
    rather than an interleaving reshape, which the Pallas kernels prefer.
    """
    x = x.astype(jnp.uint8)
    h = x.shape[-1] // 2
    hi = x[..., :h]
    lo = x[..., h:]
    return (hi << 4) | lo


def unpack_nibbles(p: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_nibbles`; returns int32 in [0, 15]."""
    hi = (p >> 4).astype(jnp.int32)
    lo = (p & 0xF).astype(jnp.int32)
    return jnp.concatenate([hi, lo], axis=-1)


# ---------------------------------------------------------------------------
# scalar-plane quantizers
# ---------------------------------------------------------------------------

def asym_quant4(x: jnp.ndarray, axis: int):
    """Asymmetric 4-bit RTN quantization reduced over ``axis``.

    Returns (q in [0,15] int32, scale S4, zero Z4); scale/zero keep the
    reduced axis with size 1.
    """
    x = x.astype(jnp.float32)
    mn = jnp.min(x, axis=axis, keepdims=True)
    mx = jnp.max(x, axis=axis, keepdims=True)
    scale = jnp.maximum((mx - mn) / _QMAX4, _EPS)
    q = jnp.clip(jnp.round((x - mn) / scale), 0, _QMAX4).astype(jnp.int32)
    return q, scale, mn


def hier_quantize(x: jnp.ndarray, axis: int) -> HierQuant:
    """Hierarchically quantize ``x``; (scale, zero) reduced over ``axis``.

    The last axis of ``x`` must have even length (nibble packing).
    """
    q_u, s4, z4 = asym_quant4(x, axis)
    recon_u = q_u.astype(jnp.float32) * s4 + z4
    err = x.astype(jnp.float32) - recon_u
    s8 = s4 / 16.0
    q_l = jnp.clip(jnp.round(err / s8), -8, 7).astype(jnp.int32)
    return HierQuant(
        upper=pack_nibbles(q_u),
        lower=pack_nibbles(q_l + 8),
        scale=s4.astype(jnp.float32),
        zero=z4.astype(jnp.float32),
    )


def dequant_upper(q: HierQuant, dtype=jnp.float32) -> jnp.ndarray:
    """Draft-model dequantization: 4-bit plane only."""
    q_u = unpack_nibbles(q.upper).astype(jnp.float32)
    return (q_u * q.scale + q.zero).astype(dtype)


def dequant_full(q: HierQuant, dtype=jnp.float32) -> jnp.ndarray:
    """Target-model dequantization: reconstruct INT8 from both planes."""
    q_u = unpack_nibbles(q.upper).astype(jnp.float32)
    q_l = unpack_nibbles(q.lower).astype(jnp.float32) - 8.0
    q8 = 16.0 * q_u + q_l
    return (q8 * (q.scale / 16.0) + q.zero).astype(dtype)


def dequant_slots(q: HierQuant, bits: jnp.ndarray,
                  dtype=jnp.float32) -> jnp.ndarray:
    """Per-slot hierarchical dequantization (leading axis = slot): rows
    with ``bits`` set reconstruct INT8 from both planes, the rest read
    the 4-bit plane only — the precision governor's per-slot draft-KV
    escalation on the flat/XLA path.

    One shared reconstruction with the lower *residual* zeroed for the
    off rows, not two dequant passes selected after the fact:
    ``(16·q_u)·(s/16) + z`` is bit-identical in fp32 to ``q_u·s + z``
    (``s/16`` is an exact power-of-two rescale and the product rounds
    once either way), so the off rows match :func:`dequant_upper`
    exactly and escalation costs a select on int planes, not a second
    dequant."""
    q_u = unpack_nibbles(q.upper).astype(jnp.float32)
    q_l = unpack_nibbles(q.lower).astype(jnp.float32) - 8.0
    sel = jnp.asarray(bits, bool).reshape((-1,) + (1,) * (q_u.ndim - 1))
    q8 = 16.0 * q_u + jnp.where(sel, q_l, 0.0)
    return (q8 * (q.scale / 16.0) + q.zero).astype(dtype)


# ---------------------------------------------------------------------------
# KV-block quantizers (the shapes the cache uses)
# ---------------------------------------------------------------------------

def quantize_k_block(k: jnp.ndarray) -> HierQuant:
    """Quantize a key block ``[..., G, H, D]`` per-channel.

    (scale, zero) are reduced over the token axis → shape ``[..., 1, H, D]``.
    """
    return hier_quantize(k, axis=-3)


def quantize_v_block(v: jnp.ndarray) -> HierQuant:
    """Quantize a value block ``[..., G, H, D]`` per-token.

    (scale, zero) are reduced over head_dim → shape ``[..., G, H, 1]``.
    """
    return hier_quantize(v, axis=-1)


def quant_pack_impl() -> str:
    """Which KV-block quantizer runs at cache flush/prefill time:
    ``'pallas'`` (the kernels/quant_pack.py quantize+pack kernel) or
    ``'jnp'`` (quantize_k_block/quantize_v_block).  ``REPRO_QUANT_PACK``
    ∈ {auto, pallas, jnp}; 'auto' → pallas on TPU only."""
    from repro.kernels import resolve_impl

    return resolve_impl("REPRO_QUANT_PACK", "pallas", "jnp")


def quantize_kv_block_pair(k: jnp.ndarray, v: jnp.ndarray
                           ) -> Tuple[HierQuant, HierQuant]:
    """Quantize one K block (per-channel) and one V block (per-token),
    both ``[..., G, H, D]`` → HierQuants with the cache's plane layouts.

    This is the single entry point every cache write goes through — the
    decode-path buffer→block flush (`hier_kv_cache.maybe_flush`,
    `paged_kv_cache.apply_step`), dense prefill, and the chunked paged
    prefill — so the Pallas pack kernel and the jnp fallback are always
    interchangeable per backend (see :func:`quant_pack_impl`)."""
    if quant_pack_impl() == "pallas":
        from repro.kernels.quant_pack import quantize_kv_block as _pk

        lead = k.shape[:-3]
        G, H, D = k.shape[-3:]
        n = 1
        for d in lead:
            n *= d
        # [..., G, H, D] -> [n*H, G, D] (head-major rows, kernel layout)
        to_rows = lambda x: x.reshape(n, G, H, D).transpose(
            0, 2, 1, 3).reshape(n * H, G, D)
        planes = _pk(to_rows(k), to_rows(v))

        def back(x, mid):  # [n*H, mid, X] -> [..., mid, H, X]
            X = x.shape[-1]
            return x.reshape(n, H, mid, X).transpose(
                0, 2, 1, 3).reshape(*lead, mid, H, X)

        kq = HierQuant(back(planes["k_upper"], G), back(planes["k_lower"], G),
                       back(planes["k_scale"], 1), back(planes["k_zero"], 1))
        vq = HierQuant(back(planes["v_upper"], G), back(planes["v_lower"], G),
                       back(planes["v_scale"], G), back(planes["v_zero"], G))
        return kq, vq
    return quantize_k_block(k), quantize_v_block(v)


def simulate_cache_quant(x: jnp.ndarray, *, group: int, residual: int,
                         axis: str, bits: int) -> jnp.ndarray:
    """Quantize-dequantize a full-sequence K or V tensor ``[B, S, H, D]``
    exactly the way the hierarchical cache would store it: tokens grouped in
    blocks of ``group`` along the sequence, the trailing ``residual`` tokens
    kept full-precision (the double FP buffer), per-``axis`` scales
    ('channel' → reduce over tokens, 'token' → reduce over head_dim),
    ``bits`` ∈ {4 (upper plane), 8 (both planes), 16 (no-op)}.

    Used by the quality benchmarks (paper Tables 2 & 5) to measure the
    perplexity effect of cache quantization without running a full decode.
    """
    if bits >= 16:
        return x
    B, S, H, D = x.shape
    n_blocks = max(0, (S - residual) // group)
    if n_blocks == 0:
        return x
    head = x[:, : n_blocks * group].reshape(B, n_blocks, group, H, D)
    red_axis = -3 if axis == "channel" else -1
    hq = hier_quantize(head, axis=red_axis)
    deq = dequant_upper(hq, x.dtype) if bits == 4 else dequant_full(hq, x.dtype)
    out = jnp.concatenate(
        [deq.reshape(B, n_blocks * group, H, D), x[:, n_blocks * group:]],
        axis=1)
    return out


def int8_reference_quant(x: jnp.ndarray, axis: int):
    """Plain (non-hierarchical) asymmetric INT8 quantization — used by tests
    to check that the hierarchical scheme matches direct INT8 to ~1 ULP."""
    x = x.astype(jnp.float32)
    mn = jnp.min(x, axis=axis, keepdims=True)
    mx = jnp.max(x, axis=axis, keepdims=True)
    scale = jnp.maximum((mx - mn) / 255.0, _EPS / 16.0)
    q = jnp.clip(jnp.round((x - mn) / scale), 0, 255)
    return q * scale + mn
