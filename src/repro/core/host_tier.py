"""Host-memory KV tier: preempt-to-host offload + resume for the paged pool.

When the device pool (or the slot table) is full and the queue head cannot
be admitted, the engine preempts a running slot: the slot's quantized pool
blocks — packed INT4 upper/lower planes, scales, zeros, gathered by its
block-table row — plus its fp double buffer are swapped to host memory,
the blocks are released back to the free stack, and the request re-enters
the queue as *resumable*.  On re-admission the snapshot restores into
freshly popped blocks (`paged_kv_cache.adopt_blocks`) and decode continues
exactly where it left off: the transfer is bit-exact (raw plane bytes, no
re-quantization), so greedy outputs are token-identical across any number
of preempt/resume cycles.

INT4 planes make this cheap: a block's quantized payload is ~4× smaller
than its fp16 equivalent (the premise of Lynx-style progressive KV
transfer), and the offload is **asynchronous** — `copy_to_host_async` is
issued at preemption time and the host copy is only materialized (one
`device_get` that by then is a cheap host-side wait) when the snapshot is
next needed, so swaps overlap the running megastep instead of stalling it.

Robustness contract (used by tests/fault_injection.py):

* every materialized snapshot carries a CRC32 checksum; `restore` verifies
  it and raises :class:`SnapshotCorruptionError` on mismatch — a corrupted
  swap-in fails *that request*, never poisons the pool;
* transfers retry with exponential backoff (:class:`TransferError` from
  the fault-injection hook or the runtime is retried up to
  ``max_retries``), and a permanently failing transfer surfaces as a
  :class:`HostTierError` the engine converts into a ``failed`` request
  status — no exception ever escapes ``run()``.

Refcount awareness lives in the *caller's* protocol, not here: the engine
snapshots the plane bytes first (aliased prefix blocks included — a byte
copy is alias-agnostic) and then runs the refcount-aware `release_slot`,
so index-retained blocks survive the preemption and the resumed slot gets
private copies (copy-on-preempt, the swap analogue of the prefix cache's
copy-on-write tail).

Three-tier hierarchy (device → host → disk): with a
:class:`~repro.core.disk_tier.DiskTier` attached and ``capacity_bytes``
set, the host store is a bounded LRU cache — offloads past the capacity
spill the least-recently-touched snapshots to per-request disk files, and
``restore``/``fetch`` fall back to the disk record transparently (the
load re-verifies every plane CRC).  A snapshot the disk tier evicted
under its own capacity watermarks surfaces as
:class:`SnapshotMissError`, which the engine treats as "recompute from
the prompt" (greedy decoding is deterministic), not a failure.  Backoff
sleeps route through the fault harness when one is attached
(``fault.sleep``), so retry-storm tests assert the schedule
deterministically instead of paying wall-clock time.
"""

from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np


class HostTierError(RuntimeError):
    """Base class for host-tier failures the engine maps to request
    ``failed`` statuses."""


class TransferError(HostTierError):
    """A device↔host transfer failed (possibly injected); retried with
    backoff up to ``max_retries`` before escaping."""


class SnapshotCorruptionError(HostTierError):
    """A restored snapshot failed its checksum — the swap-in is refused."""


class SnapshotMissError(HostTierError):
    """No tier holds the snapshot (evicted under capacity pressure, or a
    recovery found no persisted record) — the request must be replayed
    from its prompt instead of swapped in."""


@dataclasses.dataclass
class SlotSnapshot:
    """One preempted slot's KV state, slot-agnostic (restorable anywhere).

    ``planes`` is a list over attention layers (serve-state walk order) of
    dicts holding the gathered pool planes ``[NBmax, G|1, H, ...]`` (with a
    leading repeat axis for scan-stacked blocks) plus the fp double-buffer
    rows ``buf_k``/``buf_v`` — device arrays until :meth:`materialized
    <HostTier._materialize>`, numpy afterwards."""

    req_id: int
    n_blocks: int        # valid block-table lanes (the rest are padding)
    buf_len: int         # tokens in the fp double buffer
    pos: int             # committed stream position
    last_token: int      # token feeding the next spec round
    planes: list
    checksum: Optional[int] = None
    nbytes: int = 0

    @property
    def materialized(self) -> bool:
        return self.checksum is not None


def _leaves(planes) -> list:
    return jax.tree.leaves(planes)


def _crc(planes) -> int:
    crc = 0
    for leaf in _leaves(planes):
        arr = np.ascontiguousarray(leaf)
        crc = zlib.crc32(arr.view(np.uint8).reshape(-1), crc)
    return crc


class HostTier:
    """Host-memory block store for preempted slots.

    ``fault`` is an optional injection hook (tests/fault_injection.py):
    ``fault.transfer(op, req_id)`` may raise :class:`TransferError` to
    simulate a failed copy, ``fault.mangle(req_id, planes)`` may corrupt a
    materialized snapshot to exercise the checksum path, and
    ``fault.sleep(seconds)`` replaces the real backoff sleep so retry
    schedules are asserted, not waited out.

    ``disk`` attaches a :class:`~repro.core.disk_tier.DiskTier` behind the
    host store; ``capacity_bytes`` bounds host RAM use — offloads past it
    spill LRU snapshots to disk (no-op without a disk tier).
    """

    def __init__(self, *, fault: Any = None, max_retries: int = 3,
                 backoff_s: float = 0.01, verify: bool = True,
                 capacity_bytes: Optional[int] = None, disk: Any = None):
        self.fault = fault
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.verify = verify
        self.capacity_bytes = capacity_bytes
        self.disk = disk
        self._sleep = getattr(fault, "sleep", None) or time.sleep
        # insertion order doubles as the LRU order (touches re-insert)
        self._store: Dict[int, SlotSnapshot] = {}
        # telemetry
        self.offloads = 0
        self.restores = 0
        self.retries = 0
        self.bytes_offloaded = 0
        self.spills = 0            # host → disk
        self.spill_bytes = 0
        self.disk_restores = 0     # disk → host on a host-store miss

    # ------------------------------------------------------------------
    def __contains__(self, req_id: int) -> bool:
        return req_id in self._store

    def __len__(self) -> int:
        return len(self._store)

    def holds(self, req_id: int) -> bool:
        """True when *any* tier (host store or disk) can restore
        ``req_id`` — what the prefetcher and recovery probe."""
        return req_id in self._store or (
            self.disk is not None and req_id in self.disk)

    @property
    def host_bytes(self) -> int:
        return sum(s.nbytes for s in self._store.values())

    def offload(self, req_id: int, planes: list, *, n_blocks: int,
                buf_len: int, pos: int, last_token: int) -> SlotSnapshot:
        """Start swapping a preempted slot's gathered planes to host.

        Asynchronous: ``copy_to_host_async`` is issued on every leaf and
        the method returns immediately — the device keeps decoding the
        other slots while the DMA drains.  Materialization (and the
        checksum) happens lazily at :meth:`restore` (or eagerly via
        :meth:`materialize`)."""
        self._transfer("offload", req_id)
        for leaf in _leaves(planes):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        snap = SlotSnapshot(req_id=req_id, n_blocks=n_blocks,
                            buf_len=buf_len, pos=pos, last_token=last_token,
                            planes=planes)
        # size is known from the leaf shapes before the DMA drains, so
        # capacity accounting never forces an early materialize
        snap.nbytes = sum(leaf.nbytes for leaf in _leaves(planes))
        self._store[req_id] = snap
        self.offloads += 1
        self.bytes_offloaded += snap.nbytes
        try:
            self._enforce_capacity(exclude=req_id)
        except HostTierError as e:
            # the hierarchy is full end to end (disk spill failed): drop
            # the new snapshot and surface the failure so the engine fails
            # *this* preemption victim — older snapshots stay intact
            self._store.pop(req_id, None)
            raise HostTierError(
                f"host tier over capacity and spill failed: {e}") from e
        return snap

    def _enforce_capacity(self, exclude: Optional[int] = None) -> None:
        """Spill LRU host snapshots to the disk tier until the host store
        fits ``capacity_bytes``.  Without a disk tier the capacity is
        advisory (legacy unbounded behavior)."""
        if self.capacity_bytes is None or self.disk is None:
            return
        while self.host_bytes > self.capacity_bytes:
            victim = next((rid for rid in self._store if rid != exclude),
                          None)
            if victim is None:
                return
            snap = self.materialize(victim)
            self.disk.put(snap)
            self._store.pop(victim, None)
            self.spills += 1
            self.spill_bytes += snap.nbytes

    def materialize(self, req_id: int) -> SlotSnapshot:
        """Finish the host copy: device_get the planes (a cheap wait once
        the async copy has drained), checksum them, and drop the device
        references so the snapshot survives pool donation."""
        snap = self._store[req_id]
        if snap.materialized:
            return snap
        snap.planes = self._retrying_get("offload", req_id, snap.planes)
        snap.checksum = _crc(snap.planes)
        snap.nbytes = sum(leaf.nbytes for leaf in _leaves(snap.planes))
        if self.fault is not None and hasattr(self.fault, "mangle"):
            # post-checksum corruption hook: simulates bitrot between
            # offload and restore so the verify path is testable
            snap.planes = self.fault.mangle(req_id, snap.planes)
        return snap

    def restore(self, req_id: int) -> SlotSnapshot:
        """Hand back a snapshot for swap-in, verifying integrity.  Falls
        back to the disk tier when the host store spilled (or never held)
        the snapshot; raises :class:`SnapshotMissError` when no tier has
        it (capacity-evicted — the caller replays from the prompt).

        The snapshot is *popped* from every tier (a resumed slot owns
        fresh private blocks; keeping a stale copy would only mask bugs —
        and a stale *disk* copy would poison a later crash recovery with
        an out-of-date stream position)."""
        if req_id not in self._store:
            if self.disk is None or req_id not in self.disk:
                raise SnapshotMissError(
                    f"no tier holds a snapshot for request {req_id} "
                    f"(evicted under capacity pressure?)")
            self._transfer("restore", req_id)
            snap = self.disk.load(req_id)   # CRC-verified, popped
            self.disk_restores += 1
            self.restores += 1
            return snap
        snap = self.materialize(req_id)
        self._transfer("restore", req_id)
        if self.verify and _crc(snap.planes) != snap.checksum:
            self._store.pop(req_id, None)
            raise SnapshotCorruptionError(
                f"snapshot for request {req_id} failed checksum "
                f"verification — refusing swap-in")
        self._store.pop(req_id, None)
        if self.disk is not None:
            # drop any checkpoint-persisted copy: it is stale the moment
            # the request decodes again
            self.disk.discard(req_id)
        self.restores += 1
        return snap

    def persist(self, req_id: int) -> bool:
        """Copy one host snapshot to the disk tier *without* evicting the
        host copy — the checkpoint path (serving/journal.py): a later
        crash can then restore the preempted request bit-exact.  Returns
        False when the snapshot isn't host-resident (already spilled, or
        unknown)."""
        snap = self._store.get(req_id)
        if snap is None or self.disk is None:
            return False
        self.disk.put(self.materialize(req_id))
        return True

    def discard(self, req_id: int) -> None:
        """Drop a snapshot from every tier (its request was
        cancelled/failed in the queue)."""
        self._store.pop(req_id, None)
        if self.disk is not None:
            self.disk.discard(req_id)

    # ------------------------------------------------------------------
    def _transfer(self, op: str, req_id: int) -> None:
        """Fault-injection gate for one transfer, retried with backoff."""
        if self.fault is None or not hasattr(self.fault, "transfer"):
            return
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                self.fault.transfer(op, req_id)
                return
            except TransferError:
                if attempt == self.max_retries:
                    raise
                self.retries += 1
                self._sleep(delay)
                delay *= 2

    def _retrying_get(self, op: str, req_id: int, planes):
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                # lint: ok(host-sync, offload materialization is the host tier's job: planes must land in host RAM; runs on preemption only)
                return jax.device_get(planes)
            except Exception as e:         # pragma: no cover - runtime path
                if attempt == self.max_retries:
                    raise TransferError(
                        f"{op} transfer for request {req_id} failed after "
                        f"{self.max_retries} retries: {e}") from e
                self.retries += 1
                self._sleep(delay)
                delay *= 2
