"""Host-memory KV tier: preempt-to-host offload + resume for the paged pool.

When the device pool (or the slot table) is full and the queue head cannot
be admitted, the engine preempts a running slot: the slot's quantized pool
blocks — packed INT4 upper/lower planes, scales, zeros, gathered by its
block-table row — plus its fp double buffer are swapped to host memory,
the blocks are released back to the free stack, and the request re-enters
the queue as *resumable*.  On re-admission the snapshot restores into
freshly popped blocks (`paged_kv_cache.adopt_blocks`) and decode continues
exactly where it left off: the transfer is bit-exact (raw plane bytes, no
re-quantization), so greedy outputs are token-identical across any number
of preempt/resume cycles.

INT4 planes make this cheap: a block's quantized payload is ~4× smaller
than its fp16 equivalent (the premise of Lynx-style progressive KV
transfer), and the offload is **asynchronous** — `copy_to_host_async` is
issued at preemption time and the host copy is only materialized (one
`device_get` that by then is a cheap host-side wait) when the snapshot is
next needed, so swaps overlap the running megastep instead of stalling it.

Robustness contract (used by tests/fault_injection.py):

* every materialized snapshot carries a CRC32 checksum; `restore` verifies
  it and raises :class:`SnapshotCorruptionError` on mismatch — a corrupted
  swap-in fails *that request*, never poisons the pool;
* transfers retry with exponential backoff (:class:`TransferError` from
  the fault-injection hook or the runtime is retried up to
  ``max_retries``), and a permanently failing transfer surfaces as a
  :class:`HostTierError` the engine converts into a ``failed`` request
  status — no exception ever escapes ``run()``.

Refcount awareness lives in the *caller's* protocol, not here: the engine
snapshots the plane bytes first (aliased prefix blocks included — a byte
copy is alias-agnostic) and then runs the refcount-aware `release_slot`,
so index-retained blocks survive the preemption and the resumed slot gets
private copies (copy-on-preempt, the swap analogue of the prefix cache's
copy-on-write tail).
"""

from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np


class HostTierError(RuntimeError):
    """Base class for host-tier failures the engine maps to request
    ``failed`` statuses."""


class TransferError(HostTierError):
    """A device↔host transfer failed (possibly injected); retried with
    backoff up to ``max_retries`` before escaping."""


class SnapshotCorruptionError(HostTierError):
    """A restored snapshot failed its checksum — the swap-in is refused."""


@dataclasses.dataclass
class SlotSnapshot:
    """One preempted slot's KV state, slot-agnostic (restorable anywhere).

    ``planes`` is a list over attention layers (serve-state walk order) of
    dicts holding the gathered pool planes ``[NBmax, G|1, H, ...]`` (with a
    leading repeat axis for scan-stacked blocks) plus the fp double-buffer
    rows ``buf_k``/``buf_v`` — device arrays until :meth:`materialized
    <HostTier._materialize>`, numpy afterwards."""

    req_id: int
    n_blocks: int        # valid block-table lanes (the rest are padding)
    buf_len: int         # tokens in the fp double buffer
    pos: int             # committed stream position
    last_token: int      # token feeding the next spec round
    planes: list
    checksum: Optional[int] = None
    nbytes: int = 0

    @property
    def materialized(self) -> bool:
        return self.checksum is not None


def _leaves(planes) -> list:
    return jax.tree.leaves(planes)


def _crc(planes) -> int:
    crc = 0
    for leaf in _leaves(planes):
        arr = np.ascontiguousarray(leaf)
        crc = zlib.crc32(arr.view(np.uint8).reshape(-1), crc)
    return crc


class HostTier:
    """Host-memory block store for preempted slots.

    ``fault`` is an optional injection hook (tests/fault_injection.py):
    ``fault.transfer(op, req_id)`` may raise :class:`TransferError` to
    simulate a failed copy, and ``fault.mangle(req_id, planes)`` may
    corrupt a materialized snapshot to exercise the checksum path.
    """

    def __init__(self, *, fault: Any = None, max_retries: int = 3,
                 backoff_s: float = 0.01, verify: bool = True):
        self.fault = fault
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.verify = verify
        self._store: Dict[int, SlotSnapshot] = {}
        # telemetry
        self.offloads = 0
        self.restores = 0
        self.retries = 0
        self.bytes_offloaded = 0

    # ------------------------------------------------------------------
    def __contains__(self, req_id: int) -> bool:
        return req_id in self._store

    def __len__(self) -> int:
        return len(self._store)

    def offload(self, req_id: int, planes: list, *, n_blocks: int,
                buf_len: int, pos: int, last_token: int) -> SlotSnapshot:
        """Start swapping a preempted slot's gathered planes to host.

        Asynchronous: ``copy_to_host_async`` is issued on every leaf and
        the method returns immediately — the device keeps decoding the
        other slots while the DMA drains.  Materialization (and the
        checksum) happens lazily at :meth:`restore` (or eagerly via
        :meth:`materialize`)."""
        self._transfer("offload", req_id)
        for leaf in _leaves(planes):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        snap = SlotSnapshot(req_id=req_id, n_blocks=n_blocks,
                            buf_len=buf_len, pos=pos, last_token=last_token,
                            planes=planes)
        self._store[req_id] = snap
        self.offloads += 1
        return snap

    def materialize(self, req_id: int) -> SlotSnapshot:
        """Finish the host copy: device_get the planes (a cheap wait once
        the async copy has drained), checksum them, and drop the device
        references so the snapshot survives pool donation."""
        snap = self._store[req_id]
        if snap.materialized:
            return snap
        snap.planes = self._retrying_get("offload", req_id, snap.planes)
        snap.checksum = _crc(snap.planes)
        snap.nbytes = sum(leaf.nbytes for leaf in _leaves(snap.planes))
        self.bytes_offloaded += snap.nbytes
        if self.fault is not None and hasattr(self.fault, "mangle"):
            # post-checksum corruption hook: simulates bitrot between
            # offload and restore so the verify path is testable
            snap.planes = self.fault.mangle(req_id, snap.planes)
        return snap

    def restore(self, req_id: int) -> SlotSnapshot:
        """Hand back a snapshot for swap-in, verifying integrity.

        The snapshot is *popped* from the store (a resumed slot owns fresh
        private blocks; keeping a stale copy would only mask bugs)."""
        snap = self.materialize(req_id)
        self._transfer("restore", req_id)
        if self.verify and _crc(snap.planes) != snap.checksum:
            self._store.pop(req_id, None)
            raise SnapshotCorruptionError(
                f"snapshot for request {req_id} failed checksum "
                f"verification — refusing swap-in")
        self._store.pop(req_id, None)
        self.restores += 1
        return snap

    def discard(self, req_id: int) -> None:
        """Drop a snapshot (its request was cancelled/failed in the
        queue)."""
        self._store.pop(req_id, None)

    # ------------------------------------------------------------------
    def _transfer(self, op: str, req_id: int) -> None:
        """Fault-injection gate for one transfer, retried with backoff."""
        if self.fault is None or not hasattr(self.fault, "transfer"):
            return
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                self.fault.transfer(op, req_id)
                return
            except TransferError:
                if attempt == self.max_retries:
                    raise
                self.retries += 1
                time.sleep(delay)
                delay *= 2

    def _retrying_get(self, op: str, req_id: int, planes):
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                return jax.device_get(planes)
            except Exception as e:         # pragma: no cover - runtime path
                if attempt == self.max_retries:
                    raise TransferError(
                        f"{op} transfer for request {req_id} failed after "
                        f"{self.max_retries} retries: {e}") from e
                self.retries += 1
                time.sleep(delay)
                delay *= 2
