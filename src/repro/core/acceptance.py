"""Speculative-sampling verification (Leviathan et al. 2023), batched.

Given γ draft tokens with draft distributions q and target distributions p,
accept each token with probability min(1, p/q); at the first rejection,
resample from the residual distribution norm(max(p-q, 0)); if all γ are
accepted, sample one bonus token from the target's (γ+1)-th distribution.

This preserves the target model's sampling distribution exactly, so the
*only* quality question for QuantSpec is the target's INT8-KV fidelity
(validated in benchmarks/ppl_quality.py).

Batched engines here run in lockstep: the per-step accepted length is the
minimum across the batch (exact for batch=1, conservative otherwise — see
DESIGN.md §6).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# lint: ok(sharding-spec, jit-internal verification result consumed inside the round; never crosses a placement boundary)
class VerifyResult(NamedTuple):
    tokens: jnp.ndarray       # [B, gamma+1] — accepted + correction/bonus,
                              # positions >= n_new are padding
    n_accepted: jnp.ndarray   # i32 — accepted draft tokens; scalar lockstep
                              # min (verify) or per-sequence [B] (verify_per_seq)
    n_new: jnp.ndarray        # i32 — n_accepted + 1 (correction/bonus)
    accept_mask_b: jnp.ndarray  # [B, gamma] — per-sequence accept flags (stats)


def _gather_probs(probs, tokens):
    # probs [B, T, V], tokens [B, T] -> [B, T]
    return jnp.take_along_axis(probs, tokens[..., None], axis=-1)[..., 0]


def verify(draft_tokens: jnp.ndarray,
           draft_probs: jnp.ndarray,
           target_probs: jnp.ndarray,
           key: jax.Array,
           greedy: bool = False,
           gamma_eff=None) -> VerifyResult:
    """draft_tokens [B, γ]; draft_probs [B, γ, V]; target_probs [B, γ+1, V].

    ``gamma_eff`` (static int, ≤ γ) force-rejects draft positions past it —
    the precision governor's masked-γ rung.  A forced rejection samples its
    correction from the *target* distribution (the draft proposed nothing
    there, so q ≡ 0 and the residual is p itself), keeping the scheme exact
    in both greedy and sampled modes."""
    B, gamma = draft_tokens.shape
    key_u, key_res, key_bonus = jax.random.split(key, 3)

    p_draft_tok = _gather_probs(target_probs[:, :gamma], draft_tokens)
    q_draft_tok = _gather_probs(draft_probs, draft_tokens)

    if greedy:
        accept = draft_tokens == jnp.argmax(target_probs[:, :gamma], axis=-1)
    else:
        u = jax.random.uniform(key_u, (B, gamma))
        accept = u * q_draft_tok <= p_draft_tok
    if gamma_eff is not None and gamma_eff < gamma:
        accept = accept & (jnp.arange(gamma)[None, :] < gamma_eff)

    # prefix-accepted length per sequence, then lockstep min
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_b = jnp.sum(prefix, axis=-1)                     # [B]
    n = jnp.min(n_b).astype(jnp.int32)

    # distribution for the (n+1)-th token: residual if n < γ else target bonus
    p_next = jnp.take_along_axis(
        target_probs, jnp.full((B, 1, 1), 0, jnp.int32) + n, axis=1)[:, 0]
    if greedy:
        extra = jnp.argmax(p_next, axis=-1)
    else:
        q_at_n = jnp.take_along_axis(
            jnp.pad(draft_probs, ((0, 0), (0, 1), (0, 0))),
            jnp.full((B, 1, 1), 0, jnp.int32) + n, axis=1)[:, 0]
        if gamma_eff is not None and gamma_eff < gamma:
            # forced rejection: the draft never proposed position n, so the
            # correction must come from p directly, not the residual
            q_at_n = jnp.where(n >= gamma_eff, 0.0, q_at_n)
        residual = jnp.maximum(p_next - q_at_n, 0.0)
        is_bonus = (n == gamma)
        dist = jnp.where(is_bonus, p_next, residual)
        dist = dist / jnp.maximum(dist.sum(-1, keepdims=True), 1e-20)
        extra = jax.random.categorical(key_res, jnp.log(dist + 1e-20), axis=-1)

    pos = jnp.arange(gamma + 1)
    padded_draft = jnp.pad(draft_tokens, ((0, 0), (0, 1)))
    tokens = jnp.where(pos[None, :] < n, padded_draft,
                       jnp.where(pos[None, :] == n, extra[:, None], 0))
    return VerifyResult(tokens=tokens, n_accepted=n,
                        n_new=n + 1, accept_mask_b=accept)


def verify_per_seq(draft_tokens: jnp.ndarray,
                   draft_probs: jnp.ndarray,
                   target_probs: jnp.ndarray,
                   key: jax.Array,
                   greedy: bool = False,
                   gamma_eff=None) -> VerifyResult:
    """Per-sequence verification — no lockstep minimum.

    Same accept/reject math as :func:`verify`, but each sequence keeps its
    own accepted length (``n_accepted``/``n_new`` are ``[B]`` vectors).
    Used by the continuous-batching engine, where requests progress
    raggedly; for any single sequence the result is identical to a
    batch-1 :func:`verify`.

    ``gamma_eff`` (i32 ``[B]``, values in [0, γ]) is the precision
    governor's per-slot effective γ: draft positions ≥ ``gamma_eff[b]``
    are force-rejected (their cache writes roll back as if the target had
    disagreed), and the forced correction samples from the target
    distribution itself — with ``gamma_eff[b] = 0`` the slot degenerates
    to exact verify-only AR decoding of one token per round."""
    B, gamma = draft_tokens.shape
    key_u, key_res = jax.random.split(key)

    p_draft_tok = _gather_probs(target_probs[:, :gamma], draft_tokens)
    q_draft_tok = _gather_probs(draft_probs, draft_tokens)

    if greedy:
        accept = draft_tokens == jnp.argmax(target_probs[:, :gamma], axis=-1)
    else:
        u = jax.random.uniform(key_u, (B, gamma))
        accept = u * q_draft_tok <= p_draft_tok
    if gamma_eff is not None:
        accept = accept & (jnp.arange(gamma)[None, :]
                           < jnp.asarray(gamma_eff, jnp.int32)[:, None])

    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_b = jnp.sum(prefix, axis=-1).astype(jnp.int32)          # [B]

    # (n_b+1)-th token per sequence: residual at the rejection point, or the
    # target bonus when everything was accepted
    p_next = jnp.take_along_axis(
        target_probs, n_b[:, None, None], axis=1)[:, 0]       # [B, V]
    if greedy:
        extra = jnp.argmax(p_next, axis=-1)
    else:
        q_at_n = jnp.take_along_axis(
            jnp.pad(draft_probs, ((0, 0), (0, 1), (0, 0))),
            n_b[:, None, None], axis=1)[:, 0]
        if gamma_eff is not None:
            # forced rejections sample the correction from p, not the
            # residual — the draft proposed nothing at a masked position
            forced = n_b >= jnp.asarray(gamma_eff, jnp.int32)
            q_at_n = jnp.where(forced[:, None], 0.0, q_at_n)
        residual = jnp.maximum(p_next - q_at_n, 0.0)
        is_bonus = (n_b == gamma)[:, None]
        dist = jnp.where(is_bonus, p_next, residual)
        dist = dist / jnp.maximum(dist.sum(-1, keepdims=True), 1e-20)
        extra = jax.random.categorical(key_res, jnp.log(dist + 1e-20),
                                       axis=-1)

    pos = jnp.arange(gamma + 1)
    padded_draft = jnp.pad(draft_tokens, ((0, 0), (0, 1)))
    tokens = jnp.where(pos[None, :] < n_b[:, None], padded_draft,
                       jnp.where(pos[None, :] == n_b[:, None],
                                 extra[:, None], 0))
    return VerifyResult(tokens=tokens, n_accepted=n_b,
                        n_new=n_b + 1, accept_mask_b=accept)


def verify_greedy_multi(draft_tokens: jnp.ndarray,
                        target_probs: jnp.ndarray) -> VerifyResult:
    """Frame-level greedy verification for multi-codebook (audio) decoding:
    a drafted frame is accepted iff every codebook matches the target's
    argmax. draft_tokens [B, γ, K]; target_probs [B, γ+1, K, V]."""
    B, gamma, K = draft_tokens.shape
    tgt = jnp.argmax(target_probs, axis=-1)                 # [B, γ+1, K]
    accept = jnp.all(draft_tokens == tgt[:, :gamma], axis=-1)  # [B, γ]
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n = jnp.min(jnp.sum(prefix, axis=-1)).astype(jnp.int32)
    extra = jnp.take_along_axis(
        tgt, jnp.full((B, 1, 1), 0, jnp.int32) + n, axis=1)[:, 0]  # [B, K]
    pos = jnp.arange(gamma + 1)
    padded = jnp.pad(draft_tokens, ((0, 0), (0, 1), (0, 0)))
    tokens = jnp.where(pos[None, :, None] < n, padded,
                       jnp.where(pos[None, :, None] == n,
                                 extra[:, None, :], 0))
    return VerifyResult(tokens=tokens, n_accepted=n, n_new=n + 1,
                        accept_mask_b=accept)
