"""INT4 per-group weight quantization for the draft pass (QuantSpec §4.1).

The draft model shares the target's weights but loads them as 4-bit
(asymmetric, round-to-nearest, groups of 128 along the contraction axis) —
this is what accelerates the *linear* portion of decode for short contexts
(§3.1: short-context decode is weight-bound).

Weights stay packed in HBM; `Int4Weight.dequant()` is the reference
dequantization (on TPU the dequant fuses into the matmul — XLA does this
fusion for the `dequant → dot` pattern, see benchmarks/kernel_bench.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import asym_quant4

DEFAULT_GROUP = 128


@jax.tree_util.register_pytree_node_class
class Int4Weight:
    """A 4-bit quantized weight. Logical shape ``(*lead, d_in, d_out)``;
    quantization groups run along ``d_in`` (axis -2)."""

    def __init__(self, packed, scale, zero, group: int):
        self.packed = packed  # uint8 [*lead, d_in//group, group//2, d_out]
        self.scale = scale    # f32   [*lead, d_in//group, 1, d_out]
        self.zero = zero      # f32   [*lead, d_in//group, 1, d_out]
        self.group = group

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        return (self.packed, self.scale, self.zero), (self.group,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    # -------------------------------------------------------------------------
    @property
    def shape(self):
        lead = self.packed.shape[:-3]
        ng, gh, dout = self.packed.shape[-3:]
        return (*lead, ng * gh * 2, dout)

    @property
    def nbytes(self):
        if not hasattr(self.packed, "size"):
            return 0
        return (self.packed.size * self.packed.dtype.itemsize
                + self.scale.size * self.scale.dtype.itemsize
                + self.zero.size * self.zero.dtype.itemsize)

    def compression_ratio(self, ref_dtype=jnp.float16) -> float:
        """Full-precision bytes / quantized bytes (scales included)."""
        if not hasattr(self.packed, "size") or self.nbytes == 0:
            return 1.0
        n_elem = 1
        for d in self.shape:
            n_elem *= d
        return n_elem * jnp.dtype(ref_dtype).itemsize / self.nbytes

    def dequant(self, dtype=jnp.float32) -> jnp.ndarray:
        p = self.packed
        hi = (p >> 4).astype(jnp.float32)
        lo = (p & 0xF).astype(jnp.float32)
        q = jnp.stack([hi, lo], axis=-2)              # [..., ng, g//2, 2, dout]
        *lead, ng, gh, two, dout = q.shape
        q = q.reshape(*lead, ng, gh * 2, dout)
        w = q * self.scale + self.zero
        return w.reshape(*lead, ng * gh * 2, dout).astype(dtype)


def quantize_weight(w: jnp.ndarray, group: int = DEFAULT_GROUP) -> Int4Weight:
    """Quantize ``(*lead, d_in, d_out)`` along ``d_in`` in groups."""
    *lead, din, dout = w.shape
    assert din % group == 0, (w.shape, group)
    wg = w.reshape(*lead, din // group, group, dout)
    q, s, z = asym_quant4(wg, axis=-2)
    packed = ((q[..., 0::2, :].astype(jnp.uint8) << 4)
              | q[..., 1::2, :].astype(jnp.uint8))
    return Int4Weight(packed, s, z, group)


def is_quantizable(path: str, w) -> bool:
    """Default policy: 4-bit-quantize matmul weights, keep embeddings,
    norms, biases, and small tensors in full precision."""
    if not hasattr(w, "ndim") or w.ndim < 2:
        return False
    if w.shape[-2] % DEFAULT_GROUP != 0:
        return False
    lowered = path.lower()
    if any(s in lowered for s in ("embed", "norm", "bias", "scale", "a_log",
                                  "conv", "decay", "dt_")):
        return False
    return True


def quantize_tree(params, group: int = DEFAULT_GROUP, predicate=is_quantizable):
    """Walk a param pytree and replace quantizable leaves with Int4Weight."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if predicate(name, leaf):
            out.append(quantize_weight(leaf, group))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def resolve(w, dtype=jnp.float32) -> jnp.ndarray:
    """Materialize a weight that may or may not be quantized."""
    if isinstance(w, Int4Weight):
        return w.dequant(dtype)
    return w.astype(dtype)


def tree_compression(params, ref_dtype=jnp.float16):
    """Aggregate (quant_bytes, fp_bytes, ratio) over a param pytree —
    benchmark helper for the weight-bandwidth story."""
    qb = fb = 0
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, Int4Weight))
    for leaf in leaves:
        if isinstance(leaf, Int4Weight):
            qb += int(leaf.nbytes)
            n = 1
            for d in leaf.shape:
                n *= d
            fb += n * jnp.dtype(ref_dtype).itemsize
        elif hasattr(leaf, "size"):
            b = leaf.size * jnp.dtype(leaf.dtype).itemsize
            qb += b
            fb += b
    return qb, fb, (fb / qb if qb else 1.0)


# ---------------------------------------------------------------------------
# the draft matmul hot path
# ---------------------------------------------------------------------------

def matmul_impl() -> str:
    """Which INT4 matmul runs: 'fused' (Pallas, compiled on TPU / interpret
    elsewhere) or 'dequant' (materialize + dot, XLA fuses on TPU).
    REPRO_QUANT_MATMUL ∈ {auto, fused, dequant}; 'auto' → fused on TPU
    only."""
    from repro.kernels import resolve_impl

    return resolve_impl("REPRO_QUANT_MATMUL", "fused", "dequant")


def matmul(x: jnp.ndarray, w, tp=None) -> jnp.ndarray:
    """``x [..., d_in] @ w`` where ``w`` may be an :class:`Int4Weight`.

    Quantized 2-D weights route through the fused Pallas dequant×matmul
    kernel (kernels/quant_matmul.py) when enabled; everything else falls
    back to ``dequant() @ x`` (the jnp reference the kernel is tested
    against).

    Under a tensor-parallel mesh (`model` axis > 1) the quantized planes
    are sharded per `distributed.specs.param_specs`, and a monolithic
    pallas_call inside the SPMD program would force XLA to all-gather
    them. ``tp`` carries the weight's serve-mode matrix role from the call
    site — ``"col"`` (out-dim → `model`: wq/wk/wv/up/gate/lm_head) or
    ``"row"`` (in-dim → `model`: wo/w_down) — which selects the matching
    `shard_map` entry (`kernels.ops.int4_matmul_tp`): the unchanged fused
    kernel runs on each shard's local slice, with the row case paying the
    same post-projection `psum` as fp. Call sites without a role (or with
    planes the divisibility guard left replicated) fall back to the
    sharded dequant+dot, which GSPMD partitions as before."""
    if not isinstance(w, Int4Weight):
        return x @ w.astype(x.dtype)
    if matmul_impl() == "fused":
        from repro.distributed.sharding import model_parallel_size
        from repro.kernels import quant_matmul as QM
        if QM.supports(x, w):
            if model_parallel_size() == 1:
                # interpret resolution deferred to interpret_default()
                return QM.fused_matmul(x, w)
            if tp is not None:
                from repro.kernels.ops import int4_matmul_tp
                out = int4_matmul_tp(x, w, tp)
                if out is not None:
                    return out
    return x @ w.dequant(x.dtype)
