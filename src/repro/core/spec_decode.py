"""The QuantSpec self-speculative decoding round (Algorithm 1).

One round =
  1. draft γ tokens autoregressively with the 4-bit view: INT4 weights +
     upper-4-bit KV cache (+ the shared FP buffer). Draft cache writes are
     *discarded wholesale* at the end of the round — functionally this is
     the paper's REJECTCACHE, done by never committing the draft's state.
  2. target verifies all γ+1 positions in ONE pass with the INT8
     (both-plane) KV view and full-precision weights, appending its own KV
     for the window (overwriting what the draft would have written — the
     paper's TARGET(...) → C_F2 update).
  3. speculative-sampling accept/reject; attention caches roll back the
     rejected tail, recurrent (Mamba/RWKV) layers commit the per-token
     state snapshot at the acceptance point.

The whole round is one jittable function; the engine drives it in a Python
loop until `max_new_tokens`.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import acceptance
from repro.serving.sampling import sample_token


class RoundResult(NamedTuple):
    state: dict
    tokens: jnp.ndarray       # [B, gamma+1] new tokens (n_new valid)
    n_new: jnp.ndarray        # scalar
    last_token: jnp.ndarray   # [B, 1(, K)] token to feed next round
    accept_mask: jnp.ndarray  # [B, gamma]


def spec_round(model, target_params, draft_params, state, last_token,
               stream_pos, key, *, gamma: int, policy: str = "quantspec",
               greedy: bool = False, temperature: float = 1.0,
               ctx_kw=None) -> RoundResult:
    """last_token [B, 1] (or [B, 1, K] for codebooks). stream_pos = number
    of tokens already processed by the target (cache length)."""
    multi = model.cfg.num_codebooks > 0
    keys = jax.random.split(key, gamma + 2)

    # ---- 1. draft γ tokens -------------------------------------------------
    draft_state = state
    cur = last_token
    toks, qlist = [], []
    for i in range(gamma):
        dl, draft_state, _ = model.decode(
            draft_params, cur, draft_state, stream_pos + i,
            kv_mode="draft", policy=policy, ctx_kw=ctx_kw)
        logits = dl[:, -1] / temperature
        nxt = sample_token(logits, keys[i], greedy)       # [B] or [B, K]
        q = jax.nn.softmax(logits, axis=-1)
        toks.append(nxt)
        qlist.append(q)
        cur = nxt[:, None]
    draft_tokens = jnp.stack(toks, axis=1)                # [B, γ(,K)]
    draft_probs = jnp.stack(qlist, axis=1)                # [B, γ(,K), V]

    # ---- 2. target verifies in one pass ------------------------------------
    tgt_in = jnp.concatenate([last_token, draft_tokens], axis=1)  # [B, γ+1]
    tl, t_state, snaps = model.decode(
        target_params, tgt_in, state, stream_pos, kv_mode="target",
        policy=policy, collect=True, ctx_kw=ctx_kw)
    target_probs = jax.nn.softmax(tl / temperature, axis=-1)  # [B, γ+1(,K), V]

    # ---- 3. verify + commit -------------------------------------------------
    if multi:
        res = acceptance.verify_greedy_multi(draft_tokens, target_probs)
    else:
        res = acceptance.verify(draft_tokens, draft_probs, target_probs,
                                keys[gamma], greedy=greedy)
    new_state = model.commit(t_state, snaps, res.n_accepted, gamma + 1)

    last = jax.lax.dynamic_slice_in_dim(res.tokens, res.n_accepted, 1, axis=1)
    return RoundResult(state=new_state, tokens=res.tokens, n_new=res.n_new,
                       last_token=last, accept_mask=res.accept_mask_b)


def ar_step(model, params, state, last_token, stream_pos, key, *,
            policy: str = "fp", greedy: bool = False, temperature: float = 1.0,
            kv_mode: str = "target", ctx_kw=None):
    """Plain autoregressive step (the paper's AR baseline)."""
    tl, new_state, _ = model.decode(params, last_token, state, stream_pos,
                                    kv_mode=kv_mode, policy=policy,
                                    ctx_kw=ctx_kw)
    nxt = sample_token(tl[:, -1] / temperature, key, greedy)
    return new_state, nxt[:, None]
