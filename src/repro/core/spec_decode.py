"""The QuantSpec self-speculative decoding round (Algorithm 1).

One round =
  1. draft γ tokens autoregressively with the 4-bit view: INT4 weights +
     upper-4-bit KV cache (+ the shared FP buffer). Draft cache writes are
     *discarded wholesale* at the end of the round — functionally this is
     the paper's REJECTCACHE, done by never committing the draft's state.
  2. target verifies all γ+1 positions in ONE pass with the INT8
     (both-plane) KV view and full-precision weights, appending its own KV
     for the window (overwriting what the draft would have written — the
     paper's TARGET(...) → C_F2 update).
  3. speculative-sampling accept/reject; attention caches roll back the
     rejected tail, recurrent (Mamba/RWKV) layers commit the per-token
     state snapshot at the acceptance point.

`paged_spec_round` is the continuous-batching variant over the paged cache
(core/paged_kv_cache.py): per-slot stream positions, per-sequence
accept/rollback — requests of different lengths progress raggedly within
one jitted program.

Megasteps
---------
Driving one jitted round per Python-loop iteration pays a device→host sync
(read back tokens/accept counts) plus per-slot host bookkeeping before the
next round can even be dispatched — at small batch the serving loop is
dispatch-bound, not HBM-bound. :func:`megastep` / :func:`paged_megastep`
fuse ``rounds`` consecutive spec rounds into ONE jitted program: a
`lax.scan` over the round whose carry holds the cache state, page table,
last tokens, and the device-resident per-slot request state
(:class:`~repro.serving.scheduler.SlotState`: generated counts, budgets,
done mask). Budget clamping, EOS detection, and termination masking happen
on device — a slot that finishes mid-megastep is *frozen* (its page-table
row deactivated, its takes zeroed) rather than synced — and each round's
tokens/stats are stacked into packed ``[rounds, ...]`` buffers the engine
reads back with a **single** transfer per megastep.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import acceptance
from repro.serving.sampling import maybe_top_p, sample_token
from repro.serving.scheduler import SlotState

#: degradation-ladder rungs walked by the precision governor
RUNG_INT4 = 0        # full-γ speculation, INT4 (upper-nibble) draft KV
RUNG_INT4_LOW = 1    # reduced-γ speculation, INT4 draft KV
RUNG_INT8 = 2        # full-γ speculation, INT8 (both-plane) draft KV
RUNG_AR = 3          # verify-only AR floor (γ_eff = 0 except probe rounds)
NUM_RUNGS = 4


class GovernorConfig(NamedTuple):
    """Static thresholds for the per-slot acceptance-aware precision
    governor (ISSUE 10).  All fields are Python scalars baked into the
    megastep's jit hash — ladder transitions themselves are pure masking
    on device, so no threshold change or rung walk ever recompiles.

    A slot demotes one rung when its rolling-window acceptance rate drops
    below ``floor`` and promotes one rung when it recovers past
    ``ceiling`` (``floor < ceiling`` gives the hysteresis band).  The
    window evaluates once ``window`` tokens have been proposed; on a
    transition it resets, otherwise it halves (old evidence decays).  On
    the AR floor, every ``probe_every`` rounds the slot runs one full-γ
    INT8 probe round; strong acceptance in the probe re-escalates to
    :data:`RUNG_INT8`, anything else stays on the floor."""

    window: int = 32       # proposed tokens per window evaluation
    floor: float = 0.5     # demote below this windowed acceptance rate
    ceiling: float = 0.8   # promote at/above this rate (hysteresis band)
    probe_every: int = 8   # AR-floor probe cadence, in megastep rounds
    gamma_lo: int = 0      # rung-1 effective γ; 0 → max(1, γ // 2)


def governor_plan(gov: GovernorConfig, gamma: int, slots: SlotState):
    """Per-round ladder decode: ``(gamma_eff [R], draft_bits [R], probing
    [R])`` from the carried slot state.  ``gamma_eff`` is each slot's
    effective speculation depth this round (0 on the AR floor), and
    ``draft_bits`` flags slots whose draft KV read escalates to INT8
    (rung 2, and probe rounds — a probe should test the *best* draft the
    ladder can offer before concluding acceptance recovered)."""
    probing = (slots.rung == RUNG_AR) & (slots.probe <= 0)
    g_lo = gov.gamma_lo if gov.gamma_lo > 0 else max(1, gamma // 2)
    gamma_eff = jnp.select(
        [slots.rung == RUNG_INT4_LOW, slots.rung == RUNG_AR],
        [jnp.full_like(slots.rung, min(g_lo, gamma)),
         jnp.where(probing, gamma, 0)],
        gamma).astype(jnp.int32)
    draft_bits = (slots.rung == RUNG_INT8) | probing
    return gamma_eff, draft_bits, probing


def governor_update(gov: GovernorConfig, slots: SlotState, live, prop, acc,
                    probing) -> SlotState:
    """Fold one round's per-slot (proposed, accepted) into the rolling
    window and walk the ladder.  Pure element-wise masking — safe inside
    the megastep scan body on any rung mix."""
    rung, wp, wa = slots.rung, slots.win_prop, slots.win_acc
    upd = live & ~probing & (prop > 0)
    wp = jnp.where(upd, wp + prop, wp)
    wa = jnp.where(upd, wa + acc, wa)
    evaluate = upd & (wp >= gov.window)
    fwp = wp.astype(jnp.float32)
    fwa = wa.astype(jnp.float32)
    demote = evaluate & (fwa < gov.floor * fwp) & (rung < RUNG_AR)
    promote = evaluate & (fwa >= gov.ceiling * fwp) & (rung > RUNG_INT4)
    new_rung = rung + demote.astype(jnp.int32) - promote.astype(jnp.int32)
    # probe outcome: a floor slot that just ran its full-γ probe round
    # re-escalates to INT8 on strong single-round acceptance, else stays
    probed = probing & live
    probe_ok = probed & (acc.astype(jnp.float32) >= gov.ceiling
                         * jnp.maximum(prop, 1).astype(jnp.float32))
    new_rung = jnp.where(probed,
                         jnp.where(probe_ok, RUNG_INT8, RUNG_AR), new_rung)
    moved = (new_rung != rung) | probed
    wp = jnp.where(moved, 0, jnp.where(evaluate, wp // 2, wp))
    wa = jnp.where(moved, 0, jnp.where(evaluate, wa // 2, wa))
    at_floor = new_rung == RUNG_AR
    probe = jnp.where(at_floor,
                      jnp.where((rung != RUNG_AR) | probed,
                                gov.probe_every, slots.probe - 1),
                      slots.probe)
    return slots._replace(rung=new_rung, win_prop=wp, win_acc=wa,
                          probe=probe)


def _nonfinite_rows(logits: jnp.ndarray) -> jnp.ndarray:
    """Per-sequence count of verify positions whose logit rows carry any
    non-finite entry — the device half of the request-level
    ``numerics_flags`` counter (sampling already falls back to
    greedy-over-finite; this only *counts* the incidents)."""
    bad = ~jnp.all(jnp.isfinite(logits), axis=-1)     # [B, T(, K)]
    return jnp.sum(bad.reshape(bad.shape[0], -1), axis=-1).astype(jnp.int32)


class RoundResult(NamedTuple):
    state: dict
    tokens: jnp.ndarray       # [B, gamma+1] new tokens (n_new valid)
    n_new: jnp.ndarray        # scalar
    last_token: jnp.ndarray   # [B, 1(, K)] token to feed next round
    accept_mask: jnp.ndarray  # [B, gamma]
    nonfinite: jnp.ndarray    # i32 [B] — non-finite target logit rows


def spec_round(model, target_params, draft_params, state, last_token,
               stream_pos, key, *, gamma: int, policy: str = "quantspec",
               greedy: bool = False, temperature: float = 1.0,
               top_p=None, ctx_kw=None, gamma_eff: Optional[int] = None,
               draft_int8: bool = False) -> RoundResult:
    """last_token [B, 1] (or [B, 1, K] for codebooks). stream_pos = number
    of tokens already processed by the target (cache length).

    ``top_p`` filters BOTH the draft proposal q and the target p, so
    speculative sampling stays exact w.r.t. the filtered target.

    ``gamma_eff``/``draft_int8`` are the static engine's forced governor
    rung (batch-wide, static): draft positions ≥ ``gamma_eff`` are
    force-rejected in verification, and ``draft_int8`` escalates the
    draft's KV read to the INT8 both-plane view (the draft still runs
    INT4 *weights* — only the cache read widens)."""
    multi = model.cfg.num_codebooks > 0
    keys = jax.random.split(key, gamma + 2)
    draft_kv = "target" if draft_int8 else "draft"

    # ---- 1. draft γ tokens -------------------------------------------------
    # One traced step + lax.scan over γ: trace/compile time is constant in
    # gamma instead of linear (the γ-unrolled loop re-traced the whole
    # decode stack per draft token).
    def draft_step(carry, inp):
        d_state, cur = carry
        i, k_i = inp
        dl, d_state, _ = model.decode(
            draft_params, cur, d_state, stream_pos + i,
            kv_mode=draft_kv, policy=policy, ctx_kw=ctx_kw)
        logits = maybe_top_p(dl[:, -1] / temperature, top_p)
        nxt = sample_token(logits, k_i, greedy)           # [B] or [B, K]
        q = jax.nn.softmax(logits, axis=-1)
        return (d_state, nxt[:, None].astype(cur.dtype)), (nxt, q)

    _, (toks, qlist) = jax.lax.scan(
        draft_step, (state, last_token),
        (jnp.arange(gamma), keys[:gamma]))
    draft_tokens = jnp.moveaxis(toks, 0, 1)               # [B, γ(,K)]
    draft_probs = jnp.moveaxis(qlist, 0, 1)               # [B, γ(,K), V]

    # ---- 2. target verifies in one pass ------------------------------------
    tgt_in = jnp.concatenate([last_token, draft_tokens], axis=1)  # [B, γ+1]
    tl, t_state, snaps = model.decode(
        target_params, tgt_in, state, stream_pos, kv_mode="target",
        policy=policy, collect=True, ctx_kw=ctx_kw)
    target_probs = jax.nn.softmax(
        maybe_top_p(tl / temperature, top_p), axis=-1)    # [B, γ+1(,K), V]

    # ---- 3. verify + commit -------------------------------------------------
    if multi:
        assert gamma_eff is None, "governor rungs are single-codebook"
        res = acceptance.verify_greedy_multi(draft_tokens, target_probs)
    else:
        res = acceptance.verify(draft_tokens, draft_probs, target_probs,
                                keys[gamma], greedy=greedy,
                                gamma_eff=gamma_eff)
    new_state = model.commit(t_state, snaps, res.n_accepted, gamma + 1)

    last = jax.lax.dynamic_slice_in_dim(res.tokens, res.n_accepted, 1, axis=1)
    return RoundResult(state=new_state, tokens=res.tokens, n_new=res.n_new,
                       last_token=last, accept_mask=res.accept_mask_b,
                       nonfinite=_nonfinite_rows(tl))


class PagedRoundResult(NamedTuple):
    state: dict
    table: object             # PageTable pytree (post commit/rollback)
    tokens: jnp.ndarray       # [R, gamma+1] new tokens (n_new[r] valid)
    n_new: jnp.ndarray        # [R]
    last_token: jnp.ndarray   # [R, 1] token to feed next round
    accept_mask: jnp.ndarray  # [R, gamma]
    nonfinite: jnp.ndarray    # i32 [R] — non-finite target logit rows


def paged_spec_round(model, target_params, draft_params, state, table,
                     last_token, key, *, gamma: int, greedy: bool = False,
                     temperature: float = 1.0, top_p=None, ctx_kw=None,
                     gamma_eff=None, draft_bits=None, mangle=None
                     ) -> PagedRoundResult:
    """One continuous-batching QuantSpec round over the paged cache.

    Unlike :func:`spec_round`, every request slot keeps its own stream
    position (``table.pos``) and its own accepted length — commits and
    rollbacks are per-sequence, so requests of different lengths progress
    raggedly in one jitted program. Inactive slots compute garbage that is
    masked out of the table update and ignored by the engine.

    Governor hooks (all optional, all per-slot ``[R]`` arrays):

    ``gamma_eff``  i32 — effective speculation depth; draft positions ≥ it
                   are force-rejected in verification (0 = verify-only AR).
    ``draft_bits`` bool — escalate the slot's *draft* KV read from INT4 to
                   INT8 (both nibble planes); the target read is always
                   INT8, so only the draft call carries the flag.
    ``mangle``     i32 fault-injection switch (tests/fault_injection.py):
                   1 corrupts the slot's draft logits unconditionally, 2
                   only while the slot drafts from the INT4 view — a
                   deterministic acceptance collapse that INT8 escalation
                   measurably repairs. Verification is untouched, so
                   greedy outputs stay token-identical to AR decode.
    """
    from repro.core import paged_kv_cache as PC

    assert model.cfg.num_codebooks == 0, "paged engine is single-codebook"
    G = model.cfg.group_size
    keys = jax.random.split(key, gamma + 2)

    def run(params, tokens, st, tbl, pos, kv_mode, T, bits=None):
        tbl2, step = PC.plan_step(tbl, T, G)
        kw = dict(ctx_kw or {})
        kw["plan"] = PC.PagedPlan(step, tbl2)
        if bits is not None:
            kw["draft_bits"] = bits
        logits, new_st, _ = model.decode(params, tokens, st, pos,
                                         kv_mode=kv_mode, policy="paged",
                                         ctx_kw=kw)
        return logits, new_st, tbl2

    # ---- 1. draft γ tokens (cache writes discarded wholesale) --------------
    # lax.scan over γ (constant-in-gamma trace/compile, same as spec_round);
    # the per-slot table rides in the carry so flush decisions chain.
    def draft_step(carry, inp):
        d_state, d_table, cur = carry
        i, k_i = inp
        dl, d_state, d_table = run(draft_params, cur, d_state, d_table,
                                   table.pos + i, "draft", 1,
                                   bits=draft_bits)
        raw = dl[:, -1]
        if mangle is not None:
            bits = draft_bits if draft_bits is not None \
                else jnp.zeros((raw.shape[0],), bool)
            hit = (mangle == 1) | ((mangle == 2) & ~bits)
            raw = jnp.where(hit[:, None], jnp.roll(raw, 1, axis=-1), raw)
        logits = maybe_top_p(raw / temperature, top_p)
        nxt = sample_token(logits, k_i, greedy)                # [R]
        q = jax.nn.softmax(logits, axis=-1)
        return (d_state, d_table, nxt[:, None].astype(cur.dtype)), (nxt, q)

    _, (toks, qlist) = jax.lax.scan(
        draft_step, (state, table, last_token),
        (jnp.arange(gamma), keys[:gamma]))
    draft_tokens = jnp.moveaxis(toks, 0, 1)                    # [R, γ]
    draft_probs = jnp.moveaxis(qlist, 0, 1)                    # [R, γ, V]

    # ---- 2. target verifies all γ+1 positions in one pass ------------------
    tgt_in = jnp.concatenate([last_token, draft_tokens], axis=1)
    tl, t_state, v_table = run(target_params, tgt_in, state, table,
                               table.pos, "target", gamma + 1)
    target_probs = jax.nn.softmax(
        maybe_top_p(tl / temperature, top_p), axis=-1)

    # ---- 3. per-sequence verify + commit -----------------------------------
    res = acceptance.verify_per_seq(draft_tokens, draft_probs, target_probs,
                                    keys[gamma], greedy=greedy,
                                    gamma_eff=gamma_eff)
    rb = (gamma + 1) - res.n_new                               # [R]
    new_table = PC.commit(PC.rollback(v_table, rb), res.n_new)
    last = jnp.take_along_axis(res.tokens, res.n_accepted[:, None], axis=1)
    return PagedRoundResult(state=t_state, table=new_table, tokens=res.tokens,
                            n_new=res.n_new, last_token=last,
                            accept_mask=res.accept_mask_b,
                            nonfinite=_nonfinite_rows(tl))


def paged_ar_step(model, params, state, table, last_token, key, *,
                  greedy: bool = False, temperature: float = 1.0,
                  top_p=None, ctx_kw=None):
    """Plain autoregressive step on the paged cache (per-slot positions)."""
    from repro.core import paged_kv_cache as PC

    G = model.cfg.group_size
    tbl2, step = PC.plan_step(table, 1, G)
    kw = dict(ctx_kw or {})
    kw["plan"] = PC.PagedPlan(step, tbl2)
    tl, new_state, _ = model.decode(params, last_token, state, table.pos,
                                    kv_mode="target", policy="paged",
                                    ctx_kw=kw)
    nxt = sample_token(tl[:, -1] / temperature, key, greedy, top_p=top_p)
    n_new = jnp.ones((table.pos.shape[0],), jnp.int32)
    return new_state, PC.commit(tbl2, n_new), nxt[:, None], \
        _nonfinite_rows(tl)


def ar_step(model, params, state, last_token, stream_pos, key, *,
            policy: str = "fp", greedy: bool = False, temperature: float = 1.0,
            top_p=None, kv_mode: str = "target", ctx_kw=None):
    """Plain autoregressive step (the paper's AR baseline)."""
    tl, new_state, _ = model.decode(params, last_token, state, stream_pos,
                                    kv_mode=kv_mode, policy=policy,
                                    ctx_kw=ctx_kw)
    nxt = sample_token(tl[:, -1] / temperature, key, greedy, top_p=top_p)
    return new_state, nxt[:, None]


# ---------------------------------------------------------------------------
# megasteps: `rounds` fused spec rounds in one jitted program
# ---------------------------------------------------------------------------

def round_stats_dev(gamma, n_new, budget, tokens=None,
                    eos_id: Optional[int] = None):
    """Device-side :func:`repro.serving.engine.round_stats` — identical
    arithmetic, vectorized over slots, plus optional EOS truncation.

    ``n_new``/``budget`` are i32 ``[R]`` (or scalars); ``gamma`` may be a
    static int or the governor's per-slot ``gamma_eff [R]`` (0 for
    γ-masked / AR-floor rounds — such rounds report ``proposed = 0`` and
    ``accepted = 0``, and every rate consumer divides by
    ``max(proposed, 1)``, so zero-proposed rounds can never emit
    NaN). Returns ``(take, proposed_inc, accepted_inc, eos_hit)``:
    ``take = min(n_new, budget)`` tokens kept, further cut to end at the
    first EOS among them (inclusive) when ``eos_id`` is set; ``proposed``
    clamps γ by the *pre-round* budget only; ``accepted = max(min(take,
    n_new - 1), 0)`` — exactly the host helper's accounting, so
    per-request acceptance stats match the per-round loop bit for bit."""
    n_new = jnp.asarray(n_new, jnp.int32)
    budget = jnp.maximum(jnp.asarray(budget, jnp.int32), 0)
    take = jnp.minimum(n_new, budget)
    eos_hit = jnp.zeros(jnp.shape(take), bool)
    if eos_id is not None and tokens is not None:
        pos = jnp.arange(tokens.shape[-1])
        is_eos = (tokens == eos_id) & (pos[None, :] < take[..., None])
        eos_hit = jnp.any(is_eos, axis=-1)
        take = jnp.where(eos_hit, jnp.argmax(is_eos, axis=-1) + 1, take)
    proposed = jnp.minimum(gamma, budget)
    accepted = jnp.maximum(jnp.minimum(take, n_new - 1), 0)
    return take, proposed, accepted, eos_hit


class MegaResult(NamedTuple):
    """`rounds` fused static-engine spec rounds. The first four fields are
    the carried decode state (stay on device, feed the next megastep); the
    rest are the packed per-round buffers the engine reads back in one
    `device_get`. Skipped rounds (budget already met) report ``n_new=0``."""

    state: dict
    last_token: jnp.ndarray   # [B, 1(, K)]
    stream_pos: jnp.ndarray   # i32 scalar (post-megastep)
    generated: jnp.ndarray    # i32 scalar — includes the prefill token
    tokens: jnp.ndarray       # [rounds, B, gamma+1(, K)]
    n_new: jnp.ndarray        # i32 [rounds]
    proposed: jnp.ndarray     # i32 [rounds] (budget-clamped, per round_stats)
    accepted: jnp.ndarray     # i32 [rounds]
    nonfinite: jnp.ndarray    # i32 [rounds] — batch-summed numerics flags


def megastep(model, target_params, draft_params, state, last_token,
             stream_pos, generated, budget, key, *, rounds: int, gamma: int,
             policy: str = "quantspec", greedy: bool = False,
             temperature: float = 1.0, top_p=None, ctx_kw=None,
             gamma_eff: Optional[int] = None,
             draft_int8: bool = False) -> MegaResult:
    """``rounds`` consecutive :func:`spec_round`\\ s under one jit.

    ``generated``/``budget`` are traced i32 scalars (tokens produced so
    far incl. the prefill token / ``max_new_tokens``), so one compiled
    program serves every request length. Rounds past the budget are
    skipped via `lax.cond` — the carry passes through untouched and the
    packed buffers record ``n_new = 0`` — which keeps a trailing
    speculatively-dispatched megastep cheap and, crucially, stops cache
    appends once the request is done (the cache is sized to ``max_seq``,
    not ``max_seq + rounds·γ``)."""
    multi = model.cfg.num_codebooks > 0
    B = last_token.shape[0]
    tok_shape = (B, gamma + 1, model.cfg.num_codebooks) if multi \
        else (B, gamma + 1)

    def body(carry, _):
        state, last, pos, gen, key = carry
        key, kr = jax.random.split(key)

        def live(ops):
            state, last, pos, gen = ops
            res = spec_round(model, target_params, draft_params, state,
                             last, pos, kr, gamma=gamma, policy=policy,
                             greedy=greedy, temperature=temperature,
                             top_p=top_p, ctx_kw=ctx_kw,
                             gamma_eff=gamma_eff, draft_int8=draft_int8)
            g_stat = gamma if gamma_eff is None else gamma_eff
            _, prop, acc, _ = round_stats_dev(g_stat, res.n_new,
                                              budget - gen)
            return ((res.state, res.last_token, pos + res.n_new,
                     gen + res.n_new),
                    (res.tokens.astype(jnp.int32), res.n_new, prop, acc,
                     jnp.sum(res.nonfinite)))

        def skip(ops):
            zero = jnp.zeros((), jnp.int32)
            return ops, (jnp.zeros(tok_shape, jnp.int32), zero, zero, zero,
                         zero)

        new_carry, ys = jax.lax.cond(gen < budget, live, skip,
                                     (state, last, pos, gen))
        return (*new_carry, key), ys

    pos0 = jnp.asarray(stream_pos, jnp.int32)
    gen0 = jnp.asarray(generated, jnp.int32)
    (state, last, pos, gen, _), (toks, n_new, prop, acc, nf) = jax.lax.scan(
        body, (state, last_token, pos0, gen0, key), length=rounds)
    return MegaResult(state=state, last_token=last, stream_pos=pos,
                      generated=gen, tokens=toks, n_new=n_new,
                      proposed=prop, accepted=acc, nonfinite=nf)


class PagedMegaResult(NamedTuple):
    """`rounds` fused continuous-engine spec rounds. ``state``/``table``/
    ``last_token``/``slots`` are the carried decode state; the packed
    per-round buffers (plus the tiny per-slot ``first``/``done`` vectors)
    are what the engine reads back — one `device_get` per megastep."""

    state: dict
    table: object             # PageTable (finished slots deactivated)
    last_token: jnp.ndarray   # [R, 1]
    slots: SlotState          # device-resident per-slot request state
    tokens: jnp.ndarray       # [rounds, R, gamma+1]
    take: jnp.ndarray         # i32 [rounds, R] — tokens kept (0 = frozen)
    proposed: jnp.ndarray     # i32 [rounds, R]
    accepted: jnp.ndarray     # i32 [rounds, R]
    nonfinite: jnp.ndarray    # i32 [rounds, R] — live-masked numerics flags
    rung: jnp.ndarray         # i32 [rounds, R] — governor ladder rung after
                              # each round (carried value on skipped rounds)
    first: jnp.ndarray        # i32 [R] — carried-in last token (the
                              # prefill-sampled first token of slots whose
                              # admission finalized since the last readback)
    done: jnp.ndarray         # bool [R] — post-megastep done mask


def paged_megastep(model, target_params, draft_params, state, table,
                   last_token, slots: SlotState, key, mangle=None, *,
                   rounds: int, gamma: int, greedy: bool = False,
                   temperature: float = 1.0, top_p=None,
                   eos_id: Optional[int] = None, ctx_kw=None,
                   governor: Optional[GovernorConfig] = None
                   ) -> PagedMegaResult:
    """``rounds`` consecutive :func:`paged_spec_round`\\ s under one jit,
    with per-slot accept/rollback, budget clamping, EOS detection, and
    termination masking all device-resident.

    A slot that reaches its budget (or samples EOS) mid-megastep executes
    its finishing round normally — exactly as the per-round loop, which
    retires *after* the full round commit — and is then **frozen**: its
    page-table row is deactivated, so later rounds neither flush nor
    commit for it (`plan_step`/`commit`/`rollback` mask on ``active``) and
    its buffer writes land past ``buf_len`` where attention masks them
    out. Its pool blocks are returned to the free stack by the engine at
    the next harvest (`release_slot`), off the hot path. Rounds where no
    slot is live short-circuit via `lax.cond` (zeroed packed rows).

    With a :class:`GovernorConfig`, every round first decodes the carried
    per-slot ladder state into ``(gamma_eff, draft_bits)`` masks
    (:func:`governor_plan`), runs the round under them, and folds the
    observed acceptance back (:func:`governor_update`) — all transitions
    are masking inside this one compiled program.  When no live slot
    speculates (every survivor is on the AR floor, none probing), a
    nested `lax.cond` swaps the whole spec round for a single fused
    1-token target step, so a fully-collapsed batch decodes at plain-AR
    cost instead of paying γ wasted drafts per token.  ``mangle``
    (i32 ``[R]``) is the fault-injection switch forwarded to
    :func:`paged_spec_round`."""
    assert gamma > 0, "paged_megastep fuses spec rounds; use the AR loop " \
                      "for gamma=0"
    R = last_token.shape[0]

    def body(carry, _):
        state, table, last, slots, key = carry
        key, kr = jax.random.split(key)
        live = table.active & ~slots.done

        def run(ops):
            state, table, last, slots = ops
            if governor is not None:
                gamma_eff, draft_bits, probing = governor_plan(
                    governor, gamma, slots)
            else:
                gamma_eff = draft_bits = None
                probing = jnp.zeros((R,), bool)

            def spec_path(ops):
                state, table, last, slots = ops
                res = paged_spec_round(
                    model, target_params, draft_params, state, table, last,
                    kr, gamma=gamma, greedy=greedy, temperature=temperature,
                    top_p=top_p, ctx_kw=ctx_kw, gamma_eff=gamma_eff,
                    draft_bits=draft_bits, mangle=mangle)
                g_eff = gamma if gamma_eff is None else gamma_eff
                take, prop, acc, eos_hit = round_stats_dev(
                    g_eff, res.n_new, slots.budget - slots.generated,
                    res.tokens, eos_id)
                return (res.state, res.table, res.last_token,
                        res.tokens.astype(jnp.int32), take, prop, acc,
                        res.nonfinite, eos_hit)

            def ar_path(ops):
                state, table, last, slots = ops
                new_state, new_table, nxt, nf = paged_ar_step(
                    model, target_params, state, table, last, kr,
                    greedy=greedy, temperature=temperature, top_p=top_p,
                    ctx_kw=ctx_kw)
                tokens = jnp.pad(nxt.astype(jnp.int32),
                                 ((0, 0), (0, gamma)))
                take, prop, acc, eos_hit = round_stats_dev(
                    0, jnp.ones((R,), jnp.int32),
                    slots.budget - slots.generated, tokens, eos_id)
                return (new_state, new_table, nxt, tokens, take, prop, acc,
                        nf, eos_hit)

            if governor is None:
                (new_state, new_table, new_last, tokens, take, prop, acc,
                 nf, eos_hit) = spec_path(ops)
            else:
                # AR-floor fast path: both branches compile into this one
                # megastep program, so walking on/off the floor never
                # recompiles — it just flips which branch executes.
                (new_state, new_table, new_last, tokens, take, prop, acc,
                 nf, eos_hit) = jax.lax.cond(
                     jnp.any(live & (gamma_eff > 0)), spec_path, ar_path,
                     ops)

            take = jnp.where(live, take, 0)
            prop = jnp.where(live, prop, 0)
            acc = jnp.where(live, acc, 0)
            nf = jnp.where(live, nf, 0)
            gen = slots.generated + take
            done = slots.done | (live & ((gen >= slots.budget) | eos_hit))
            new_slots = slots._replace(generated=gen, done=done)
            if governor is not None:
                new_slots = governor_update(governor, new_slots, live,
                                            prop, acc, probing)
            # freeze finished slots: inactive rows are ignored by
            # plan/commit/rollback, so the remaining rounds leave them be
            new_table = new_table._replace(active=new_table.active & ~done)
            return ((new_state, new_table, new_last, new_slots),
                    (tokens, take, prop, acc, nf, new_slots.rung))

        def skip(ops):
            zeros = jnp.zeros((R,), jnp.int32)
            # rung passes through (zeros would read back as a spurious
            # transition to rung 0 at harvest)
            return ops, (jnp.zeros((R, gamma + 1), jnp.int32),
                         zeros, zeros, zeros, zeros, ops[3].rung)

        new_carry, ys = jax.lax.cond(jnp.any(live), run, skip,
                                     (state, table, last, slots))
        return (*new_carry, key), ys

    first = jnp.asarray(last_token[:, 0], jnp.int32)
    (state, table, last, slots, _), (toks, take, prop, acc, nf, rung) = \
        jax.lax.scan(body, (state, table, last_token, slots, key),
                     length=rounds)
    return PagedMegaResult(state=state, table=table, last_token=last,
                           slots=slots, tokens=toks, take=take,
                           proposed=prop, accepted=acc, nonfinite=nf,
                           rung=rung, first=first, done=slots.done)
