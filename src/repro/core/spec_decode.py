"""The QuantSpec self-speculative decoding round (Algorithm 1).

One round =
  1. draft γ tokens autoregressively with the 4-bit view: INT4 weights +
     upper-4-bit KV cache (+ the shared FP buffer). Draft cache writes are
     *discarded wholesale* at the end of the round — functionally this is
     the paper's REJECTCACHE, done by never committing the draft's state.
  2. target verifies all γ+1 positions in ONE pass with the INT8
     (both-plane) KV view and full-precision weights, appending its own KV
     for the window (overwriting what the draft would have written — the
     paper's TARGET(...) → C_F2 update).
  3. speculative-sampling accept/reject; attention caches roll back the
     rejected tail, recurrent (Mamba/RWKV) layers commit the per-token
     state snapshot at the acceptance point.

The whole round is one jittable function; the engine drives it in a Python
loop until `max_new_tokens`.

`paged_spec_round` is the continuous-batching variant over the paged cache
(core/paged_kv_cache.py): per-slot stream positions, per-sequence
accept/rollback — requests of different lengths progress raggedly within
one jitted program.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import acceptance
from repro.serving.sampling import maybe_top_p, sample_token


class RoundResult(NamedTuple):
    state: dict
    tokens: jnp.ndarray       # [B, gamma+1] new tokens (n_new valid)
    n_new: jnp.ndarray        # scalar
    last_token: jnp.ndarray   # [B, 1(, K)] token to feed next round
    accept_mask: jnp.ndarray  # [B, gamma]


def spec_round(model, target_params, draft_params, state, last_token,
               stream_pos, key, *, gamma: int, policy: str = "quantspec",
               greedy: bool = False, temperature: float = 1.0,
               top_p=None, ctx_kw=None) -> RoundResult:
    """last_token [B, 1] (or [B, 1, K] for codebooks). stream_pos = number
    of tokens already processed by the target (cache length).

    ``top_p`` filters BOTH the draft proposal q and the target p, so
    speculative sampling stays exact w.r.t. the filtered target."""
    multi = model.cfg.num_codebooks > 0
    keys = jax.random.split(key, gamma + 2)

    # ---- 1. draft γ tokens -------------------------------------------------
    # One traced step + lax.scan over γ: trace/compile time is constant in
    # gamma instead of linear (the γ-unrolled loop re-traced the whole
    # decode stack per draft token).
    def draft_step(carry, inp):
        d_state, cur = carry
        i, k_i = inp
        dl, d_state, _ = model.decode(
            draft_params, cur, d_state, stream_pos + i,
            kv_mode="draft", policy=policy, ctx_kw=ctx_kw)
        logits = maybe_top_p(dl[:, -1] / temperature, top_p)
        nxt = sample_token(logits, k_i, greedy)           # [B] or [B, K]
        q = jax.nn.softmax(logits, axis=-1)
        return (d_state, nxt[:, None].astype(cur.dtype)), (nxt, q)

    _, (toks, qlist) = jax.lax.scan(
        draft_step, (state, last_token),
        (jnp.arange(gamma), keys[:gamma]))
    draft_tokens = jnp.moveaxis(toks, 0, 1)               # [B, γ(,K)]
    draft_probs = jnp.moveaxis(qlist, 0, 1)               # [B, γ(,K), V]

    # ---- 2. target verifies in one pass ------------------------------------
    tgt_in = jnp.concatenate([last_token, draft_tokens], axis=1)  # [B, γ+1]
    tl, t_state, snaps = model.decode(
        target_params, tgt_in, state, stream_pos, kv_mode="target",
        policy=policy, collect=True, ctx_kw=ctx_kw)
    target_probs = jax.nn.softmax(
        maybe_top_p(tl / temperature, top_p), axis=-1)    # [B, γ+1(,K), V]

    # ---- 3. verify + commit -------------------------------------------------
    if multi:
        res = acceptance.verify_greedy_multi(draft_tokens, target_probs)
    else:
        res = acceptance.verify(draft_tokens, draft_probs, target_probs,
                                keys[gamma], greedy=greedy)
    new_state = model.commit(t_state, snaps, res.n_accepted, gamma + 1)

    last = jax.lax.dynamic_slice_in_dim(res.tokens, res.n_accepted, 1, axis=1)
    return RoundResult(state=new_state, tokens=res.tokens, n_new=res.n_new,
                       last_token=last, accept_mask=res.accept_mask_b)


class PagedRoundResult(NamedTuple):
    state: dict
    table: object             # PageTable pytree (post commit/rollback)
    tokens: jnp.ndarray       # [R, gamma+1] new tokens (n_new[r] valid)
    n_new: jnp.ndarray        # [R]
    last_token: jnp.ndarray   # [R, 1] token to feed next round
    accept_mask: jnp.ndarray  # [R, gamma]


def paged_spec_round(model, target_params, draft_params, state, table,
                     last_token, key, *, gamma: int, greedy: bool = False,
                     temperature: float = 1.0, top_p=None, ctx_kw=None
                     ) -> PagedRoundResult:
    """One continuous-batching QuantSpec round over the paged cache.

    Unlike :func:`spec_round`, every request slot keeps its own stream
    position (``table.pos``) and its own accepted length — commits and
    rollbacks are per-sequence, so requests of different lengths progress
    raggedly in one jitted program. Inactive slots compute garbage that is
    masked out of the table update and ignored by the engine.
    """
    from repro.core import paged_kv_cache as PC

    assert model.cfg.num_codebooks == 0, "paged engine is single-codebook"
    G = model.cfg.group_size
    keys = jax.random.split(key, gamma + 2)

    def run(params, tokens, st, tbl, pos, kv_mode, T):
        tbl2, step = PC.plan_step(tbl, T, G)
        kw = dict(ctx_kw or {})
        kw["plan"] = PC.PagedPlan(step, tbl2)
        logits, new_st, _ = model.decode(params, tokens, st, pos,
                                         kv_mode=kv_mode, policy="paged",
                                         ctx_kw=kw)
        return logits, new_st, tbl2

    # ---- 1. draft γ tokens (cache writes discarded wholesale) --------------
    # lax.scan over γ (constant-in-gamma trace/compile, same as spec_round);
    # the per-slot table rides in the carry so flush decisions chain.
    def draft_step(carry, inp):
        d_state, d_table, cur = carry
        i, k_i = inp
        dl, d_state, d_table = run(draft_params, cur, d_state, d_table,
                                   table.pos + i, "draft", 1)
        logits = maybe_top_p(dl[:, -1] / temperature, top_p)
        nxt = sample_token(logits, k_i, greedy)                # [R]
        q = jax.nn.softmax(logits, axis=-1)
        return (d_state, d_table, nxt[:, None].astype(cur.dtype)), (nxt, q)

    _, (toks, qlist) = jax.lax.scan(
        draft_step, (state, table, last_token),
        (jnp.arange(gamma), keys[:gamma]))
    draft_tokens = jnp.moveaxis(toks, 0, 1)                    # [R, γ]
    draft_probs = jnp.moveaxis(qlist, 0, 1)                    # [R, γ, V]

    # ---- 2. target verifies all γ+1 positions in one pass ------------------
    tgt_in = jnp.concatenate([last_token, draft_tokens], axis=1)
    tl, t_state, v_table = run(target_params, tgt_in, state, table,
                               table.pos, "target", gamma + 1)
    target_probs = jax.nn.softmax(
        maybe_top_p(tl / temperature, top_p), axis=-1)

    # ---- 3. per-sequence verify + commit -----------------------------------
    res = acceptance.verify_per_seq(draft_tokens, draft_probs, target_probs,
                                    keys[gamma], greedy=greedy)
    rb = (gamma + 1) - res.n_new                               # [R]
    new_table = PC.commit(PC.rollback(v_table, rb), res.n_new)
    last = jnp.take_along_axis(res.tokens, res.n_accepted[:, None], axis=1)
    return PagedRoundResult(state=t_state, table=new_table, tokens=res.tokens,
                            n_new=res.n_new, last_token=last,
                            accept_mask=res.accept_mask_b)


def paged_ar_step(model, params, state, table, last_token, key, *,
                  greedy: bool = False, temperature: float = 1.0,
                  top_p=None, ctx_kw=None):
    """Plain autoregressive step on the paged cache (per-slot positions)."""
    from repro.core import paged_kv_cache as PC

    G = model.cfg.group_size
    tbl2, step = PC.plan_step(table, 1, G)
    kw = dict(ctx_kw or {})
    kw["plan"] = PC.PagedPlan(step, tbl2)
    tl, new_state, _ = model.decode(params, last_token, state, table.pos,
                                    kv_mode="target", policy="paged",
                                    ctx_kw=kw)
    nxt = sample_token(tl[:, -1] / temperature, key, greedy, top_p=top_p)
    n_new = jnp.ones((table.pos.shape[0],), jnp.int32)
    return new_state, PC.commit(tbl2, n_new), nxt[:, None]


def ar_step(model, params, state, last_token, stream_pos, key, *,
            policy: str = "fp", greedy: bool = False, temperature: float = 1.0,
            top_p=None, kv_mode: str = "target", ctx_kw=None):
    """Plain autoregressive step (the paper's AR baseline)."""
    tl, new_state, _ = model.decode(params, last_token, state, stream_pos,
                                    kv_mode=kv_mode, policy=policy,
                                    ctx_kw=ctx_kw)
    nxt = sample_token(tl[:, -1] / temperature, key, greedy, top_p=top_p)
    return new_state, nxt[:, None]
