"""Host-side radix tree over quantized-prefix blocks: cross-request prefix
caching for the paged hierarchical KV cache.

QuantSpec's quant groups are immutable once full, which makes a completed
pool block a natural unit of cross-request reuse (shared system prompts,
few-shot templates, multi-turn history).  The index is a radix tree whose
edges are ``G``-token keys: a node at depth ``d`` represents the prompt
prefix formed by the keys on its root path and records

* ``block_id`` — the pool block holding that group's quantized planes
  (``-1`` for the static engine's dense path, which has no pool), and
* ``fp`` — the group's **full-precision** K/V per attention layer, host
  resident (the ROADMAP's host tier: cheap DRAM, not HBM).

The fp payload is what makes cached admission *bit-exact*: a hit seeds the
new request's transient :class:`~repro.core.paged_kv_cache.PrefillScratch`
with the prefix fp, so the uncached suffix attends exactly the history a
cold prefill would have computed — greedy outputs are token-identical, not
merely close (asserted in tests/test_prefix_cache.py).  Quantization is
deterministic, so the one re-packed tail group (copy-on-write at the ragged
fp window) reproduces the original block bit-for-bit.

Only *prefill-computed* groups are inserted (``blocks(S) = max(0,
(S-G)//G)`` groups of the prompt): decode-produced K/V attends quantized
history and would poison the exactness contract.

The tree is pure host bookkeeping — device refcounts
(:func:`~repro.core.paged_kv_cache.retain_blocks` /
:func:`~repro.core.paged_kv_cache.evict_blocks`) are the engine's job; the
index only decides *what* to share and *what* to evict (LRU over leaves,
never a shielded or interior node, so the tree stays prefix-closed).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class PrefixNode:
    """One indexed quant group: the ``G``-token key extending the parent's
    prefix, its pool block, and the group's host-resident fp K/V (one
    ``(k, v)`` pair per attention layer in engine walk order, token axis at
    ``-3``)."""

    key: Tuple[int, ...]
    block_id: int
    fp: List[Tuple[np.ndarray, np.ndarray]]
    children: Dict[Tuple[int, ...], "PrefixNode"] = dataclasses.field(
        default_factory=dict)
    parent: Optional["PrefixNode"] = None
    last_used: int = 0

    @property
    def is_leaf(self) -> bool:
        return not self.children


class PrefixIndex:
    """Radix tree over token-id block keys → (pool block, fp payload)."""

    def __init__(self, group: int):
        self.group = group
        self.children: Dict[Tuple[int, ...], PrefixNode] = {}  # root edges
        self._clock = 0
        self.blocks = 0          # indexed pool blocks (block_id >= 0)
        self.hits = 0            # match() calls that returned >= 1 node
        self.misses = 0
        self.hit_tokens = 0      # prompt tokens covered by matches

    # ------------------------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    @staticmethod
    def _keys(tokens: Sequence[int], group: int) -> List[Tuple[int, ...]]:
        toks = [int(t) for t in tokens]
        n = len(toks) // group
        return [tuple(toks[g * group:(g + 1) * group]) for g in range(n)]

    def match(self, tokens: Sequence[int]) -> List[PrefixNode]:
        """Longest indexed prefix of ``tokens``: the chain of nodes whose
        concatenated keys prefix the prompt (whole groups only).  Bumps LRU
        clocks along the chain."""
        now = self._tick()
        chain: List[PrefixNode] = []
        level = self.children
        for key in self._keys(tokens, self.group):
            node = level.get(key)
            if node is None:
                break
            node.last_used = now
            chain.append(node)
            level = node.children
        if chain:
            self.hits += 1
            self.hit_tokens += len(chain) * self.group
        else:
            self.misses += 1
        return chain

    def insert(self, tokens: Sequence[int], block_ids: Sequence[int],
               fp_groups: Sequence[List[Tuple[np.ndarray, np.ndarray]]]
               ) -> List[PrefixNode]:
        """Index the first ``len(block_ids)`` groups of ``tokens``; existing
        nodes are kept (first producer wins — its block already holds the
        identical planes) and only genuinely new nodes are created.  Returns
        the created nodes; the caller must ``retain_blocks`` their ids."""
        now = self._tick()
        created: List[PrefixNode] = []
        level = self.children
        parent: Optional[PrefixNode] = None
        keys = self._keys(tokens, self.group)[:len(block_ids)]
        for g, key in enumerate(keys):
            node = level.get(key)
            if node is None:
                node = PrefixNode(key=key, block_id=int(block_ids[g]),
                                  fp=list(fp_groups[g]), parent=parent)
                level[key] = node
                created.append(node)
                if node.block_id >= 0:
                    self.blocks += 1
            node.last_used = now
            parent = node
            level = node.children
        return created

    # ------------------------------------------------------------------
    def evict(self, n: int, shield: frozenset = frozenset()
              ) -> List[int]:
        """Evict up to ``n`` leaf nodes, least-recently-used first, skipping
        blocks in ``shield`` (aliased by a live slot, or about to be).
        Interior nodes only become candidates once their subtree is gone,
        so the tree stays prefix-closed.  Returns the evicted pool block
        ids; the caller must ``evict_blocks`` them to drop the device
        refcounts."""
        evicted: List[int] = []
        while len(evicted) < n:
            leaves = [nd for nd in self._iter_nodes()
                      if nd.is_leaf and nd.block_id not in shield]
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_used)
            level = (victim.parent.children if victim.parent is not None
                     else self.children)
            del level[victim.key]
            if victim.block_id >= 0:
                self.blocks -= 1
                evicted.append(victim.block_id)
        return evicted

    def _iter_nodes(self):
        stack = list(self.children.values())
        while stack:
            nd = stack.pop()
            yield nd
            stack.extend(nd.children.values())

    def __len__(self) -> int:
        return sum(1 for _ in self._iter_nodes())

    @property
    def stats(self) -> dict:
        return {"nodes": len(self), "blocks": self.blocks, "hits": self.hits,
                "misses": self.misses, "hit_tokens": self.hit_tokens}
