"""Hierarchical quantized KV cache with double full-precision buffer.

Layout (per layer, batch-first):

    quantized region : NB blocks × G tokens, two nibble-packed INT4 planes
                       (upper/lower) for K and V + per-block scales/zeros.
    FP buffer        : ``2*G`` most-recent tokens in compute precision,
                       logically split into C_F1 = buf[:G] (always full once
                       prefill exceeds G tokens) and C_F2 = buf[G:].

Invariants maintained by the engine (QuantSpec §4.3.2):
  * ``buf_len >= G`` after prefill (recent tokens stay full-precision).
  * rollbacks (rejected draft tokens) only ever shrink C_F2.
  * when the buffer fills, C_F1 is quantized+appended as one block and C_F2
    shifts down into C_F1 — quantization work happens once per G tokens.

All shapes are static; ``blocks`` / ``buf_len`` are traced scalars so every
operation jits. Sequence-position bookkeeping: token ``t`` of the stream
lives either in quant block ``t // G`` or in the buffer at
``t - blocks*G``.

This is the *contiguous* layout (one dense region per request, uniform
batch). The paged layout for ragged multi-request serving — same planes,
block-pool storage — lives in core/paged_kv_cache.py.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.quantization import HierQuant, dequant_full, dequant_upper, quantize_kv_block_pair


class HierKVCache(NamedTuple):
    # --- quantized region --------------------------------------------------
    k_upper: jnp.ndarray  # uint8 [B, NB, G, H, D//2]
    k_lower: jnp.ndarray  # uint8 [B, NB, G, H, D//2]
    k_scale: jnp.ndarray  # f32   [B, NB, 1, H, D]
    k_zero: jnp.ndarray   # f32   [B, NB, 1, H, D]
    v_upper: jnp.ndarray  # uint8 [B, NB, G, H, D//2]
    v_lower: jnp.ndarray  # uint8 [B, NB, G, H, D//2]
    v_scale: jnp.ndarray  # f32   [B, NB, G, H, 1]
    v_zero: jnp.ndarray   # f32   [B, NB, G, H, 1]
    blocks: jnp.ndarray   # i32 scalar — filled quant blocks
    # --- double full-precision buffer ---------------------------------------
    buf_k: jnp.ndarray    # [B, 2G, H, D] compute dtype
    buf_v: jnp.ndarray    # [B, 2G, H, D]
    buf_len: jnp.ndarray  # i32 scalar — tokens in buffer

    @property
    def group(self) -> int:
        return self.buf_k.shape[1] // 2

    @property
    def seq_len(self) -> jnp.ndarray:
        return self.blocks * self.group + self.buf_len

    @property
    def capacity(self) -> int:
        return self.k_upper.shape[1] * self.group + 2 * self.group


def init_cache(batch: int, max_blocks: int, group: int, heads: int,
               head_dim: int, dtype=jnp.float32) -> HierKVCache:
    B, NB, G, H, D = batch, max_blocks, group, heads, head_dim
    u8 = partial(jnp.zeros, dtype=jnp.uint8)
    f32 = partial(jnp.zeros, dtype=jnp.float32)
    return HierKVCache(
        k_upper=u8((B, NB, G, H, D // 2)),
        k_lower=u8((B, NB, G, H, D // 2)),
        k_scale=f32((B, NB, 1, H, D)),
        k_zero=f32((B, NB, 1, H, D)),
        v_upper=u8((B, NB, G, H, D // 2)),
        v_lower=u8((B, NB, G, H, D // 2)),
        v_scale=f32((B, NB, G, H, 1)),
        v_zero=f32((B, NB, G, H, 1)),
        blocks=jnp.zeros((), jnp.int32),
        buf_k=jnp.zeros((B, 2 * G, H, D), dtype),
        buf_v=jnp.zeros((B, 2 * G, H, D), dtype),
        buf_len=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# quantize helpers
# ---------------------------------------------------------------------------

def _quantize_blocks(k: jnp.ndarray, v: jnp.ndarray, group: int):
    """Quantize ``[B, n*G, H, D]`` into per-block HierQuants ``[B, n, ...]``."""
    B, S, H, D = k.shape
    n = S // group
    kb = k.reshape(B, n, group, H, D)
    vb = v.reshape(B, n, group, H, D)
    return quantize_kv_block_pair(kb, vb)


def prefill(cache: HierKVCache, k: jnp.ndarray, v: jnp.ndarray) -> HierKVCache:
    """Insert a prefill's K/V ``[B, S, H, D]`` (S static).

    Quantizes all but the trailing ``rem ∈ [G, 2G)`` tokens (everything stays
    in the buffer when ``S < G``).
    """
    G = cache.group
    S = k.shape[1]
    n_blocks = max(0, (S - G) // G)
    rem = S - n_blocks * G
    assert rem <= 2 * G
    new = cache
    if n_blocks > 0:
        kq, vq = _quantize_blocks(k[:, : n_blocks * G], v[:, : n_blocks * G], G)
        def put(dst, src):
            return jax.lax.dynamic_update_slice_in_dim(dst, src, 0, axis=1)
        new = new._replace(
            k_upper=put(new.k_upper, kq.upper), k_lower=put(new.k_lower, kq.lower),
            k_scale=put(new.k_scale, kq.scale), k_zero=put(new.k_zero, kq.zero),
            v_upper=put(new.v_upper, vq.upper), v_lower=put(new.v_lower, vq.lower),
            v_scale=put(new.v_scale, vq.scale), v_zero=put(new.v_zero, vq.zero),
        )
    buf_k = jax.lax.dynamic_update_slice_in_dim(
        new.buf_k, k[:, n_blocks * G:].astype(new.buf_k.dtype), 0, axis=1)
    buf_v = jax.lax.dynamic_update_slice_in_dim(
        new.buf_v, v[:, n_blocks * G:].astype(new.buf_v.dtype), 0, axis=1)
    return new._replace(
        blocks=jnp.asarray(n_blocks, jnp.int32),
        buf_k=buf_k, buf_v=buf_v,
        buf_len=jnp.asarray(rem, jnp.int32),
    )


def prefill_dynamic(cache: HierKVCache, k: jnp.ndarray, v: jnp.ndarray,
                    length) -> HierKVCache:
    """Length-aware prefill for bucket-padded prompts.

    ``k``/``v`` are ``[B, Sp, H, D]`` with ``Sp`` the (static) bucket size;
    only the first ``length`` (traced i32) tokens are valid.  Produces, on a
    freshly initialized cache, exactly the state
    ``prefill(cache, k[:, :length], v[:, :length])`` would — so one
    compiled program serves every prompt length in a bucket instead of
    recompiling per length.

    All ``Sp // G`` groups are quantized (padding garbage included) and the
    writes of groups ≥ ``n_blocks`` are masked out; the double buffer is a
    dynamic 2G-window slice with the invalid tail zeroed (matching the
    zero-initialized buffer the unpadded path leaves there).
    """
    G = cache.group
    B, Sp, H, D = k.shape
    L = jnp.asarray(length, jnp.int32)
    n_blocks = jnp.maximum(0, (L - G) // G)
    NB = cache.k_upper.shape[1]
    n_groups = min(Sp // G, NB)
    new = cache
    if n_groups > 0:
        kq, vq = _quantize_blocks(k[:, : n_groups * G], v[:, : n_groups * G],
                                  G)
        ok = (jnp.arange(n_groups) < n_blocks)[None, :, None, None, None]

        def put(dst, src):
            cur = jax.lax.dynamic_slice_in_dim(dst, 0, n_groups, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(
                dst, jnp.where(ok, src, cur), 0, axis=1)

        new = new._replace(
            k_upper=put(new.k_upper, kq.upper), k_lower=put(new.k_lower, kq.lower),
            k_scale=put(new.k_scale, kq.scale), k_zero=put(new.k_zero, kq.zero),
            v_upper=put(new.v_upper, vq.upper), v_lower=put(new.v_lower, vq.lower),
            v_scale=put(new.v_scale, vq.scale), v_zero=put(new.v_zero, vq.zero),
        )
    # buffer window [n_blocks*G, n_blocks*G + 2G) of the stream; pad the
    # source so the (dynamic-start) slice never clamps, then zero the tail
    pad = jnp.zeros((B, 2 * G, H, D), k.dtype)
    kp = jnp.concatenate([k, pad], axis=1)
    vp = jnp.concatenate([v, pad], axis=1)
    start = n_blocks * G
    zero = jnp.zeros((), jnp.int32)
    bk = jax.lax.dynamic_slice(kp, (zero, start, zero, zero), (B, 2 * G, H, D))
    bv = jax.lax.dynamic_slice(vp, (zero, start, zero, zero), (B, 2 * G, H, D))
    buf_len = L - start
    live = (jnp.arange(2 * G) < buf_len)[None, :, None, None]
    return new._replace(
        blocks=n_blocks,
        buf_k=jnp.where(live, bk.astype(cache.buf_k.dtype), 0),
        buf_v=jnp.where(live, bv.astype(cache.buf_v.dtype), 0),
        buf_len=buf_len,
    )


def append(cache: HierKVCache, k: jnp.ndarray, v: jnp.ndarray) -> HierKVCache:
    """Append ``T`` new tokens ``[B, T, H, D]`` to the FP buffer (C_F2).

    Caller must guarantee ``buf_len + T <= 2G`` (flush first otherwise).
    """
    start = cache.buf_len
    buf_k = _update_at(cache.buf_k, k.astype(cache.buf_k.dtype), start)
    buf_v = _update_at(cache.buf_v, v.astype(cache.buf_v.dtype), start)
    return cache._replace(buf_k=buf_k, buf_v=buf_v,
                          buf_len=cache.buf_len + k.shape[1])


def _update_at(buf: jnp.ndarray, x: jnp.ndarray, start) -> jnp.ndarray:
    idx = (jnp.zeros((), jnp.int32), jnp.asarray(start, jnp.int32),
           jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    return jax.lax.dynamic_update_slice(buf, x, idx)


def rollback(cache: HierKVCache, n) -> HierKVCache:
    """Drop the last ``n`` tokens (rejected drafts) — a counter decrement.

    Only ever removes tokens from C_F2 (engine invariant), so no quantized
    state needs touching: this is the "flexible discard" of §4.3.2.
    """
    return cache._replace(buf_len=cache.buf_len - jnp.asarray(n, jnp.int32))


def maybe_flush(cache: HierKVCache, headroom: int = 0) -> HierKVCache:
    """If the buffer cannot absorb ``headroom`` more tokens (or is full),
    quantize C_F1 into a new block and shift C_F2 → C_F1."""
    G = cache.group

    def do_flush(c: HierKVCache) -> HierKVCache:
        # routes through the Pallas quantize+pack kernel on TPU (the decode
        # hot path flushes once per G accepted tokens), jnp elsewhere
        kq, vq = quantize_kv_block_pair(c.buf_k[:, :G], c.buf_v[:, :G])
        b = c.blocks

        def put(dst, src):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src[:, None], b, axis=1)

        shifted_k = jnp.concatenate(
            [c.buf_k[:, G:], jnp.zeros_like(c.buf_k[:, :G])], axis=1)
        shifted_v = jnp.concatenate(
            [c.buf_v[:, G:], jnp.zeros_like(c.buf_v[:, :G])], axis=1)
        return c._replace(
            k_upper=put(c.k_upper, kq.upper),
            k_lower=put(c.k_lower, kq.lower),
            k_scale=put(c.k_scale, kq.scale),
            k_zero=put(c.k_zero, kq.zero),
            v_upper=put(c.v_upper, vq.upper),
            v_lower=put(c.v_lower, vq.lower),
            v_scale=put(c.v_scale, vq.scale),
            v_zero=put(c.v_zero, vq.zero),
            blocks=c.blocks + 1,
            buf_k=shifted_k, buf_v=shifted_v,
            buf_len=c.buf_len - G,
        )

    need = cache.buf_len + headroom > 2 * G - 1
    return jax.lax.cond(need, do_flush, lambda c: c, cache)


# ---------------------------------------------------------------------------
# dequantized views (reference path; the Pallas kernel reads packed planes)
# ---------------------------------------------------------------------------

def dequant_region(cache: HierKVCache, mode: str, dtype=jnp.float32):
    """Dequantize the quantized region → ``(k, v)`` of ``[B, NB*G, H, D]``.

    mode='draft' loads only the upper plane (4-bit); mode='target'
    reconstructs INT8 from both planes. Positions ≥ blocks*G are garbage and
    must be masked by the caller (valid quant length = ``blocks * G``).
    """
    deq = dequant_upper if mode == "draft" else dequant_full
    kq = HierQuant(cache.k_upper, cache.k_lower, cache.k_scale, cache.k_zero)
    vq = HierQuant(cache.v_upper, cache.v_lower, cache.v_scale, cache.v_zero)
    k = deq(kq, dtype)
    v = deq(vq, dtype)
    B, NB, G, H, D = k.shape
    return k.reshape(B, NB * G, H, D), v.reshape(B, NB * G, H, D)


def materialize(cache: HierKVCache, mode: str, dtype=jnp.float32):
    """Full logical K/V ``[B, NB*G + 2G, H, D]`` plus the valid length.

    Reference implementation used by the pure-jnp attention path and as the
    oracle for the Pallas kernel.
    """
    kq, vq = dequant_region(cache, mode, dtype)
    k = jnp.concatenate([kq, cache.buf_k.astype(dtype)], axis=1)
    v = jnp.concatenate([vq, cache.buf_v.astype(dtype)], axis=1)
    quant_len = cache.blocks * cache.group
    Sq = kq.shape[1]
    pos = jnp.arange(k.shape[1])
    valid = jnp.where(pos < Sq, pos < quant_len,
                      pos - Sq < cache.buf_len)
    return k, v, valid, quant_len


# ---------------------------------------------------------------------------
# Plain full-precision cache (targets of the sparse-KV baselines, and the
# FP16 autoregressive baseline)
# ---------------------------------------------------------------------------

class FullKVCache(NamedTuple):
    k: jnp.ndarray        # [B, S_max, H, D]
    v: jnp.ndarray        # [B, S_max, H, D]
    length: jnp.ndarray   # i32 scalar

    @property
    def seq_len(self):
        return self.length


def init_full_cache(batch, max_seq, heads, head_dim, dtype=jnp.float32):
    return FullKVCache(
        k=jnp.zeros((batch, max_seq, heads, head_dim), dtype),
        v=jnp.zeros((batch, max_seq, heads, head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def full_prefill(cache: FullKVCache, k, v, length) -> FullKVCache:
    """Length-aware prefill of a bucket-padded prompt into the plain FP
    cache: the padded tail is written (and masked by ``length`` everywhere
    the cache is read) — equivalent to ``full_append(cache, k[:, :length],
    v[:, :length])`` on a fresh cache, without a per-length recompile."""
    S = k.shape[1]
    L = jnp.asarray(length, jnp.int32)
    live = (jnp.arange(S) < L)[None, :, None, None]
    kk = _update_at(cache.k, jnp.where(live, k.astype(cache.k.dtype), 0), 0)
    vv = _update_at(cache.v, jnp.where(live, v.astype(cache.v.dtype), 0), 0)
    return FullKVCache(kk, vv, L)


def full_append(cache: FullKVCache, k, v) -> FullKVCache:
    kk = _update_at(cache.k, k.astype(cache.k.dtype), cache.length)
    vv = _update_at(cache.v, v.astype(cache.v.dtype), cache.length)
    return FullKVCache(kk, vv, cache.length + k.shape[1])


def full_rollback(cache: FullKVCache, n) -> FullKVCache:
    return cache._replace(length=cache.length - jnp.asarray(n, jnp.int32))


# ---------------------------------------------------------------------------
# Windowed (ring) cache — StreamingLLM-style sink + sliding window. Used for
# gemma3 local layers, the StreamingLLM draft baseline, and the streaming
# long_500k mode of pure full-attention architectures.
# ---------------------------------------------------------------------------

class WindowKVCache(NamedTuple):
    sink_k: jnp.ndarray   # [B, n_sink, H, D]
    sink_v: jnp.ndarray
    ring_k: jnp.ndarray   # [B, W, H, D]
    ring_v: jnp.ndarray
    pos: jnp.ndarray      # i32 — absolute position of next token
    # ring slot of token p is p % W once p >= n_sink


def init_window_cache(batch, window, heads, head_dim, n_sink=4,
                      dtype=jnp.float32):
    return WindowKVCache(
        sink_k=jnp.zeros((batch, n_sink, heads, head_dim), dtype),
        sink_v=jnp.zeros((batch, n_sink, heads, head_dim), dtype),
        ring_k=jnp.zeros((batch, window, heads, head_dim), dtype),
        ring_v=jnp.zeros((batch, window, heads, head_dim), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def window_append(cache: WindowKVCache, k, v) -> WindowKVCache:
    """Append T tokens; sink absorbs the first n_sink ever seen.

    Large chunks (T > window, i.e. prefill) are split: sink head + last-W
    tail; skipped middle tokens only advance ``pos``.
    """
    B, T, H, D = k.shape
    n_sink = cache.sink_k.shape[1]
    W = cache.ring_k.shape[1]
    if T > W:
        cache = window_append(cache, k[:, :n_sink], v[:, :n_sink])
        skip = max(0, T - n_sink - W)
        cache = cache._replace(pos=cache.pos + skip)
        return window_append(cache, k[:, n_sink + skip:], v[:, n_sink + skip:])

    import os
    if T == 1 and os.environ.get("REPRO_WINDOW_FAST", "1") != "0":
        # decode fast path: two dynamic_update_slices instead of a padded
        # scatter (which copies the whole ring) — §Perf iteration, exercised
        # by every streaming/window decode step (REPRO_WINDOW_FAST=0 restores
        # the baseline scatter for before/after measurement)
        pos = cache.pos
        kk = k.astype(cache.sink_k.dtype)
        vv = v.astype(cache.sink_v.dtype)
        if n_sink > 0:
            in_sink = pos < n_sink
            sidx = jnp.clip(pos, 0, n_sink - 1)
            old_sk = jax.lax.dynamic_slice_in_dim(cache.sink_k, sidx, 1, axis=1)
            old_sv = jax.lax.dynamic_slice_in_dim(cache.sink_v, sidx, 1, axis=1)
            sink_k = jax.lax.dynamic_update_slice_in_dim(
                cache.sink_k, jnp.where(in_sink, kk, old_sk), sidx, axis=1)
            sink_v = jax.lax.dynamic_update_slice_in_dim(
                cache.sink_v, jnp.where(in_sink, vv, old_sv), sidx, axis=1)
        else:
            in_sink = jnp.zeros((), bool)
            sink_k, sink_v = cache.sink_k, cache.sink_v
        ridx = pos % W
        old_rk = jax.lax.dynamic_slice_in_dim(cache.ring_k, ridx, 1, axis=1)
        old_rv = jax.lax.dynamic_slice_in_dim(cache.ring_v, ridx, 1, axis=1)
        ring_k = jax.lax.dynamic_update_slice_in_dim(
            cache.ring_k, jnp.where(in_sink, old_rk, kk), ridx, axis=1)
        ring_v = jax.lax.dynamic_update_slice_in_dim(
            cache.ring_v, jnp.where(in_sink, old_rv, vv), ridx, axis=1)
        return WindowKVCache(sink_k, sink_v, ring_k, ring_v, cache.pos + 1)

    positions = cache.pos + jnp.arange(T)
    in_sink = positions < n_sink
    sink_k = _masked_scatter(cache.sink_k, k, positions, in_sink)
    sink_v = _masked_scatter(cache.sink_v, v, positions, in_sink)
    ring_k = _masked_scatter(cache.ring_k, k, positions % W, ~in_sink)
    ring_v = _masked_scatter(cache.ring_v, v, positions % W, ~in_sink)
    return WindowKVCache(sink_k, sink_v, ring_k, ring_v, cache.pos + T)


def _masked_scatter(dst, src, idx, mask):
    """dst[:, idx[t]] = src[:, t] where mask[t]; masked-out writes land in a
    dummy slot (duplicate-index safe). Real indices must be unique."""
    n = dst.shape[1]
    padded = jnp.concatenate([dst, jnp.zeros_like(dst[:, :1])], axis=1)
    safe_idx = jnp.where(mask, jnp.clip(idx, 0, n - 1), n)
    padded = padded.at[:, safe_idx].set(src.astype(dst.dtype))
    return padded[:, :n]


def window_rollback(cache: WindowKVCache, n) -> WindowKVCache:
    # Ring entries of rolled-back tokens will be overwritten by the
    # re-generated tokens at the same positions; only `pos` moves back.
    return cache._replace(pos=cache.pos - jnp.asarray(n, jnp.int32))
