"""QuantSpec core: hierarchical INT4+INT4 quantization, the contiguous and
paged hierarchical KV caches, speculative-sampling acceptance, and the
draft→verify→commit spec-decode rounds (static and continuous-batching)."""
