"""Disk KV tier: durable spill target behind the host tier.

The host tier (core/host_tier.py) holds preempted slots' quantized KV
snapshots in RAM.  That caps the hierarchy at host memory and loses every
snapshot when the process dies.  The :class:`DiskTier` extends the
hierarchy to ``device → host → disk``: least-recently-used host snapshots
spill to **per-request files** and stream back on demand (Lynx-style
progressive quantized KV transfer — the INT4 planes are ~4x smaller than
their fp16 equivalent, which is what makes a slow link viable), and the
same files double as the durable half of crash recovery
(serving/journal.py): a snapshot persisted at a checkpoint survives a
SIGKILL and restores **bit-exact** after ``ContinuousEngine.recover``.

File record (``req_<id>.kvsnap``, full layout in docs/kv_cache_format.md):

    magic "KVS1" | u32 header_len | header JSON | raw plane payload

The header carries the slot metadata (``n_blocks``/``buf_len``/``pos``/
``last_token``) and, per plane, its key, dtype, shape, byte offset and a
**CRC32 over its raw bytes**.  Reads verify every plane CRC and the total
payload length, so bit-flips and torn/partial writes surface as
:class:`~repro.core.host_tier.SnapshotCorruptionError` — a corrupt file
fails *that request*, never the engine.  Writes are **atomic**: the record
is written to a temp file in the same directory, flushed (+ optional
fsync), then ``os.replace``d into place — a crash mid-write leaves either
the old record or none, never a half-record under the live name.

Capacity is watermarked: when ``used_bytes`` exceeds ``high_watermark *
capacity_bytes`` after a put, LRU records are evicted until usage falls
below ``low_watermark * capacity_bytes`` (the record being written is
exempt).  An evicted snapshot is *not* a dead request — the engine replays
the request from its prompt (greedy decoding is deterministic, so the
regenerated tokens are identical); eviction trades recompute for disk,
the graceful end of the hierarchy.  A put that cannot fit even after
eviction (or hits a real ``ENOSPC``) raises :class:`DiskTierError`.

Fault injection (tests/fault_injection.py): ``fault.disk(op, req_id)``
may raise before a put/load (ENOSPC and friends), and
``fault.disk_mangle(req_id, path)`` may truncate or bit-flip the record
after a successful put (torn write / bitrot on read-back) — both must be
absorbed per the contract above.
"""

from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.host_tier import HostTierError, SlotSnapshot, SnapshotCorruptionError, _crc

_MAGIC = b"KVS1"


class DiskTierError(HostTierError):
    """A disk-tier put/load failed (ENOSPC, IO error, capacity overflow)."""


@dataclasses.dataclass
class _Record:
    """Host-side bookkeeping for one on-disk snapshot file."""

    req_id: int
    path: str
    nbytes: int          # full file size
    seq: int             # LRU clock at last touch


def _plane_items(planes) -> List[tuple]:
    """Flatten the per-layer plane dicts into ``(layer, key, array)``
    triples in a deterministic order (layer-major, key-sorted)."""
    out = []
    for li, layer in enumerate(planes):
        for key in sorted(layer):
            out.append((li, key, np.ascontiguousarray(layer[key])))
    return out


class DiskTier:
    """Per-request snapshot files under ``root`` with LRU capacity
    eviction (see module docstring)."""

    def __init__(self, root: str, *, capacity_bytes: Optional[int] = None,
                 high_watermark: float = 1.0, low_watermark: float = 0.8,
                 fsync: bool = False, fault: Any = None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.capacity_bytes = capacity_bytes
        self.high_watermark = high_watermark
        self.low_watermark = low_watermark
        self.fsync = fsync
        self.fault = fault
        self._records: Dict[int, _Record] = {}
        self._clock = 0
        # telemetry (plumbed into GenStats / the serve summary)
        self.puts = 0
        self.loads = 0
        self.evictions = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self._scan_existing()

    # ------------------------------------------------------------------
    def _path(self, req_id: int) -> str:
        return os.path.join(self.root, f"req_{req_id}.kvsnap")

    def _scan_existing(self) -> None:
        """Adopt records already on disk (crash recovery: snapshots
        persisted by a previous process).  Unreadable names are ignored;
        integrity is only verified at load time."""
        for name in sorted(os.listdir(self.root)):
            if not (name.startswith("req_") and name.endswith(".kvsnap")):
                continue
            try:
                req_id = int(name[len("req_"):-len(".kvsnap")])
                nbytes = os.path.getsize(os.path.join(self.root, name))
            except (ValueError, OSError):
                continue
            self._clock += 1
            self._records[req_id] = _Record(
                req_id=req_id, path=os.path.join(self.root, name),
                nbytes=nbytes, seq=self._clock)

    def __contains__(self, req_id: int) -> bool:
        return req_id in self._records

    def __len__(self) -> int:
        return len(self._records)

    @property
    def used_bytes(self) -> int:
        return sum(r.nbytes for r in self._records.values())

    # ------------------------------------------------------------------
    def put(self, snap: SlotSnapshot) -> int:
        """Persist a **materialized** snapshot atomically; returns the
        record size in bytes.  Idempotent per request id (a re-put
        replaces the record)."""
        assert snap.materialized, "spill requires a materialized snapshot"
        if self.fault is not None and hasattr(self.fault, "disk"):
            try:
                self.fault.disk("put", snap.req_id)
            except OSError as e:
                raise DiskTierError(
                    f"disk put for request {snap.req_id} failed: {e}") from e
        items = _plane_items(snap.planes)
        index, offset = [], 0
        for li, key, arr in items:
            raw = arr.view(np.uint8).reshape(-1)
            index.append({"layer": li, "key": key, "dtype": str(arr.dtype),
                          "shape": list(arr.shape), "offset": offset,
                          "nbytes": int(arr.nbytes),
                          "crc": zlib.crc32(raw) & 0xFFFFFFFF})
            offset += int(arr.nbytes)
        header = json.dumps({
            "req_id": snap.req_id, "n_blocks": snap.n_blocks,
            "buf_len": snap.buf_len, "pos": snap.pos,
            "last_token": snap.last_token, "payload_bytes": offset,
            "planes": index,
        }).encode()
        path = self._path(snap.req_id)
        tmp = path + ".tmp"
        try:
            with open(tmp, "wb") as f:
                f.write(_MAGIC)
                f.write(len(header).to_bytes(4, "little"))
                f.write(header)
                for _, _, arr in items:
                    f.write(arr.view(np.uint8).reshape(-1).tobytes())
                f.flush()
                if self.fsync:
                    os.fsync(f.fileno())
            os.replace(tmp, path)
        except OSError as e:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise DiskTierError(
                f"disk put for request {snap.req_id} failed: {e}") from e
        nbytes = len(_MAGIC) + 4 + len(header) + offset
        self._clock += 1
        self._records[snap.req_id] = _Record(
            req_id=snap.req_id, path=path, nbytes=nbytes, seq=self._clock)
        self.puts += 1
        self.bytes_written += nbytes
        if self.fault is not None and hasattr(self.fault, "disk_mangle"):
            # post-write corruption hook: torn writes / bitrot on read-back
            self.fault.disk_mangle(snap.req_id, path)
        self._enforce_capacity(exclude=snap.req_id)
        return nbytes

    def load(self, req_id: int, *, pop: bool = True) -> SlotSnapshot:
        """Read a record back, verifying the per-plane CRCs.  ``pop``
        removes the record (the default: a restored slot owns fresh
        blocks; the stale file would only mask bugs in recovery)."""
        rec = self._records.get(req_id)
        if rec is None:
            raise KeyError(req_id)
        if self.fault is not None and hasattr(self.fault, "disk"):
            try:
                self.fault.disk("load", req_id)
            except OSError as e:
                raise DiskTierError(
                    f"disk load for request {req_id} failed: {e}") from e
        try:
            with open(rec.path, "rb") as f:
                data = f.read()
        except OSError as e:
            raise DiskTierError(
                f"disk load for request {req_id} failed: {e}") from e
        snap = self._parse(req_id, data)
        self._clock += 1
        rec.seq = self._clock
        self.loads += 1
        self.bytes_read += len(data)
        if pop:
            self.discard(req_id)
        return snap

    def _parse(self, req_id: int, data: bytes) -> SlotSnapshot:
        def corrupt(why: str) -> SnapshotCorruptionError:
            self.discard(req_id)   # refused records are dropped
            return SnapshotCorruptionError(
                f"disk snapshot for request {req_id} is corrupt ({why}) — "
                f"refusing swap-in")

        if data[:4] != _MAGIC or len(data) < 8:
            raise corrupt("bad magic")
        hlen = int.from_bytes(data[4:8], "little")
        if len(data) < 8 + hlen:
            raise corrupt("truncated header")
        try:
            header = json.loads(data[8:8 + hlen])
        except (UnicodeDecodeError, json.JSONDecodeError):
            raise corrupt("unparseable header")
        payload = data[8 + hlen:]
        if len(payload) != header["payload_bytes"]:
            raise corrupt(f"payload is {len(payload)} bytes, header says "
                          f"{header['payload_bytes']} (torn write)")
        n_layers = 1 + max((p["layer"] for p in header["planes"]), default=-1)
        planes: List[dict] = [{} for _ in range(n_layers)]
        for p in header["planes"]:
            raw = payload[p["offset"]:p["offset"] + p["nbytes"]]
            if (zlib.crc32(raw) & 0xFFFFFFFF) != p["crc"]:
                raise corrupt(f"plane {p['layer']}/{p['key']} failed CRC")
            arr = np.frombuffer(raw, dtype=np.dtype(p["dtype"]))
            planes[p["layer"]][p["key"]] = arr.reshape(p["shape"])
        snap = SlotSnapshot(
            req_id=header["req_id"], n_blocks=header["n_blocks"],
            buf_len=header["buf_len"], pos=header["pos"],
            last_token=header["last_token"], planes=planes)
        # re-stamp the in-memory checksum so HostTier.restore's verify pass
        # (which covers the host-RAM window after this load) has a baseline
        snap.checksum = _crc(snap.planes)
        snap.nbytes = sum(p["nbytes"] for p in header["planes"])
        return snap

    def discard(self, req_id: int) -> None:
        rec = self._records.pop(req_id, None)
        if rec is not None:
            try:
                os.unlink(rec.path)
            except OSError:
                pass

    # ------------------------------------------------------------------
    def _enforce_capacity(self, exclude: Optional[int] = None) -> None:
        """LRU-evict records past the high watermark down to the low one.
        The just-written record is exempt — evicting what we came to
        store would make the put a silent no-op."""
        if self.capacity_bytes is None:
            return
        if self.used_bytes <= self.high_watermark * self.capacity_bytes:
            return
        floor = self.low_watermark * self.capacity_bytes
        victims = sorted((r for r in self._records.values()
                          if r.req_id != exclude), key=lambda r: r.seq)
        for rec in victims:
            if self.used_bytes <= floor:
                break
            self.discard(rec.req_id)
            self.evictions += 1

    @property
    def stats(self) -> dict:
        return {"puts": self.puts, "loads": self.loads,
                "evictions": self.evictions, "resident": len(self),
                "used_bytes": self.used_bytes,
                "bytes_written": self.bytes_written,
                "bytes_read": self.bytes_read}
