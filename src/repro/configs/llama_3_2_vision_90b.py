"""llama-3.2-vision-90b — VLM backbone: 100 decoder layers with a
cross-attention (image) layer every 5th. The vision encoder + projector are
stubbed; `input_specs` provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision, scaled to 90B]"""

from repro.models.config import ATTN_CROSS, ATTN_FULL, MLP_DENSE, LayerSpec, ModelConfig

_S = LayerSpec(mixer=ATTN_FULL, mlp=MLP_DENSE)
_X = LayerSpec(mixer=ATTN_CROSS, mlp=MLP_DENSE)


def full_config() -> ModelConfig:
    # 100 layers = (4 self + 1 cross) x 20
    return ModelConfig(
        name="llama-3.2-vision-90b", arch_type="vlm",
        d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
        d_ff=28672, vocab_size=128256,
        pattern=(_S, _S, _S, _S, _X), n_repeats=20,
        num_image_tokens=1600,
        rope_theta=500_000.0,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-smoke", arch_type="vlm",
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
        pattern=(_S, _X), n_repeats=1,
        num_image_tokens=16, group_size=16,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )
