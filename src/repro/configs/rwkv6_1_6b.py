"""rwkv6-1.6b ("Finch") — attention-free, data-dependent decay.
[arXiv:2404.05892]"""

from repro.models.config import MIX_RWKV, MLP_RWKV, LayerSpec, ModelConfig

_L = LayerSpec(mixer=MIX_RWKV, mlp=MLP_RWKV)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", arch_type="ssm",
        d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
        d_ff=7168, vocab_size=65536,
        pattern=(_L,), n_repeats=24,
        source="arXiv:2404.05892",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-smoke", arch_type="ssm",
        d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512,
        pattern=(_L,), n_repeats=2, group_size=16,
        source="arXiv:2404.05892",
    )
