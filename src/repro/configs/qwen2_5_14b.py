"""qwen2.5-14b — dense GQA decoder with QKV bias.
[hf:Qwen/Qwen2.5-0.5B family card, scaled to 14B]"""

from repro.models.config import ATTN_FULL, MLP_DENSE, LayerSpec, ModelConfig

_L = LayerSpec(mixer=ATTN_FULL, mlp=MLP_DENSE)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", arch_type="dense",
        d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
        d_ff=13824, vocab_size=152064,
        pattern=(_L,), n_repeats=48,
        qkv_bias=True, rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen2.5-0.5B",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke", arch_type="dense",
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
        pattern=(_L,), n_repeats=2, qkv_bias=True, group_size=16,
        source="hf:Qwen/Qwen2.5-0.5B",
    )
