"""Config registry: one module per assigned architecture (+ the paper's own
Llama-2-7B-32K). `get_config(name)` returns the full production config;
`get_config(name, smoke=True)` returns the reduced same-family variant used
by CPU smoke tests (≤2 layers, d_model ≤ 512, ≤4 experts)."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "gemma3-27b",
    "llama-3.2-vision-90b",
    "mistral-large-123b",
    "starcoder2-7b",
    "qwen3-moe-235b-a22b",
    "rwkv6-1.6b",
    "qwen2.5-14b",
    "deepseek-moe-16b",
    "musicgen-large",
    "jamba-v0.1-52b",
    # the paper's own evaluation model
    "llama2-7b-32k",
    # small models for CPU-trainable quality benchmarks
    "tiny-lm",
)


def _module(name: str):
    return importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = _module(name)
    return mod.smoke_config() if smoke else mod.full_config()
