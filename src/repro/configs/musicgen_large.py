"""musicgen-large — decoder-only over EnCodec tokens (4 codebooks, delay
pattern). The EnCodec frontend is stubbed: inputs are codebook token ids.
[arXiv:2306.05284]"""

from repro.models.config import ATTN_FULL, MLP_DENSE, LayerSpec, ModelConfig

_L = LayerSpec(mixer=ATTN_FULL, mlp=MLP_DENSE)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", arch_type="audio",
        d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=2048,
        pattern=(_L,), n_repeats=48,
        num_codebooks=4,
        source="arXiv:2306.05284",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke", arch_type="audio",
        d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=128,
        pattern=(_L,), n_repeats=2,
        num_codebooks=4, group_size=16,
        source="arXiv:2306.05284",
    )
