"""starcoder2-7b — dense GQA decoder with RoPE. [arXiv:2402.19173]"""

from repro.models.config import ATTN_FULL, MLP_DENSE, LayerSpec, ModelConfig

_L = LayerSpec(mixer=ATTN_FULL, mlp=MLP_DENSE)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", arch_type="dense",
        d_model=4608, num_heads=36, num_kv_heads=4, head_dim=128,
        d_ff=18432, vocab_size=49152,
        pattern=(_L,), n_repeats=32,
        rope_theta=1_000_000.0, qkv_bias=True,
        source="arXiv:2402.19173",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke", arch_type="dense",
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
        pattern=(_L,), n_repeats=2, qkv_bias=True, group_size=16,
        source="arXiv:2402.19173",
    )
