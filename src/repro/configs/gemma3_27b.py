"""gemma3-27b — dense, 5:1 local(sliding-window):global attention, 128k ctx.
[hf:google/gemma-3-1b-pt family card, scaled to 27B]"""

from repro.models.config import ATTN_FULL, ATTN_WINDOW, MLP_DENSE, LayerSpec, ModelConfig

_W = LayerSpec(mixer=ATTN_WINDOW, mlp=MLP_DENSE)
_G = LayerSpec(mixer=ATTN_FULL, mlp=MLP_DENSE)


def full_config() -> ModelConfig:
    # 62 layers = (5 local + 1 global) x 10 + (1 local + 1 global) tail
    return ModelConfig(
        name="gemma3-27b", arch_type="dense",
        d_model=5376, num_heads=32, num_kv_heads=16, head_dim=128,
        d_ff=21504, vocab_size=262144,
        pattern=(_W, _W, _W, _W, _W, _G), n_repeats=10,
        tail_layers=(_W, _G),
        window=1024, rope_theta=1_000_000.0,
        source="hf:google/gemma-3-1b-pt",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke", arch_type="dense",
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
        pattern=(_W, _G), n_repeats=1,
        window=32, n_sink=2, group_size=16,
        source="hf:google/gemma-3-1b-pt",
    )
