"""qwen3-moe-235b-a22b — MoE: 128 experts, top-8, no shared experts.
[hf:Qwen/Qwen3-30B-A3B family card, scaled to 235B-A22B]"""

from repro.models.config import ATTN_FULL, MLP_MOE, LayerSpec, ModelConfig

_L = LayerSpec(mixer=ATTN_FULL, mlp=MLP_MOE)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", arch_type="moe",
        d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
        d_ff=1536, vocab_size=151936,
        pattern=(_L,), n_repeats=94,
        num_experts=128, top_k=8, moe_d_ff=1536,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b-smoke", arch_type="moe",
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=256, vocab_size=512,
        pattern=(_L,), n_repeats=2,
        num_experts=4, top_k=2, moe_d_ff=256, group_size=16,
        source="hf:Qwen/Qwen3-30B-A3B",
    )
