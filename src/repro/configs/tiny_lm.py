"""tiny-lm — ~20M-param dense model, CPU-trainable in minutes. Used by the
quality benchmarks (perplexity FP16 vs INT8/INT4 KV; quant-axis ablation)
and the end-to-end training example."""

from repro.models.config import ATTN_FULL, MLP_DENSE, LayerSpec, ModelConfig

_L = LayerSpec(mixer=ATTN_FULL, mlp=MLP_DENSE)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="tiny-lm", arch_type="dense",
        d_model=384, num_heads=6, num_kv_heads=6, head_dim=64,
        d_ff=1024, vocab_size=512,
        pattern=(_L,), n_repeats=6,
        group_size=32,
        source="repo-internal (quality benches)",
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(name="tiny-lm-smoke", n_repeats=2)
