"""llama2-7b-32k — the paper's own evaluation model
(Llama-2-7B-32K-Instruct; QuantSpec Table 3)."""

from repro.models.config import ATTN_FULL, MLP_DENSE, LayerSpec, ModelConfig

_L = LayerSpec(mixer=ATTN_FULL, mlp=MLP_DENSE)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b-32k", arch_type="dense",
        d_model=4096, num_heads=32, num_kv_heads=32, head_dim=128,
        d_ff=11008, vocab_size=32000,
        pattern=(_L,), n_repeats=32,
        source="QuantSpec paper §5.1 / hf:togethercomputer/Llama-2-7B-32K-Instruct",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b-32k-smoke", arch_type="dense",
        d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512,
        pattern=(_L,), n_repeats=2, group_size=16,
        source="QuantSpec paper §5.1",
    )
