"""mistral-large-123b — dense GQA decoder.
[hf:mistralai/Mistral-Large-Instruct-2407]"""

from repro.models.config import ATTN_FULL, MLP_DENSE, LayerSpec, ModelConfig

_L = LayerSpec(mixer=ATTN_FULL, mlp=MLP_DENSE)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", arch_type="dense",
        d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
        d_ff=28672, vocab_size=32768,
        pattern=(_L,), n_repeats=88,
        rope_theta=1_000_000.0,
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-smoke", arch_type="dense",
        d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512,
        pattern=(_L,), n_repeats=2, group_size=16,
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )
