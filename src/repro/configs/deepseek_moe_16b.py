"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts top-6,
first layer dense. [arXiv:2401.06066]"""

from repro.models.config import ATTN_FULL, MLP_DENSE, MLP_MOE, LayerSpec, ModelConfig

_DENSE = LayerSpec(mixer=ATTN_FULL, mlp=MLP_DENSE)
_MOE = LayerSpec(mixer=ATTN_FULL, mlp=MLP_MOE)


def full_config() -> ModelConfig:
    # 28 layers = 1 dense head + 27 MoE
    return ModelConfig(
        name="deepseek-moe-16b", arch_type="moe",
        d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=10944,                 # dense (first) layer FFN
        vocab_size=102400,
        head_layers=(_DENSE,),
        pattern=(_MOE,), n_repeats=27,
        num_experts=64, top_k=6, moe_d_ff=1408, num_shared_experts=2,
        source="arXiv:2401.06066",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke", arch_type="moe",
        d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512,
        head_layers=(_DENSE,),
        pattern=(_MOE,), n_repeats=1,
        num_experts=4, top_k=2, moe_d_ff=128, num_shared_experts=1,
        group_size=16,
        source="arXiv:2401.06066",
    )
