"""jamba-v0.1-52b — hybrid Mamba+attention (1:7) with MoE every other layer
(16 experts, top-2). Jamba block = 8 layers, attention at index 4, MoE on
odd indices. [arXiv:2403.19887]"""

from repro.models.config import ATTN_FULL, MIX_MAMBA, MLP_DENSE, MLP_MOE, LayerSpec, ModelConfig

_M_D = LayerSpec(mixer=MIX_MAMBA, mlp=MLP_DENSE)
_M_E = LayerSpec(mixer=MIX_MAMBA, mlp=MLP_MOE)
_A_E = LayerSpec(mixer=ATTN_FULL, mlp=MLP_MOE)


def full_config() -> ModelConfig:
    # 32 layers = 4 Jamba blocks of 8; attn at position 4 of each block
    block = (_M_D, _M_E, _M_D, _M_E, LayerSpec(ATTN_FULL, MLP_DENSE),
             _M_E, _M_D, _M_E)
    return ModelConfig(
        name="jamba-v0.1-52b", arch_type="hybrid",
        d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=65536,
        pattern=block, n_repeats=4,
        num_experts=16, top_k=2, moe_d_ff=14336,
        d_state=16, d_conv=4, ssm_expand=2,
        source="arXiv:2403.19887",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke", arch_type="hybrid",
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
        pattern=(_M_E, LayerSpec(ATTN_FULL, MLP_DENSE)), n_repeats=1,
        num_experts=4, top_k=2, moe_d_ff=256,
        d_state=8, d_conv=4, ssm_expand=2, group_size=16,
        source="arXiv:2403.19887",
    )
