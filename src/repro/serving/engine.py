"""Serving engine: batched request generation with QuantSpec, autoregressive
FP, and sparse-KV self-speculative baselines (StreamingLLM / SnapKV).

The engine jits one `spec_round` (draft γ → verify → commit) and drives it
in a Python loop; prefill is jitted separately per prompt length.

Policies
--------
quantspec : hierarchical INT4/INT8 shared cache, INT4 draft weights (paper)
fp        : plain FP cache, no speculation (AR baseline)
streaming : FP target cache + StreamingLLM sink+window draft cache
snapkv    : FP target cache + SnapKV prefill-selected draft cache

For the baselines the draft weights stay full precision (matching the
MagicDec-style sparse-KV baselines of the paper, whose draft cost savings
come from the sparse cache only).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec_decode import ar_step, spec_round
from repro.core.weight_quant import quantize_tree
from repro.models.stack import StackModel
from repro.serving.sampling import sample_token


@dataclasses.dataclass
class GenStats:
    proposed: int = 0
    accepted: int = 0
    rounds: int = 0
    generated: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.proposed, 1)

    @property
    def tokens_per_round(self) -> float:
        return self.generated / max(self.rounds, 1)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # [B, n_generated(, K)]
    stats: GenStats


class Engine:
    def __init__(self, model: StackModel, params, *, policy: str = "quantspec",
                 gamma: int = 4, greedy: bool = False,
                 temperature: float = 1.0,
                 quantize_weights: Optional[bool] = None,
                 max_seq: int = 4096, ctx_kw: Optional[dict] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.policy = policy
        self.gamma = gamma
        self.greedy = greedy
        self.temperature = temperature
        self.ctx_kw = ctx_kw or {}
        self.max_seq = max_seq
        if quantize_weights is None:
            quantize_weights = policy == "quantspec"
        self.draft_params = (quantize_tree(
            params, group=self.cfg.weight_quant_group)
            if quantize_weights else params)

        self._round = jax.jit(
            partial(spec_round, model, gamma=gamma, policy=policy,
                    greedy=greedy, temperature=temperature,
                    ctx_kw=self.ctx_kw),
            static_argnames=())
        self._ar = jax.jit(
            partial(ar_step, model, policy=policy, greedy=greedy,
                    temperature=temperature,
                    kv_mode="target" if policy == "quantspec" else "fp",
                    ctx_kw=self.ctx_kw))
        self._prefill_jit = jax.jit(self._prefill,
                                    static_argnames=("batch",))

    # ------------------------------------------------------------------
    def _prefill(self, prompt, memory, batch):
        state = self.model.init_serve_state(
            batch, max_seq=self.max_seq, policy=self.policy,
            ctx_kw=self.ctx_kw)
        logits, state = self.model.prefill(
            self.params, prompt, state, policy=self.policy, memory=memory,
            ctx_kw=self.ctx_kw)
        return logits, state

    def generate(self, prompt: jnp.ndarray, max_new_tokens: int,
                 key=None, memory=None, speculative: Optional[bool] = None
                 ) -> GenerationResult:
        """prompt [B, S] (or [B, S, K] for codebooks)."""
        if key is None:
            key = jax.random.PRNGKey(0)
        if speculative is None:
            speculative = self.policy != "fp"
        B = prompt.shape[0]
        stats = GenStats()

        t0 = time.perf_counter()
        logits, state = jax.block_until_ready(
            self._prefill_jit(prompt, memory, batch=B))
        stats.prefill_s = time.perf_counter() - t0

        key, k0 = jax.random.split(key)
        last = sample_token(logits[:, -1] / self.temperature, k0, self.greedy)
        last = last[:, None]
        out = [np.asarray(last)]
        stream_pos = prompt.shape[1]
        generated = 1

        t1 = time.perf_counter()
        while generated < max_new_tokens:
            key, kr = jax.random.split(key)
            if speculative:
                res = self._round(self.params, self.draft_params, state,
                                  last, stream_pos, kr)
                state, last = res.state, res.last_token
                n_new = int(res.n_new)
                toks = np.asarray(res.tokens)[:, :n_new]
                stats.rounds += 1
                stats.proposed += self.gamma
                stats.accepted += n_new - 1  # lockstep-committed drafts
                stream_pos += n_new
            else:
                state, last = self._ar(self.params, state, last,
                                       stream_pos, kr)
                toks = np.asarray(last)
                n_new = 1
                stream_pos += 1
                stats.rounds += 1
            out.append(toks)
            generated += n_new
        jax.block_until_ready(last)
        stats.decode_s = time.perf_counter() - t1
        stats.generated = generated

        tokens = np.concatenate(out, axis=1)[:, :max_new_tokens]
        return GenerationResult(tokens=tokens, stats=stats)


def make_engine(model, params, policy: str, **kw) -> Engine:
    defaults = {"quantspec": dict(gamma=4),
                "fp": dict(gamma=0),
                "streaming": dict(gamma=1, quantize_weights=False),
                "snapkv": dict(gamma=1, quantize_weights=False)}[policy]
    defaults.update(kw)
    return Engine(model, params, policy=policy, **defaults)
