"""Serving engines: static-batch and continuous-batching request generation
with QuantSpec, autoregressive FP, and sparse-KV self-speculative baselines
(StreamingLLM / SnapKV).

`Engine` (static batch) jits one `spec_round` (draft γ → verify → commit)
over a fixed ``[B, S]`` prompt batch and drives it in a Python loop.  For
pure full-attention stacks (quantspec/fp policies) prompts are padded to a
chunk-bucket grid and prefilled through the length-masked fast path
(`serve_prefill_attention` — the Pallas flash-prefill kernel on TPU), so
prefill compiles once per bucket instead of once per prompt length.

`ContinuousEngine` serves ragged multi-request traffic over the **paged**
hierarchical cache (core/paged_kv_cache.py): requests are admitted into
slots and retired between spec rounds, each slot progresses at its own
stream position with per-sequence accept/rollback, and KV blocks come from
a shared pool.  Admission is **chunked and decode-interleaved**: at most
one fixed-size prompt chunk advances per engine iteration, each chunk
attending the prompt-so-far (a transient fp scratch sized to the prompt's
chunk bucket) and quantizing the groups it completes straight into pool
blocks — no dense ``max_seq`` intermediate cache and no `adopt_hier` copy,
and in-flight requests keep decoding while a 128k prompt trickles in.

Device-resident decode megastep (``rounds_per_step``)
-----------------------------------------------------
Both engines default to driving decode in **megasteps**: ``rounds_per_step``
consecutive spec rounds fused into one jitted `lax.scan`
(core/spec_decode.py `megastep`/`paged_megastep`) that carries the cache
state, page table, last tokens, and device-resident per-slot request state
(`SlotState`: generated/budget/done + EOS detection), so budget clamping
and termination masking never leave the accelerator.  The driver is
double-buffered: megastep ``i+1`` is enqueued on the carried device state
*before* megastep ``i``'s packed token/stat buffers are read back (one
`jax.device_get` per megastep, no `block_until_ready` in the steady
state); the scheduler re-enters only between megasteps for
admission/retire, and retirement itself is a jitted `release_slot` — no
host sync.  ``rounds_per_step=0`` keeps the legacy one-round-per-dispatch
loop (the baseline `benchmarks/serving_bench.py` measures against); greedy
outputs are token-identical for every ``rounds_per_step``.

Policies (static engine)
------------------------
quantspec : hierarchical INT4/INT8 shared cache, INT4 draft weights (paper)
fp        : plain FP cache, no speculation (AR baseline)
streaming : FP target cache + StreamingLLM sink+window draft cache
snapkv    : FP target cache + SnapKV prefill-selected draft cache

For the baselines the draft weights stay full precision (matching the
MagicDec-style sparse-KV baselines of the paper, whose draft cost savings
come from the sparse cache only). The continuous engine always runs the
paged quantspec cache; set ``gamma=0`` for its AR baseline.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import paged_kv_cache as PC
from repro.core.disk_tier import DiskTier
from repro.core.host_tier import HostTier, HostTierError, SnapshotMissError
from repro.core.prefix_index import PrefixIndex
from repro.core.spec_decode import (
    RUNG_AR,
    RUNG_INT4,
    RUNG_INT8,
    GovernorConfig,
    MegaResult,
    PagedMegaResult,
    PagedRoundResult,
    RoundResult,
    ar_step,
    megastep,
    paged_ar_step,
    paged_megastep,
    paged_spec_round,
    spec_round,
)
from repro.core.weight_quant import quantize_tree
from repro.distributed import specs as SP
from repro.distributed.sharding import axis_rules
from repro.models.config import ATTN_FULL
from repro.models.stack import AttnState, StackModel
from repro.serving import journal as J
from repro.serving.sampling import sample_token
from repro.serving.scheduler import Request, Scheduler, SlotState, init_slot_state


@dataclasses.dataclass
class GenStats:
    proposed: int = 0
    accepted: int = 0
    rounds: int = 0
    generated: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    # verify positions whose target logits carried non-finite entries —
    # sampling fell back to greedy-over-finite for them (serving/sampling.py)
    numerics_flags: int = 0
    # swap telemetry (host/disk tier): offload/restore counts, bytes moved,
    # prefetch hit/miss at each resume, seconds the engine hot path blocked
    # in resume, and replays-from-prompt after a snapshot was lost
    offloads: int = 0
    restores: int = 0
    swap_bytes: int = 0
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    resume_block_s: float = 0.0
    restarts: int = 0
    # precision-governor telemetry (continuous engine, --governor): ladder
    # walks this request took, rounds spent on the degraded rungs, and the
    # rung it finished on (0 = full-γ INT4 speculation … 3 = AR floor)
    demotions: int = 0
    promotions: int = 0
    int8_rounds: int = 0
    ar_rounds: int = 0
    final_rung: int = 0

    @property
    def acceptance_rate(self) -> float:
        """Safe under zero proposals (an AR-floor round proposes nothing)."""
        return self.accepted / max(self.proposed, 1)

    @property
    def tokens_per_round(self) -> float:
        return self.generated / max(self.rounds, 1)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # [B, n_generated(, K)]
    stats: GenStats


def _round_up(n: int, step: int) -> int:
    return -(-max(n, 1) // step) * step


def round_stats(gamma: int, n_new: int, budget: int):
    """Per-request accounting of one spec round that may be cut short by
    the request's remaining token budget.

    Returns ``(take, proposed_inc, accepted_inc)``. ``take = min(n_new,
    budget)`` tokens are actually kept. ``proposed`` counts only drafts
    that could ever have been used: ``gamma`` clamped by the *pre-round*
    budget — never by the round's outcome, which would shrink ordinary
    rounds and inflate acceptance rates. ``accepted`` counts the kept
    tokens that are accepted drafts: the round's tokens are the
    ``n_new - 1`` accepted drafts followed by the bonus/correction token,
    so an untruncated round keeps ``n_new - 1`` of them and a truncated
    round keeps ``take`` (the bonus token lies beyond the cut) —
    ``min(take, n_new - 1)``. A fully-accepting round therefore reports
    rate 1.0 whether or not the budget cut it short."""
    take = min(n_new, budget)
    return take, min(gamma, budget), max(min(take, n_new - 1), 0)


def _map_attn_state(state, fn):
    """Apply ``fn(attn_state, stacked)`` over every mixer state of a serve
    state dict (requires a pure full-attention stack)."""
    new = {"head": [], "tail": [], "blocks": None}
    for k in ("head", "tail"):
        for mix, ml in state[k]:
            new[k].append((fn(mix, False), ml))
    new["blocks"] = tuple((fn(mix, True), ml)
                          for mix, ml in state["blocks"])
    return new


def _group_fp(scratches, n_groups: int, group: int):
    """Host copies of the first ``n_groups`` quant groups of each layer's
    prefill scratch, grouped for :meth:`PrefixIndex.insert`: a list over
    groups of per-layer ``(k, v)`` pairs (token axis at -3)."""
    cut = n_groups * group
    # lint: ok(host-sync, prefix fingerprints are host-side index keys; runs once per finished prefill, not in the decode steady state)
    fp = jax.device_get([(s.k[..., :cut, :, :], s.v[..., :cut, :, :])
                         for s in scratches])
    return [[(k[..., g * group:(g + 1) * group, :, :],
              v[..., g * group:(g + 1) * group, :, :]) for k, v in fp]
            for g in range(n_groups)]


def _seed_scratch(scr: "PC.PrefillScratch", chain, layer: int, cut: int):
    """Write a matched prefix chain's fp K/V (entry ``layer`` of each
    node's payload) into ``scr[..., :cut, :, :]`` — the suffix then attends
    bit-identical history to a cold prefill."""
    sk = jnp.concatenate([jnp.asarray(nd.fp[layer][0]) for nd in chain],
                         axis=-3)
    sv = jnp.concatenate([jnp.asarray(nd.fp[layer][1]) for nd in chain],
                         axis=-3)
    return PC.PrefillScratch(
        k=scr.k.at[..., :cut, :, :].set(sk.astype(scr.k.dtype)),
        v=scr.v.at[..., :cut, :, :].set(sv.astype(scr.v.dtype)))


@contextlib.contextmanager
def _mesh_scope(mesh: Optional[Mesh]):
    """Activate `mesh` + the serve-mode logical-axis rules so that model
    tracing (the `constrain` calls and the kernels' shard_map entries) sees
    the mesh; a no-op for single-device engines."""
    if mesh is None:
        yield
    else:
        with mesh, axis_rules(mesh, "serve"):
            yield


def _place_params(params, draft_params, mesh: Mesh):
    """device_put target + (possibly Int4-quantized) draft trees per the
    serve-mode param specs; returns (params, drafts, param_sh, draft_sh)."""
    p_sh = SP.param_specs(params, mesh, "serve")
    placed = jax.device_put(params, p_sh)
    if draft_params is params:
        return placed, placed, p_sh, p_sh
    d_sh = SP.param_specs(draft_params, mesh, "serve")
    return placed, jax.device_put(draft_params, d_sh), p_sh, d_sh


class Engine:
    def __init__(self, model: StackModel, params, *, policy: str = "quantspec",
                 gamma: int = 4, greedy: bool = False,
                 temperature: float = 1.0, top_p: Optional[float] = None,
                 quantize_weights: Optional[bool] = None,
                 max_seq: int = 4096, prefill_chunk: int = 512,
                 rounds_per_step: int = 1, mesh: Optional[Mesh] = None,
                 prefix_cache: bool = False,
                 force_rung: Optional[int] = None,
                 ctx_kw: Optional[dict] = None):
        self.model = model
        self.cfg = model.cfg
        self.policy = policy
        self.gamma = gamma
        self.greedy = greedy
        self.temperature = temperature
        self.top_p = top_p
        self.ctx_kw = ctx_kw or {}
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.rounds_per_step = rounds_per_step
        # decode-loop telemetry: blocking device→host transfers and jitted
        # decode dispatches (megasteps, or rounds on the legacy path)
        self.host_syncs = 0
        self.decode_steps = 0
        self.mesh = mesh
        if policy == "quantspec" and gamma + 1 > self.cfg.group_size:
            # one verify pass appends gamma+1 tokens; maybe_flush frees at
            # most G buffer slots, so the append must fit one group
            raise ValueError(f"gamma+1 = {gamma + 1} exceeds the quant "
                             f"group size {self.cfg.group_size}")
        if quantize_weights is None:
            quantize_weights = policy == "quantspec"
        self.params = params
        self.draft_params = (quantize_tree(
            params, group=self.cfg.weight_quant_group)
            if quantize_weights else params)
        self._param_sh = self._draft_sh = None
        if mesh is not None:
            (self.params, self.draft_params, self._param_sh,
             self._draft_sh) = _place_params(params, self.draft_params, mesh)
        # bucketed (padded, length-masked) prefill: pure full-attention
        # stacks under the quantspec/fp policies; other mixers keep scalar
        # stream positions / select on the full prompt, so they take the
        # legacy per-length path
        self._bucketed = (policy in ("quantspec", "fp") and
                          all(s.mixer == ATTN_FULL for s in self.cfg.layers))
        G = self.cfg.group_size
        self._prefill_cap = _round_up(max_seq, G) + 2 * G

        # pin the whole batch to one precision-ladder rung (the static
        # engine has no per-slot governor; this is the rung-identity oracle
        # tests/test_governor.py compares the continuous governor against):
        # 1 halves the effective γ, 2 reads the draft's KV at INT8 (both
        # nibble planes), 3 masks every draft (verify-only AR decode)
        if force_rung not in (None, RUNG_INT4, 1, RUNG_INT8, RUNG_AR):
            raise ValueError(f"force_rung must be None or 0..3, "
                             f"got {force_rung!r}")
        self.force_rung = force_rung
        gamma_eff = None
        draft_int8 = False
        if force_rung == 1:
            gamma_eff = max(1, gamma // 2)
        elif force_rung == RUNG_INT8:
            draft_int8 = True
        elif force_rung == RUNG_AR:
            gamma_eff = 0

        # proposals per round for stats: the masked rounds only ever use
        # gamma_eff drafts, so acceptance rates stay meaningful under a
        # forced rung (and an AR-forced run reports rate 0/0 -> 1-safe)
        self._gamma_stat = gamma if gamma_eff is None else gamma_eff
        self._round_kw = dict(gamma=gamma, policy=policy, greedy=greedy,
                              temperature=temperature, top_p=top_p,
                              gamma_eff=gamma_eff, draft_int8=draft_int8,
                              ctx_kw=self.ctx_kw)
        self._ar_kw = dict(policy=policy, greedy=greedy,
                           temperature=temperature, top_p=top_p,
                           kv_mode="target" if policy == "quantspec" else "fp",
                           ctx_kw=self.ctx_kw)
        self._round = jax.jit(partial(spec_round, model, **self._round_kw))
        self._ar = jax.jit(partial(ar_step, model, **self._ar_kw))
        self._mega = None
        if rounds_per_step >= 1:
            self._mega = jax.jit(partial(megastep, model,
                                         rounds=rounds_per_step,
                                         **self._round_kw),
                                 donate_argnums=(2,))
        self._sharded_fns = {}      # batch -> (round, ar, mega, state specs)
        self._prefill_jit = jax.jit(self._prefill,
                                    static_argnames=("batch",))
        # dense prefix caching (the paged engine's token-identity oracle):
        # admissions run through the history-seeded prefill so the fp K/V
        # of completed prompt groups can be captured into the index
        self.prefix: Optional[PrefixIndex] = None
        if prefix_cache:
            if not self._bucketed or policy != "quantspec":
                raise ValueError("prefix_cache requires the quantspec "
                                 "policy on a pure full-attention stack")
            if mesh is not None:
                raise NotImplementedError("prefix_cache on the static "
                                          "engine is single-device (use "
                                          "ContinuousEngine for sharded "
                                          "serving)")
            self.prefix = PrefixIndex(G)
            self._hist_jit = jax.jit(self._prefill_hist,
                                     static_argnames=("hist",))

    def _mesh_fns(self, state, batch: int):
        """Per-batch jitted rounds with explicit in/out shardings and cache
        donation: params/drafts per `param_specs("serve")`, cache state per
        `state_specs`, scalars/tokens replicated — XLA then partitions the
        round so heads stay local under `model` and the only collectives
        are the post-`wo`/`w_down` all-reduces."""
        fns = self._sharded_fns.get(batch)
        if fns is not None:
            return fns
        mesh = self.mesh
        repl = NamedSharding(mesh, P())
        s_sh = SP.state_specs(state, mesh)
        round_fn = jax.jit(
            partial(spec_round, self.model, **self._round_kw),
            in_shardings=(self._param_sh, self._draft_sh, s_sh, repl, repl,
                          repl),
            out_shardings=RoundResult(state=s_sh, tokens=repl, n_new=repl,
                                      last_token=repl, accept_mask=repl,
                                      nonfinite=repl),
            donate_argnums=(2,))
        ar_fn = jax.jit(
            partial(ar_step, self.model, **self._ar_kw),
            in_shardings=(self._param_sh, s_sh, repl, repl, repl),
            out_shardings=(s_sh, repl),
            donate_argnums=(1,))
        mega_fn = None
        if self.rounds_per_step >= 1:
            mega_fn = jax.jit(
                partial(megastep, self.model, rounds=self.rounds_per_step,
                        **self._round_kw),
                in_shardings=(self._param_sh, self._draft_sh, s_sh, repl,
                              repl, repl, repl, repl),
                out_shardings=MegaResult(
                    state=s_sh, last_token=repl, stream_pos=repl,
                    generated=repl, tokens=repl, n_new=repl, proposed=repl,
                    accepted=repl, nonfinite=repl),
                donate_argnums=(2,))
        fns = (round_fn, ar_fn, mega_fn, s_sh)
        self._sharded_fns[batch] = fns
        return fns

    # ------------------------------------------------------------------
    def _prefill(self, prompt, memory, batch, valid_len=None):
        state = self.model.init_serve_state(
            batch, max_seq=self.max_seq, policy=self.policy,
            ctx_kw=self.ctx_kw)
        kw = dict(self.ctx_kw)
        if valid_len is not None:
            kw["prefill_len"] = valid_len
        logits, state = self.model.prefill(
            self.params, prompt, state, policy=self.policy, memory=memory,
            ctx_kw=kw)
        return logits, state

    def prefill_compiles(self) -> int:
        """Distinct prefill programs compiled so far (one per chunk bucket
        on the padded path; one per prompt length on the legacy path)."""
        return self._prefill_jit._cache_size()

    def _run_prefill(self, prompt, memory, batch):
        """Dispatch to the bucketed padded prefill when the stack/policy
        support it; the prompt is padded to the chunk-bucket grid and the
        true length is position-masked inside (a traced scalar, so ragged
        sweeps reuse one compiled program per bucket)."""
        L = prompt.shape[1]
        bucket = _round_up(L, self.prefill_chunk)
        if not self._bucketed or memory is not None \
                or bucket > self._prefill_cap:
            return self._prefill_jit(prompt, memory, batch=batch)
        pad = [(0, 0), (0, bucket - L)] + [(0, 0)] * (prompt.ndim - 2)
        padded = jnp.pad(jnp.asarray(prompt), pad)
        return self._prefill_jit(padded, memory, batch=batch,
                                 valid_len=jnp.asarray(L, jnp.int32))

    # ---- dense prefix caching (batch-1 oracle path) -------------------
    def _prefill_hist(self, suffix, scratches, hist: int):
        """Jitted history-seeded prefill: the per-layer scratches carry the
        cached prefix fp in ``[0, hist)``; only the suffix runs through the
        stack (band attention over the seeded history), and each layer's
        filled scratch comes back in ``state.draft`` for index capture."""
        state = self.model.init_serve_state(
            1, max_seq=self.max_seq, policy=self.policy, ctx_kw=self.ctx_kw)
        it = iter(scratches)
        state = _map_attn_state(
            state, lambda mix, _s: AttnState(mix.primary, next(it)))
        kw = dict(self.ctx_kw)
        kw["prefill_hist"] = hist
        return self.model.prefill(self.params, suffix, state,
                                  policy=self.policy, ctx_kw=kw)

    def _scratch_stacking(self):
        """Stacked-ness of each attention layer in serve-state walk order
        (head, tail, then the scan-stacked pattern blocks)."""
        cfg = self.cfg
        return ([False] * (len(cfg.head_layers) + len(cfg.tail_layers))
                + [True] * (len(cfg.pattern) if cfg.n_repeats > 0 else 0))

    def _prefill_prefix(self, prompt):
        """Cached-prefix admission: match the prompt against the index,
        seed per-layer scratches with the hit's fp K/V, prefill only the
        uncached suffix, then capture the prompt's completed groups back
        into the index.  Greedy outputs are token-identical to a cold
        prefill (asserted in tests/test_prefix_cache.py)."""
        cfg = self.cfg
        G = cfg.group_size
        toks = np.asarray(prompt)
        S = int(toks.shape[1])
        chain = self.prefix.match(toks[0])
        m_use = min(len(chain), (S - 1) // G)
        chain = chain[:m_use]
        cut = m_use * G
        dtype = jnp.dtype(cfg.dtype)
        scratches = []
        for i, stacked in enumerate(self._scratch_stacking()):
            scr = PC.PrefillScratch(
                k=jnp.zeros((1, S, cfg.num_kv_heads, cfg.hd), dtype),
                v=jnp.zeros((1, S, cfg.num_kv_heads, cfg.hd), dtype))
            if stacked:
                scr = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x, (cfg.n_repeats,) + x.shape), scr)
            if cut:
                scr = _seed_scratch(scr, chain, i, cut)
            scratches.append(scr)
        logits, state = self._hist_jit(jnp.asarray(toks[:, cut:]), scratches,
                                       hist=cut)
        caps = []
        state = _map_attn_state(
            state, lambda mix, _s: (caps.append(mix.draft),
                                    AttnState(mix.primary, None))[1])
        nb = max(0, (S - G) // G)
        if nb:
            self.prefix.insert(toks[0], [-1] * nb, _group_fp(caps, nb, G))
        return logits, state

    def generate(self, prompt: jnp.ndarray, max_new_tokens: int,
                 key=None, memory=None, speculative: Optional[bool] = None
                 ) -> GenerationResult:
        """prompt [B, S] (or [B, S, K] for codebooks)."""
        if key is None:
            key = jax.random.PRNGKey(0)
        if speculative is None:
            speculative = self.policy != "fp"
        B = prompt.shape[0]
        stats = GenStats()

        with _mesh_scope(self.mesh):
            t0 = time.perf_counter()
            prompt = jnp.asarray(prompt)
            if (self.prefix is not None and B == 1 and memory is None
                    and prompt.ndim == 2):
                # lint: ok(host-sync, prefill boundary fence so stats.prefill_s measures completed work; runs once per generate call)
                logits, state = jax.block_until_ready(
                    self._prefill_prefix(prompt))
            else:
                # lint: ok(host-sync, prefill boundary fence so stats.prefill_s measures completed work; runs once per generate call)
                logits, state = jax.block_until_ready(
                    self._run_prefill(prompt, memory, B))
            round_fn, ar_fn, mega_fn = self._round, self._ar, self._mega
            if self.mesh is not None:
                round_fn, ar_fn, mega_fn, s_sh = self._mesh_fns(state, B)
                # commit the freshly-prefilled cache onto its serve specs
                # (heads → model, batch → data) before the first round
                state = jax.device_put(state, s_sh)
            stats.prefill_s = time.perf_counter() - t0

            key, k0 = jax.random.split(key)
            last = sample_token(logits[:, -1] / self.temperature, k0,
                                self.greedy, top_p=self.top_p)
            last = last[:, None]
            # keep the first sampled token on device: the host copy is only
            # needed for the final concatenate, so deferring the transfer
            # lets it overlap the first decode dispatch instead of stalling
            # between prefill and round 0
            out = [last]
            generated = 1

            t1 = time.perf_counter()
            if speculative and mega_fn is not None:
                generated = self._drive_megasteps(
                    mega_fn, state, last, prompt.shape[1], generated,
                    max_new_tokens, key, out, stats)
            else:
                generated = self._drive_rounds(
                    round_fn, ar_fn, state, last, prompt.shape[1], generated,
                    max_new_tokens, key, out, stats, speculative)
            stats.decode_s = time.perf_counter() - t1
            stats.generated = min(generated, max_new_tokens)

        tokens = np.concatenate(out, axis=1)[:, :max_new_tokens]
        return GenerationResult(tokens=tokens, stats=stats)

    def _drive_rounds(self, round_fn, ar_fn, state, last, stream_pos,
                      generated, max_new_tokens, key, out, stats,
                      speculative):
        """Legacy per-round loop: one jitted dispatch — and two blocking
        readbacks (`n_new`, tokens) — per spec round.  The benchmark
        baseline, and the AR (non-speculative) path."""
        while generated < max_new_tokens:
            key, kr = jax.random.split(key)
            if speculative:
                res = round_fn(self.params, self.draft_params, state,
                               last, stream_pos, kr)
                state, last = res.state, res.last_token
                # lint: ok(host-sync, legacy per-round loop is the measured two-syncs-per-round baseline; the megastep driver is the fast path)
                n_new = int(res.n_new)
                # lint: ok(host-sync, legacy per-round loop readback; counted in host_syncs)
                toks = np.asarray(res.tokens)[:, :n_new]
                self.host_syncs += 2
                stats.rounds += 1
                # lockstep-committed drafts, clamped by the remaining
                # budget so a final round's trimmed tail isn't counted
                _, proposed, accepted = round_stats(
                    self._gamma_stat, n_new, max_new_tokens - generated)
                stats.proposed += proposed
                stats.accepted += accepted
                # lint: ok(host-sync, numerics flags ride the same legacy-loop readback; already counted)
                stats.numerics_flags += int(np.sum(np.asarray(res.nonfinite)))
                stream_pos += n_new
            else:
                state, last = ar_fn(self.params, state, last,
                                    stream_pos, kr)
                # lint: ok(host-sync, AR path emits one token per step and must read it back to append; counted in host_syncs)
                toks = np.asarray(last)
                self.host_syncs += 1
                n_new = 1
                stream_pos += 1
                stats.rounds += 1
            self.decode_steps += 1
            out.append(toks)
            generated += n_new
        # lint: ok(host-sync, terminal fence so stats.decode_s measures completed work; once per generate call)
        jax.block_until_ready(last)
        return generated

    def _drive_megasteps(self, mega_fn, state, last, stream_pos, generated,
                         max_new_tokens, key, out, stats):
        """Double-buffered megastep driver: dispatch megastep ``i+1`` on the
        device-carried state *before* reading back megastep ``i``'s packed
        buffers, so the single per-megastep `device_get` overlaps the next
        megastep's compute.  Termination masking is on device (`lax.cond`
        per round), so the one speculatively-dispatched trailing megastep
        is all-skip and near-free."""
        budget = jnp.asarray(max_new_tokens, jnp.int32)
        gen_dev = jnp.asarray(generated, jnp.int32)
        pos_dev = jnp.asarray(stream_pos, jnp.int32)
        prev = None
        while generated < max_new_tokens:
            key, kmega = jax.random.split(key)
            res = mega_fn(self.params, self.draft_params, state, last,
                          pos_dev, gen_dev, budget, kmega)
            state, last = res.state, res.last_token
            pos_dev, gen_dev = res.stream_pos, res.generated
            self.decode_steps += 1
            if prev is not None:
                generated = self._harvest_megastep(prev, out, stats,
                                                   generated, max_new_tokens)
            prev = (res.tokens, res.n_new, res.proposed, res.accepted,
                    res.nonfinite)
        if prev is not None:
            generated = self._harvest_megastep(prev, out, stats, generated,
                                               max_new_tokens)
        return generated

    def _harvest_megastep(self, packed, out, stats, generated,
                          max_new_tokens):
        """The single blocking transfer per megastep; per-round bookkeeping
        happens on the packed host copies (skipped rounds have n_new=0)."""
        # lint: ok(host-sync, the one budgeted readback per megastep; overlapped with the next megastep by the double-buffered driver)
        toks, n_new, proposed, accepted, nonfinite = jax.device_get(packed)
        self.host_syncs += 1
        for k in range(n_new.shape[0]):
            nn = int(n_new[k])
            if nn == 0:
                continue
            out.append(toks[k][:, :nn])
            stats.rounds += 1
            stats.proposed += int(proposed[k])
            stats.accepted += int(accepted[k])
            stats.numerics_flags += int(nonfinite[k])
            generated += nn
        return generated


@dataclasses.dataclass
class _PrefillJob:
    """One in-flight chunked admission: per-layer fp scratch + progress."""

    req: Request
    slot: int
    bucket: int                  # prompt length rounded up to the chunk grid
    n_chunks: int
    scratch: list                # per-attn-layer PrefillScratch (walk order)
    chunk: int = 0               # chunks admitted so far
    cut: int = 0                 # cached-prefix tokens (prefix caching):
                                 # chunks cover only [cut, prompt_len)


@dataclasses.dataclass
class _InflightMega:
    """One dispatched-but-unharvested megastep: the packed device buffers
    plus the slot→request mapping captured at dispatch time (slots can be
    retired and re-admitted between dispatch and harvest; the mapping pins
    each packed row to the request that owned the slot when the megastep
    launched)."""

    packed: tuple                # (tokens, take, proposed, accepted,
                                 #  nonfinite, rung, first, done) device
                                 #  arrays
    reqs: dict                   # slot -> Request decoding at dispatch
    emit_first: list             # slots whose pending_first this harvests


@dataclasses.dataclass
class _Prefetch:
    """One speculatively restored snapshot: device-placed planes (the
    device_put was dispatched while a megastep was in flight) or the error
    the restore hit (surfaced when the request is actually admitted)."""

    snap: object = None          # SlotSnapshot with device-resident planes
    error: Optional[Exception] = None
    fetch_s: float = 0.0         # host time the off-path dispatch took


class ContinuousEngine:
    """Continuous-batching engine over the paged hierarchical cache.

    ``max_slots`` requests decode concurrently; waiting requests are
    admitted the moment a slot frees *and* the block pool can hold their
    worst-case footprint. One jitted `paged_spec_round` serves every round
    regardless of which requests occupy which slots (shapes are static in
    [slots, pool]); admission/retirement mutate only the page table.

    Admission is chunked: each engine iteration advances the in-flight
    prefill by at most one ``prefill_chunk``-token chunk between spec
    rounds, so admitting a long prompt never stalls active decodes.  A
    chunk attends the prompt-so-far from a transient fp scratch (sized to
    the prompt's chunk bucket — numerics match one-shot dense prefill) and
    its completed groups are quantized straight into pool blocks; there is
    no dense ``max_seq`` intermediate cache and no `adopt_hier` copy.

    Greedy decoding is schedule-invariant: each request's output tokens are
    identical to a batch-1 run of the static engine on the same prompt
    (verified in tests/test_paged_engine.py and benchmarks/paged_serving.py).
    """

    def __init__(self, model: StackModel, params, *, gamma: int = 4,
                 greedy: bool = False, temperature: float = 1.0,
                 top_p: Optional[float] = None,
                 quantize_weights: bool = True, max_slots: int = 4,
                 max_seq: int = 4096, pool_blocks: Optional[int] = None,
                 prefill_chunk: int = 256, rounds_per_step: int = 1,
                 eos_id: Optional[int] = None, mesh: Optional[Mesh] = None,
                 prefix_cache: bool = False,
                 overflow: str = "preempt", preempt_patience: int = 16,
                 governor: bool = False, accept_window: int = 32,
                 accept_floor: float = 0.5, accept_ceiling: float = 0.8,
                 probe_every: int = 8, gamma_lo: int = 0,
                 max_pending: Optional[int] = None, strict: bool = False,
                 host_tier: Optional[HostTier] = None, fault=None,
                 host_capacity_bytes: Optional[int] = None,
                 disk_dir: Optional[str] = None,
                 disk_capacity_bytes: Optional[int] = None,
                 prefetch: bool = True,
                 journal_dir: Optional[str] = None,
                 checkpoint_every: int = 8, journal_fsync: bool = False,
                 ctx_kw: Optional[dict] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.gamma = gamma
        self.greedy = greedy
        self.temperature = temperature
        self.top_p = top_p
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.rounds_per_step = rounds_per_step
        self.eos_id = eos_id
        if overflow not in ("preempt", "wait", "reject"):
            raise ValueError(f"unknown overflow mode {overflow!r}")
        # what happens when the queue head cannot be admitted even after
        # LRU prefix eviction: "preempt" swaps the youngest/lowest-priority
        # running slot to the host tier and resumes it later (graceful
        # degradation), "wait" blocks FCFS until capacity frees (legacy),
        # "reject" fails the head immediately (the overload-bench baseline)
        self.overflow = overflow
        self.preempt_patience = max(int(preempt_patience), 1)
        self.strict = strict
        self.fault = fault
        # crash-safe serving (serving/journal.py): a write-ahead log of
        # lifecycle events plus periodic checkpoints that persist host-tier
        # snapshots to the disk tier; `recover()` replays the log.  The
        # journal dir also hosts the default disk-tier root (kv/), so a
        # bare --journal flag gets durable snapshots too.
        self.journal: Optional[J.Journal] = None
        if journal_dir is not None:
            self.journal = J.Journal(journal_dir, fsync=journal_fsync)
            if disk_dir is None:
                disk_dir = os.path.join(journal_dir, "kv")
        self.checkpoint_every = checkpoint_every
        self.checkpoints = 0
        self._harvests = 0
        # three-tier hierarchy: device → host (HostTier) → disk (DiskTier);
        # the host tier spills LRU snapshots past host_capacity_bytes
        self.disk_tier: Optional[DiskTier] = (
            DiskTier(disk_dir, capacity_bytes=disk_capacity_bytes,
                     fault=fault) if disk_dir is not None else None)
        if host_tier is not None:
            self.host_tier = host_tier
            if host_tier.disk is None and self.disk_tier is not None:
                host_tier.disk = self.disk_tier
        else:
            self.host_tier = (
                HostTier(fault=fault, capacity_bytes=host_capacity_bytes,
                         disk=self.disk_tier)
                if overflow == "preempt" else None)
        self.preempts = 0
        self.resumes = 0
        # speculative prefetch: while a megastep is in flight, the restore
        # (disk→host read + host→device device_put) of the resumable queue
        # front is dispatched ahead of admission, so `_do_resume` finds the
        # planes already on device and blocks ~0 on the hot path
        self.prefetch = prefetch
        self._prefetched: Dict[int, "_Prefetch"] = {}
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self.resume_block_s = 0.0
        self.restarts = 0
        self._stall = 0             # lifecycle sweeps with a blocked head
        # the megastep driver needs device-side termination (gamma>0 spec
        # rounds); gamma=0 serves AR baselines on the legacy loop
        self._use_megastep = rounds_per_step >= 1 and gamma > 0
        # acceptance-aware precision governor: per-slot ladder walks run
        # entirely inside the megastep (masking within the one compiled
        # program); gamma=0 engines already *are* the AR floor
        self.governor_cfg: Optional[GovernorConfig] = None
        if governor:
            if not self._use_megastep:
                raise ValueError("governor requires the megastep driver "
                                 "(rounds_per_step >= 1 and gamma > 0); a "
                                 "gamma=0 engine is already pure AR decode")
            self.governor_cfg = GovernorConfig(
                window=max(int(accept_window), 1),
                floor=float(accept_floor), ceiling=float(accept_ceiling),
                probe_every=max(int(probe_every), 1),
                gamma_lo=int(gamma_lo))
        if eos_id is not None and not self._use_megastep:
            raise ValueError("eos_id requires the megastep driver "
                             "(rounds_per_step >= 1 and gamma > 0): EOS "
                             "detection is device-resident")
        # decode-loop telemetry (see benchmarks/serving_bench.py)
        self.host_syncs = 0
        self.decode_steps = 0
        self.mesh = mesh
        G = self.cfg.group_size
        if gamma + 1 > G:
            # plan_step flushes at most one block per step, so a verify
            # append of gamma+1 tokens must fit one group
            raise ValueError(f"gamma+1 = {gamma + 1} exceeds the quant "
                             f"group size {G}; the FP buffer would overflow")
        self.nbmax = max(1, -(-max_seq // G))
        self.pool_blocks = pool_blocks or max_slots * self.nbmax
        self.ctx_kw = ctx_kw or {}
        self.draft_params = (quantize_tree(
            params, group=self.cfg.weight_quant_group)
            if quantize_weights else params)
        self._param_sh = self._draft_sh = None
        if mesh is not None:
            (self.params, self.draft_params, self._param_sh,
             self._draft_sh) = _place_params(params, self.draft_params, mesh)

        self.state = model.init_serve_state(
            max_slots, max_seq=max_seq, policy="paged",
            ctx_kw={**self.ctx_kw, "pool_blocks": self.pool_blocks})
        self.table = PC.init_table(max_slots, self.nbmax, self.pool_blocks)
        self.last = jnp.zeros((max_slots, 1), jnp.int32)
        self.slots_dev = init_slot_state(max_slots)
        self.scheduler = Scheduler(max_slots, self.pool_blocks, G,
                                   max_pending=max_pending, strict=strict)
        self._retired: List[Request] = []   # finished, not yet run()-claimed
        self._prefilling: Optional[_PrefillJob] = None
        self._inflight: Optional[_InflightMega] = None
        # prefix caching: radix index over quantized prompt blocks; cached
        # admissions alias index-owned blocks into the slot's table row and
        # prefill only the uncached suffix (greedy outputs stay identical —
        # tests/test_prefix_cache.py)
        self.prefix: Optional[PrefixIndex] = (PrefixIndex(G) if prefix_cache
                                              else None)
        # blocking index-harvest transfers (block ids + fp capture at each
        # finalize) — kept separate from `host_syncs` so the decode-loop
        # sync budget (≤1/megastep) stays assertable
        self.cache_syncs = 0
        # slot -> pool block ids the slot's prompt prefix references (aliased
        # or slot-produced-and-indexed); shields them from LRU eviction
        self._slot_shared: dict = {}
        # (req_id, matched chain) memo: match once per pending head, reused
        # by _start_prefill so admission doesn't double-count hits/LRU bumps
        self._head_chain: Optional[tuple] = None

        round_p = partial(paged_spec_round, model, gamma=gamma, greedy=greedy,
                          temperature=temperature, top_p=top_p,
                          ctx_kw=self.ctx_kw or None)
        ar_p = partial(paged_ar_step, model, greedy=greedy,
                       temperature=temperature, top_p=top_p,
                       ctx_kw=self.ctx_kw or None)
        mega_p = partial(paged_megastep, model, rounds=max(rounds_per_step, 1),
                         gamma=max(gamma, 1), greedy=greedy,
                         temperature=temperature, top_p=top_p, eos_id=eos_id,
                         ctx_kw=self.ctx_kw or None,
                         governor=self.governor_cfg)
        # per-slot draft-corruption switches (tests/fault_injection.py
        # draft_mangle): always passed as a traced i32 [slots] vector so
        # toggling a slot never changes the jit cache key — zero recompiles
        self._mangle_host = np.zeros((max_slots,), np.int32)
        self._mangle_dev = jnp.asarray(self._mangle_host)
        self._release = jax.jit(PC.release_slot)
        if mesh is None:
            self._state_sh = self._table_sh = None
            self._round = jax.jit(round_p)
            self._ar = jax.jit(ar_p)
            self._mega = (jax.jit(mega_p, donate_argnums=(2, 3, 4, 5))
                          if self._use_megastep else None)
        else:
            # build the cache state directly onto its serve shardings (pool
            # kv-heads → model, buffer slots → data, table replicated) and
            # pin the round's in/out shardings to them; the donated cache
            # then stays in place and XLA's only collectives are the
            # post-`wo`/`w_down` all-reduces.
            repl = NamedSharding(mesh, P())
            self._state_sh = SP.state_specs(self.state, mesh)
            self._table_sh = SP.table_specs(self.table, mesh)
            slots_sh = SP.slot_state_specs(self.slots_dev, mesh)
            self.state = jax.device_put(self.state, self._state_sh)
            self.table = jax.device_put(self.table, self._table_sh)
            self.last = jax.device_put(self.last, repl)
            self.slots_dev = jax.device_put(self.slots_dev, slots_sh)
            self._round = jax.jit(
                round_p,
                in_shardings=(self._param_sh, self._draft_sh, self._state_sh,
                              self._table_sh, repl, repl),
                out_shardings=PagedRoundResult(
                    state=self._state_sh, table=self._table_sh, tokens=repl,
                    n_new=repl, last_token=repl, accept_mask=repl,
                    nonfinite=repl),
                donate_argnums=(2, 3))
            self._ar = jax.jit(
                ar_p,
                in_shardings=(self._param_sh, self._state_sh, self._table_sh,
                              repl, repl),
                out_shardings=(self._state_sh, self._table_sh, repl, repl),
                donate_argnums=(1, 2))
            self._mega = None
            if self._use_megastep:
                # the whole carried decode state is donated and pinned to
                # its serve shardings, so K rounds run SPMD without the
                # cache ever changing placement; the packed readback
                # buffers are replicated (tiny)
                self._mega = jax.jit(
                    mega_p,
                    in_shardings=(self._param_sh, self._draft_sh,
                                  self._state_sh, self._table_sh, repl,
                                  slots_sh, repl, repl),
                    out_shardings=PagedMegaResult(
                        state=self._state_sh, table=self._table_sh,
                        last_token=repl, slots=slots_sh, tokens=repl,
                        take=repl, proposed=repl, accepted=repl,
                        nonfinite=repl, rung=repl, first=repl, done=repl),
                    donate_argnums=(2, 3, 4, 5))
        self._chunk_jit = jax.jit(self._chunk_step)
        self._finalize_jit = jax.jit(self._finalize_step)
        # preempt-to-host tier: snapshot gathers a slot's plane bytes by
        # block-table row (no donation — the carried state lives on), the
        # resume jit pops fresh blocks and scatters the bytes back
        self._snapshot_jit = jax.jit(self._snapshot_step)
        self._resume_jit = jax.jit(self._resume_step,
                                   donate_argnums=(0, 1, 2, 3))

    # ---- chunked prefill pipeline ------------------------------------
    def _chunk_step(self, params, tokens, state, table, slot, valid):
        """One jitted prompt chunk: plan block allocation once, run the
        stack (band attention + fused quantize-to-pool per layer)."""
        table, step = PC.plan_prefill_chunk(
            table, slot, valid, self.prefill_chunk, self.cfg.group_size)
        kw = dict(self.ctx_kw)
        kw["prefill_chunk"] = step
        logits, state = self.model.prefill(params, tokens, state,
                                           policy="paged", ctx_kw=kw)
        return logits, state, table

    def _finalize_step(self, state, table, last, slots, slot, logits, k0,
                       budget):
        """After the last chunk: move each layer's trailing fp window from
        the scratch into the slot's double buffer, activate the slot, and
        sample the request's first token **on device** — it lands in the
        carried ``last`` and in ``SlotState`` (generated=1, done if the
        budget is ≤1 or EOS), and reaches the host only with the next
        megastep's packed readback. No blocking transfer at admission."""
        blocks = table.blocks[slot]
        buf_len = table.buf_len[slot]

        def fin(mix, stacked):
            scratch = mix.draft
            if stacked:
                pool = jax.vmap(
                    lambda pl_, sk, sv: PC.write_prefill_buffer(
                        pl_, slot, blocks, buf_len, PC.PrefillScratch(sk, sv))
                )(mix.primary, scratch.k, scratch.v)
            else:
                pool = PC.write_prefill_buffer(mix.primary, slot, blocks,
                                               buf_len, scratch)
            return AttnState(pool, scratch)

        # the chunk step already sliced the last valid position's logits
        first = sample_token(logits[:, 0] / self.temperature, k0,
                             self.greedy, top_p=self.top_p)[0]
        done = budget <= 1
        if self.eos_id is not None:
            done = done | (first == self.eos_id)
        zero = jnp.asarray(0, jnp.int32)
        new_slots = slots._replace(
            generated=slots.generated.at[slot].set(jnp.minimum(budget, 1)),
            budget=slots.budget.at[slot].set(budget),
            done=slots.done.at[slot].set(done),
            # fresh admissions start at the top of the precision ladder with
            # an empty acceptance window and no probe countdown
            rung=slots.rung.at[slot].set(zero),
            win_prop=slots.win_prop.at[slot].set(zero),
            win_acc=slots.win_acc.at[slot].set(zero),
            probe=slots.probe.at[slot].set(zero))
        return (self._map_attn(state, fin), PC.activate_slot(table, slot),
                last.at[slot, 0].set(first), new_slots)

    @staticmethod
    def _map_attn(state, fn):
        """Apply ``fn(attn_state, stacked)`` over every mixer state (the
        paged engine requires a pure full-attention stack)."""
        return _map_attn_state(state, fn)

    def _inject_scratch(self, state, scratch: list):
        it = iter(scratch)
        return self._map_attn(
            state, lambda mix, _s: AttnState(mix.primary, next(it)))

    def _extract_scratch(self, state):
        out: list = []

        def fn(mix, _stacked):
            out.append(mix.draft)
            return AttnState(mix.primary, None)

        return self._map_attn(state, fn), out

    # ---- preempt-to-host tier ----------------------------------------
    _POOL_PLANES = ("k_upper", "k_lower", "k_scale", "k_zero",
                    "v_upper", "v_lower", "v_scale", "v_zero")

    def _snapshot_step(self, state, table, last, slot):
        """Gather one slot's KV bytes for offload: every layer's pool
        planes indexed by the slot's block-table row (masked lanes gather
        block 0 — harmless padding, the restore scatters them into the
        write-scratch block) plus its fp double-buffer rows.  All gathers
        run along unsharded axes, so the step partitions under a mesh
        without collectives; the tiny meta tuple is what the host reads
        back synchronously at preemption time."""
        row = table.block_table[slot]
        planes = []

        def fn(mix, _stacked):
            p = mix.primary
            d = {f: jnp.take(getattr(p, f), row, axis=-4)
                 for f in self._POOL_PLANES}
            d["buf_k"] = jnp.take(p.buf_k, slot, axis=-4)
            d["buf_v"] = jnp.take(p.buf_v, slot, axis=-4)
            planes.append(d)
            return mix

        self._map_attn(state, fn)
        meta = (table.blocks[slot], table.buf_len[slot], table.pos[slot],
                last[slot, 0])
        return planes, meta

    def _resume_step(self, state, table, last, slots, planes, slot, n,
                     buf_len, pos, last_tok, gen, budget):
        """Swap a snapshot back in: pop ``n`` fresh blocks into ``slot``'s
        (re-activated) table row and scatter the saved plane bytes into
        them — bit-exact, no re-quantization — then restore the carried
        last token and the device-resident SlotState row."""
        table, ids = PC.adopt_blocks(table, slot, n, buf_len, pos)
        it = iter(planes)

        def fn(mix, stacked):
            d = next(it)
            p = mix.primary

            def scat(arr, v, idx):
                v = v.astype(arr.dtype)
                return (arr.at[:, idx].set(v) if stacked
                        else arr.at[idx].set(v))

            repl = {f: scat(getattr(p, f), d[f], ids)
                    for f in self._POOL_PLANES}
            repl["buf_k"] = scat(p.buf_k, d["buf_k"], slot)
            repl["buf_v"] = scat(p.buf_v, d["buf_v"], slot)
            return AttnState(p._replace(**repl), mix.draft)

        state = self._map_attn(state, fn)
        last = last.at[slot, 0].set(jnp.asarray(last_tok, jnp.int32))
        zero = jnp.asarray(0, jnp.int32)
        slots = slots._replace(
            generated=slots.generated.at[slot].set(
                jnp.asarray(gen, jnp.int32)),
            budget=slots.budget.at[slot].set(jnp.asarray(budget, jnp.int32)),
            done=slots.done.at[slot].set(False),
            # a resumed request re-enters at the top rung with a fresh
            # window; the governor re-demotes quickly if acceptance is
            # still collapsed (its host-side window survives in Request)
            rung=slots.rung.at[slot].set(zero),
            win_prop=slots.win_prop.at[slot].set(zero),
            win_acc=slots.win_acc.at[slot].set(zero),
            probe=slots.probe.at[slot].set(zero))
        return state, table, last, slots

    def _do_preempt(self, slot: int) -> bool:
        """Preempt one running slot to the host tier.  Called only with an
        empty megastep pipeline (request bookkeeping current): gather the
        slot's plane bytes (dispatched on the carried device state), start
        the async host copy, release the blocks (refcount-aware — blocks
        the prefix index retains survive for other requests to alias), and
        re-enqueue the request at the queue front as resumable."""
        req = self.scheduler.active[slot]
        planes, meta = self._snapshot_jit(self.state, self.table, self.last,
                                          jnp.asarray(slot, jnp.int32))
        # lint: ok(host-sync, preemption boundary: victim metadata must reach the host to build the snapshot record; off the steady-state path)
        n, buf_len, pos, last_tok = (int(x) for x in jax.device_get(meta))
        self.host_syncs += 1
        if req.pending_first:
            # the prefill-sampled first token never reached the host; it is
            # the slot's carried last token, which we just read back
            req.tokens.append(last_tok)
            req.pending_first = False
        try:
            snap = self.host_tier.offload(req.req_id, planes, n_blocks=n,
                                          buf_len=buf_len, pos=pos,
                                          last_token=last_tok)
        except HostTierError as e:
            # can't preserve the slot's KV — fail this request, keep serving
            self._retire(slot, "failed", f"offload failed: {e}")
            self.preempts += 1
            return True
        req.offloads += 1
        req.swap_bytes += snap.nbytes
        self.table = self._release(self.table, jnp.asarray(slot, jnp.int32))
        self._slot_shared.pop(slot, None)
        self.set_mangle(slot, 0)
        self.scheduler.preempt(slot)
        self.preempts += 1
        self._log("preempt", req=req.req_id,
                  tokens=[int(t) for t in req.tokens])
        return True

    def _do_resume(self, req: Request) -> str:
        """Swap a resumable request back in (it already holds its slot and
        reservation from `next_admission`).  The prefetcher usually did the
        expensive half already — disk→host read plus host→device
        device_put, dispatched while the previous megastep was in flight —
        so the hot path only runs the resume jit on device-resident planes
        (a prefetch *hit*; misses fall back to the PR 7 dispatch-at-
        admission restore).  Returns ``"resumed"``, ``"failed"``, or
        ``"restart"`` (snapshot capacity-evicted from every tier → the
        caller replays the request from its prompt; greedy decoding makes
        the replayed tokens identical)."""
        slot = req.slot
        t0 = time.perf_counter()
        pf = self._prefetched.pop(req.req_id, None)
        if pf is not None and pf.error is not None:
            if isinstance(pf.error, SnapshotMissError):
                pf = None                   # fall through to the live probe
            else:
                self.scheduler.retire(slot, "failed",
                                      f"swap-in failed: {pf.error}")
                # a corrupt/unreadable record must not leak in any tier
                self.host_tier.discard(req.req_id)
                self._log("finish", req=req.req_id, status="failed",
                          reason=f"swap-in failed: {pf.error}")
                self._retired.append(req)
                return "failed"
        if pf is not None:
            snap = pf.snap
            self.prefetch_hits += 1
            req.prefetch_hits += 1
            planes = snap.planes            # already device-resident
        else:
            try:
                snap = self.host_tier.restore(req.req_id)
            except SnapshotMissError:
                self._restart_from_scratch(req)
                return "restart"
            except HostTierError as e:
                self.scheduler.retire(slot, "failed", f"swap-in failed: {e}")
                # a corrupt/unreadable record must not leak in any tier
                self.host_tier.discard(req.req_id)
                self._log("finish", req=req.req_id, status="failed",
                          reason=f"swap-in failed: {e}")
                self._retired.append(req)
                return "failed"
            self.prefetch_misses += 1
            req.prefetch_misses += 1
            planes = snap.planes
            if self.mesh is not None:
                planes = jax.device_put(
                    planes, SP.snapshot_specs(planes, self.mesh))
        gen = len(req.tokens)
        self.state, self.table, self.last, self.slots_dev = self._resume_jit(
            self.state, self.table, self.last, self.slots_dev, planes,
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(snap.n_blocks, jnp.int32),
            jnp.asarray(snap.buf_len, jnp.int32),
            jnp.asarray(snap.pos, jnp.int32),
            jnp.asarray(snap.last_token, jnp.int32),
            jnp.asarray(gen, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32))
        req.resume = False
        req.admit_t = time.perf_counter()
        req.restores += 1
        req.swap_bytes += snap.nbytes
        dt = time.perf_counter() - t0
        req.resume_block_s += dt
        self.resume_block_s += dt
        self.resumes += 1
        self._log("resume", req=req.req_id)
        return "resumed"

    def _restart_from_scratch(self, req: Request) -> None:
        """The snapshot was lost (capacity-evicted from host *and* disk,
        or never persisted before a crash): replay the request from its
        prompt.  It keeps its slot and reservation; the harvested tokens
        are discarded and regenerated — greedy decoding is deterministic,
        so the final stream is token-identical (asserted in
        tests/test_disk_tier.py / test_recovery.py)."""
        req.resume = False
        req.tokens = []
        req.pending_first = False
        req.prefill_pos = 0
        req.prefill_chunks = 0
        req.restarts += 1
        self.restarts += 1
        self._log("restart", req=req.req_id)
        self._prefilling = self._start_prefill(req)

    def _maybe_prefetch(self) -> None:
        """Speculatively restore the resumable queue front: dispatch its
        disk→host read and host→device `device_put` now, while the just-
        enqueued megastep still occupies the device, so the eventual
        `_do_resume` blocks ~0 on the hot path.  At most one fetch per
        call bounds the off-path work; resumables sit at the queue front
        (re-enqueued there by preemption), so scanning stops at the first
        non-resumable request.  Restore errors are *recorded*, not raised —
        they surface at admission, on the request they belong to."""
        if not self.prefetch or self.host_tier is None:
            return
        for r in self.scheduler.pending:
            if not r.resume:
                break
            if r.req_id in self._prefetched:
                continue
            t0 = time.perf_counter()
            try:
                snap = self.host_tier.restore(r.req_id)
            except SnapshotMissError:
                # nothing to fetch — admission will replay from the prompt
                continue
            except HostTierError as e:
                self._prefetched[r.req_id] = _Prefetch(error=e)
                return
            planes = snap.planes
            if self.mesh is not None:
                planes = jax.device_put(
                    planes, SP.snapshot_specs(planes, self.mesh))
            else:
                planes = jax.device_put(planes)
            snap.planes = planes
            self._prefetched[r.req_id] = _Prefetch(
                snap=snap, fetch_s=time.perf_counter() - t0)
            return

    def _log(self, ev: str, **fields) -> None:
        if self.journal is not None:
            self.journal.append(ev, **fields)

    def _checkpoint(self) -> None:
        """Persist every host-resident snapshot to the disk tier (copy,
        not evict) and mark the journal position — the durable half of
        crash recovery.  A failing persist degrades that one request to
        replay-from-prompt after a crash; it never stops the engine."""
        persisted = []
        if self.host_tier is not None and self.host_tier.disk is not None:
            for rid in list(self.host_tier._store):
                try:
                    if self.host_tier.persist(rid):
                        persisted.append(rid)
                except HostTierError as e:
                    self._log("checkpoint_skip", req=rid, reason=str(e))
        self.journal.checkpoint({"persisted": persisted})
        self.checkpoints += 1

    def set_mangle(self, slot: int, mode: int) -> None:
        """Arm (or disarm) deterministic draft corruption for one slot:
        0 = off, 1 = mangle every draft sample, 2 = mangle only INT4-rung
        draft samples (the corruption "heals" once the governor escalates
        the slot's draft KV read to INT8).  The switch is a traced vector,
        so toggling it never recompiles the megastep."""
        if self._mangle_host[slot] != mode:
            self._mangle_host[slot] = mode
            self._mangle_dev = jnp.asarray(self._mangle_host)

    def cancel(self, req: Request) -> None:
        """Request cancellation; honored at the next megastep harvest
        boundary (the device may decode a few more speculative tokens that
        are simply discarded)."""
        req.cancel_requested = True

    def _match_prefix(self, req: Request) -> list:
        """Matched (LRU-trimmed) index chain for ``req``, memoised per
        request so the admission hint and `_start_prefill` share one
        `match()` (stats and LRU clocks bump once per admission).  The
        chain is capped at ``(S-1)//G`` groups: at least one suffix token
        must run through the stack to produce the last-position logits."""
        if self._head_chain is not None and self._head_chain[0] == req.req_id:
            return self._head_chain[1]
        G = self.cfg.group_size
        chain = self.prefix.match(req.prompt)
        chain = chain[:min(len(chain), (req.prompt_len - 1) // G)]
        self._head_chain = (req.req_id, chain)
        return chain

    def _start_prefill(self, req: Request) -> _PrefillJob:
        C = self.prefill_chunk
        G = self.cfg.group_size
        H, hd = self.cfg.num_kv_heads, self.cfg.hd
        chain = self._match_prefix(req) if self.prefix is not None else []
        self._head_chain = None
        cut = len(chain) * G
        # the suffix chunks land at [cut, cut + k*C); keep the grid anchored
        # at `cut` so the last chunk's scratch write stays in bounds
        bucket = cut + _round_up(req.prompt_len - cut, C)
        dtype = self._buf_dtype()

        def make(_mix, stacked):
            scr = PC.init_prefill_scratch(bucket, G, H, hd, dtype)
            if stacked:
                scr = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x, (self.cfg.n_repeats,) + x.shape), scr)
            if cut:
                # seed the cached prefix fp — suffix chunks then attend
                # bit-identical history to a cold full-prompt admission
                scr = _seed_scratch(scr, chain, len(scratch), cut)
            if self.mesh is not None:
                # transient fp prompt history: kv-heads follow the K/V
                # projections onto `model`, the rest replicated
                scr = jax.device_put(
                    scr, SP.scratch_specs(scr, self.mesh, stacked))
            return scr

        scratch = []
        self._map_attn(self.state,
                       lambda mix, st: scratch.append(make(mix, st)) or mix)
        if chain:
            # alias the index's blocks into the slot row (all but the last
            # matched group — that one is re-packed privately from the
            # seeded scratch, the copy-on-write at the ragged fp window)
            ids = [nd.block_id for nd in chain[:-1]]
            self.table = PC.share_blocks(self.table, req.slot, ids, cut, G)
            self._slot_shared[req.slot] = list(ids)
            req.prefill_pos = cut
        req.admit_t = time.perf_counter()
        req.prefill_bucket = bucket
        return _PrefillJob(req=req, slot=req.slot, bucket=bucket,
                           n_chunks=(bucket - cut) // C, scratch=scratch,
                           cut=cut)

    def _buf_dtype(self):
        for k in ("head", "tail"):
            for mix, _ in self.state[k]:
                return mix.primary.buf_k.dtype
        return self.state["blocks"][0][0].primary.buf_k.dtype

    def _prepare_admission(self, head: Request):
        """Prefix-caching admission prep for the queue head: set the shared
        hint (aliased blocks never pop the free stack, so the scheduler
        discounts them from the reservation) and, if the pool still can't
        fit the request, LRU-evict unreferenced indexed blocks.  Blocks
        aliased by live slots — or about to be, via the head's own matched
        chain — are shielded; eviction can never free memory in use.

        A resumable head never aliases (its snapshot restores into fresh
        private blocks), so it skips the match and keeps shared_hint=0 —
        only the eviction half applies."""
        chain = [] if head.resume else self._match_prefix(head)
        if not head.resume:
            self.scheduler.set_shared_hint(head, max(len(chain) - 1, 0))
        deficit = (self.scheduler.reserved_blocks
                   + self.scheduler.block_bound(head)
                   + self.scheduler.extra_reserved - self.pool_blocks)
        if deficit <= 0:
            return
        shield = frozenset(nd.block_id for nd in chain) | frozenset(
            b for ids in self._slot_shared.values() for b in ids)
        evicted = self.prefix.evict(deficit, shield)
        if evicted:
            self.table = PC.evict_blocks(self.table, evicted)
            self.scheduler.extra_reserved -= len(evicted)

    def _index_insert(self, req: Request, job: _PrefillJob, caps: list):
        """Harvest the finished admission into the prefix index: the slot's
        completed prompt blocks (aliased prefix + freshly packed) keyed by
        the prompt's tokens, with the fp K/V straight off the prefill
        scratch.  Existing nodes win ties (their block already holds the
        identical planes — quantization is deterministic), so only
        genuinely new nodes take an index reference."""
        G = self.cfg.group_size
        nb = max(0, (req.prompt_len - G) // G)
        if nb == 0:
            return
        # lint: ok(host-sync, prefix-index insertion needs host block ids; once per finished prefill and counted in cache_syncs)
        ids = jax.device_get(self.table.block_table[job.slot, :nb])
        fp = _group_fp(caps, nb, G)
        self.cache_syncs += 1
        created = self.prefix.insert(req.prompt, [int(b) for b in ids], fp)
        new_ids = [nd.block_id for nd in created]
        if new_ids:
            self.table = PC.retain_blocks(self.table, new_ids)
            self.scheduler.extra_reserved += len(new_ids)
        # every indexed block of this prompt is now readable via the slot's
        # table row — shield the lot until the request retires
        self._slot_shared[job.slot] = [int(b) for b in ids]

    def _advance_prefill(self, key):
        """Advance the in-flight admission by at most ONE chunk (starting a
        new job if none is in flight) — the decode-interleaving contract.

        Chunk dispatches are fully asynchronous: no `block_until_ready`
        between chunks, and under the megastep driver even the finalize's
        first-token sample stays on device (``req.prefill_s`` therefore
        measures dispatch time, not device occupancy)."""
        if self._prefilling is None:
            # admission-time lifecycle guard: a queued head whose deadline
            # lapsed (or that was cancelled) since the last sweep must
            # never consume a slot — retire it `timed_out` un-admitted
            now = time.perf_counter()
            while self.scheduler.pending:
                head = self.scheduler.pending[0]
                if head.cancel_requested:
                    self._drop_pending(head, "cancelled",
                                       "cancelled before completion")
                elif head.deadline_exceeded(now):
                    self._drop_pending(head, "timed_out",
                                       "deadline exceeded while queued")
                else:
                    break
            if (self.prefix is not None and self.scheduler.pending
                    and self.scheduler.free_slots):
                self._prepare_admission(self.scheduler.pending[0])
            req = self.scheduler.next_admission()
            if req is None:
                return key
            if req.resume:
                # host-tier swap-in: no prefill — with a prefetch hit the
                # planes are already on device and the resume jit simply
                # joins the carried state; the slot decodes in the very
                # next megastep where it left off
                if self._do_resume(req) != "restart":
                    return key
                # snapshot lost: _restart_from_scratch queued a prefill
                # job for this slot — fall through and advance its chunk
            else:
                self._log("admit", req=req.req_id)
                self._prefilling = self._start_prefill(req)
        job = self._prefilling
        req = job.req
        t0 = time.perf_counter()
        C = self.prefill_chunk
        start = job.cut + job.chunk * C
        valid = min(req.prompt_len - start, C)
        tok = np.zeros((1, C), np.int32)
        tok[0, :valid] = req.prompt[start:start + valid]
        state = self._inject_scratch(self.state, job.scratch)
        logits, state, self.table = self._chunk_jit(
            self.params, jnp.asarray(tok), state, self.table,
            jnp.asarray(job.slot, jnp.int32), jnp.asarray(valid, jnp.int32))
        self.state, job.scratch = self._extract_scratch(state)
        job.chunk += 1
        req.prefill_pos = min(start + C, req.prompt_len)
        req.prefill_chunks = job.chunk

        if job.chunk == job.n_chunks:
            state = self._inject_scratch(self.state, job.scratch)
            key, k0 = jax.random.split(key)
            state, self.table, self.last, self.slots_dev = \
                self._finalize_jit(state, self.table, self.last,
                                   self.slots_dev,
                                   jnp.asarray(job.slot, jnp.int32), logits,
                                   k0, jnp.asarray(req.max_new_tokens,
                                                   jnp.int32))
            self.state, caps = self._extract_scratch(state)  # scratch freed
            if self.prefix is not None:
                self._index_insert(req, job, caps)
            self._prefilling = None
            req.prefill_s += time.perf_counter() - t0
            if req.max_new_tokens <= 0:
                # nothing to generate: match the static engine's [:, :0]
                self._retire(job.slot)
            elif self._use_megastep:
                # the first token stays on device; it reaches the host (and
                # req.tokens) with the next megastep's packed readback
                req.pending_first = True
            else:
                # lint: ok(host-sync, legacy-path first-token readback at admission; the megastep path defers it to the packed harvest)
                first = int(np.asarray(self.last[job.slot, 0]))
                self.host_syncs += 1
                req.tokens.append(first)
                self._log("tokens", req=req.req_id, toks=[first])
                if req.generated >= req.max_new_tokens:
                    self._retire(job.slot)
        else:
            req.prefill_s += time.perf_counter() - t0
        return key

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, priority: int = 0,
               deadline_s: Optional[float] = None) -> Request:
        """Submit a request; never raises mid-service (unless
        ``strict=True``) — impossible requests come back with
        ``status="rejected"`` and a reason so one bad request can't crash
        a serve loop."""
        prompt = np.asarray(prompt, np.int32)
        total = prompt.shape[0] + max_new_tokens
        if total > self.max_seq:
            reason = (
                f"prompt+generation = {total} tokens exceeds the engine's "
                f"max_seq {self.max_seq} (block tables hold "
                f"{self.nbmax} blocks/request)")
            if self.strict:
                raise ValueError(reason)
            req = Request(req_id=-1, prompt=prompt,
                          max_new_tokens=max_new_tokens, priority=priority,
                          deadline_s=deadline_s,
                          submit_t=time.perf_counter())
            return req.finish("rejected", reason)
        req = self.scheduler.submit(prompt, max_new_tokens,
                                    priority=priority, deadline_s=deadline_s)
        if req.status == "queued":
            # the WAL submit record carries the full prompt: the journal
            # alone must suffice to replay the request after a crash
            self._log("submit", req=req.req_id,
                      prompt=[int(t) for t in prompt],
                      max_new=max_new_tokens, priority=priority,
                      deadline_s=deadline_s)
        return req

    def _retire(self, slot: int, status: str = "ok", reason: str = ""):
        # jitted release: blocks return to the free stack on device, no
        # host sync on the (possibly still in-flight) table; blocks the
        # prefix index still references keep refcount >= 1 and stay put
        self.table = self._release(self.table, jnp.asarray(slot, jnp.int32))
        self._slot_shared.pop(slot, None)
        self.set_mangle(slot, 0)    # never leak corruption to the next
        req = self.scheduler.retire(slot, status, reason)  # slot occupant
        self._log("finish", req=req.req_id, status=status, reason=reason)
        self._retired.append(req)

    # ---- request lifecycle -------------------------------------------
    def _head_blocked(self) -> bool:
        """Queue head exists but can't be admitted, even after LRU prefix
        eviction (the overflow policies' trigger)."""
        if not self.scheduler.head_blocked():
            return False
        if self.prefix is not None and self.scheduler.free_slots \
                and not self._prefilling:
            self._prepare_admission(self.scheduler.pending[0])
        return self.scheduler.head_blocked()

    def _needs_lifecycle(self, blocked: bool) -> bool:
        """Cheap host-only probe deciding whether to drain the megastep
        pipeline for a lifecycle sweep this iteration — draining costs the
        readback overlap, so the steady state (no faults, no cancels, head
        admissible or merely waiting) never pays it."""
        if self.fault is not None \
                and getattr(self.fault, "needs_drain", True):
            return True
        now = time.perf_counter()
        if any(r.cancel_requested or r.deadline_exceeded(now)
               for r in self.scheduler.pending) or \
           any(r.cancel_requested or r.deadline_exceeded(now)
               for r in self.scheduler.active.values()):
            return True
        if blocked:
            if self.overflow == "reject":
                return True
            if self.overflow == "preempt" \
                    and self._stall >= self.preempt_patience:
                return True
            # watchdog (any mode): nothing running or prefilling can ever
            # free capacity for the blocked head
            if not self.scheduler.active and self._prefilling is None:
                return True
        return False

    def _drop_pending(self, req: Request, status: str, reason: str = ""):
        self.scheduler.drop_pending(req, status, reason)
        if self.host_tier is not None:
            self.host_tier.discard(req.req_id)
        self._prefetched.pop(req.req_id, None)
        self._log("finish", req=req.req_id, status=status, reason=reason)
        self._retired.append(req)

    def _lifecycle(self):
        """Request-lifecycle sweep, run only at a megastep harvest boundary
        with an empty pipeline (bookkeeping current, state mutable):
        fault-injection tick, cancellations, wall-clock deadlines, the
        overflow policy (preempt to host tier / reject), and the
        permanently-unadmittable-head watchdog."""
        if self.fault is not None and hasattr(self.fault, "tick"):
            self.fault.tick(self)
        now = time.perf_counter()
        for req in [r for r in self.scheduler.pending
                    if r.cancel_requested or r.deadline_exceeded(now)]:
            if req.cancel_requested:
                self._drop_pending(req, "cancelled",
                                   "cancelled before completion")
            else:
                self._drop_pending(req, "timed_out", "deadline exceeded")
        busy = self._prefilling.slot if self._prefilling else None
        for slot, req in list(self.scheduler.active.items()):
            if not (req.cancel_requested or req.deadline_exceeded(now)):
                continue
            if slot == busy:
                self._prefilling = None   # abandon the half-done admission
                busy = None
            if req.cancel_requested:
                self._retire(slot, "cancelled", "cancelled before completion")
            else:
                self._retire(slot, "timed_out", "deadline exceeded")
        if not self._head_blocked():
            self._stall = 0
            return
        if self.overflow == "reject":
            # admission-time rejection baseline: no queueing past capacity
            self._drop_pending(self.scheduler.pending[0], "rejected",
                               "pool full")
            self._stall = 0
            return
        if self.overflow == "preempt" and self._stall >= self.preempt_patience:
            victim = self.scheduler.preemption_victim(
                exclude=() if busy is None else (busy,))
            if victim is not None:
                self._do_preempt(victim)
                self._stall = 0
                # dispatch the victim's restore immediately: its async host
                # copy is still draining, and when the scheduler re-admits
                # it (often the very next iteration) the planes are already
                # device-resident — the resume jit is all that's left on
                # the admission hot path
                self._maybe_prefetch()
                return
        if not self.scheduler.active and self._prefilling is None \
                and self._head_blocked():
            # watchdog: the head's reservation can never fit (pool fully
            # drained, prefix eviction exhausted) — fail it, keep serving
            self._drop_pending(self.scheduler.pending[0], "failed",
                               "reservation exceeds pool")
            self._stall = 0

    def _tick_stall(self) -> bool:
        blocked = self.scheduler.head_blocked()
        self._stall = self._stall + 1 if blocked else 0
        return blocked

    # ------------------------------------------------------------------
    def step(self, key):
        """One engine iteration: ≤1 prefill chunk, one megastep
        (``rounds_per_step`` fused spec rounds) over the decoding slots,
        harvest, retire.  `step` is the synchronous entry point — it drains
        any pipelined megastep first and harvests its own before returning,
        so request state is current when it hands back; `run` overlaps
        readback with the next megastep instead."""
        with _mesh_scope(self.mesh):
            if not self._use_megastep:
                if self._needs_lifecycle(self._tick_stall()):
                    self._lifecycle()
                elif self.fault is not None \
                        and hasattr(self.fault, "tick"):
                    self.fault.tick(self)
                return self._step_legacy(key)
            if self._inflight is not None:
                self._harvest(self._inflight)
                self._inflight = None
            if self._needs_lifecycle(self._tick_stall()):
                self._lifecycle()
            elif self.fault is not None and hasattr(self.fault, "tick"):
                self.fault.tick(self)
            key = self._dispatch(key)
            if self._inflight is not None:
                self._harvest(self._inflight)
                self._inflight = None
            return key

    def _step_legacy(self, key):
        """One spec round (or AR step) per dispatch, harvested immediately —
        two blocking readbacks per round.  The gamma=0 AR path and the
        ``rounds_per_step=0`` benchmark baseline."""
        key = self._advance_prefill(key)
        busy = self._prefilling.slot if self._prefilling else None
        decoding = {s: r for s, r in self.scheduler.active.items()
                    if s != busy}
        if not decoding:
            return key
        key, kr = jax.random.split(key)
        if self.gamma > 0:
            res = self._round(self.params, self.draft_params, self.state,
                              self.table, self.last, kr)
            self.state, self.table, self.last = (res.state, res.table,
                                                 res.last_token)
            # lint: ok(host-sync, legacy per-round continuous path; two counted readbacks per round by design)
            n_new = np.asarray(res.n_new)
            # lint: ok(host-sync, legacy per-round continuous path readback)
            toks = np.asarray(res.tokens)
            # lint: ok(host-sync, legacy per-round continuous path readback)
            nonfinite = np.asarray(res.nonfinite)
            self.host_syncs += 2
        else:
            self.state, self.table, self.last, _ar_nf = self._ar(
                self.params, self.state, self.table, self.last, kr)
            n_new = np.ones((self.max_slots,), np.int64)
            # lint: ok(host-sync, AR continuous path reads one token per step back; counted in host_syncs)
            toks = np.asarray(self.last)
            nonfinite = None
            self.host_syncs += 1
        self.decode_steps += 1

        for slot, req in list(decoding.items()):
            # clamp the stats by the request's remaining budget: when it
            # hits max_new_tokens mid-round the discarded tail beyond
            # `take` neither proposed usefully nor counts as accepted
            # (uncapped, per-request acceptance rates inflate)
            take, proposed, accepted = round_stats(
                self.gamma, int(n_new[slot]),
                req.max_new_tokens - req.generated)
            delta = [int(t) for t in toks[slot, :take]]
            req.tokens.extend(delta)
            if delta:
                self._log("tokens", req=req.req_id, toks=delta)
            req.rounds += 1
            req.megasteps += 1
            req.proposed += proposed
            req.accepted += accepted
            if nonfinite is not None:
                req.numerics_flags += int(nonfinite[slot])
            if req.generated >= req.max_new_tokens:
                self._retire(slot)
        self._harvests += 1
        if self.journal is not None and self.checkpoint_every \
                and self._harvests % self.checkpoint_every == 0:
            self._checkpoint()
        self._maybe_prefetch()
        return key

    # ---- megastep driver ---------------------------------------------
    def _dispatch(self, key):
        """≤1 prefill chunk, then enqueue one megastep over the decoding
        slots (recording the slot→request mapping for its later harvest).
        Nothing here blocks: the megastep runs on carried device state, and
        slots whose requests finished in the still-unharvested previous
        megastep are already frozen by the device-side done mask."""
        key = self._advance_prefill(key)
        busy = self._prefilling.slot if self._prefilling else None
        decoding = {s: r for s, r in self.scheduler.active.items()
                    if s != busy}
        if not decoding:
            self._maybe_prefetch()
            return key
        key, kmega = jax.random.split(key)
        res = self._mega(self.params, self.draft_params, self.state,
                         self.table, self.last, self.slots_dev, kmega,
                         self._mangle_dev)
        self.state, self.table = res.state, res.table
        self.last, self.slots_dev = res.last_token, res.slots
        self.decode_steps += 1
        self._inflight = _InflightMega(
            packed=(res.tokens, res.take, res.proposed, res.accepted,
                    res.nonfinite, res.rung, res.first, res.done),
            reqs=decoding,
            emit_first=[s for s, r in decoding.items() if r.pending_first])
        # with the megastep enqueued, the device is busy for a while —
        # speculatively restore the resumable queue front behind it
        self._maybe_prefetch()
        return key

    def _harvest(self, flight: _InflightMega):
        """The single blocking device→host transfer per megastep: packed
        per-round tokens/takes/stats plus the tiny first-token and done
        vectors.  All request bookkeeping happens on the host copies.
        Requests that went terminal between dispatch and harvest
        (cancelled, timed out, preempted away) are guarded by ``req.done``
        / a stale slot mapping — their speculative tokens are discarded."""
        toks, take, proposed, accepted, nonfinite, rung, first, done = \
            jax.device_get(flight.packed)  # lint: ok(host-sync, the one budgeted readback per continuous megastep; overlapped with the in-flight dispatch by the double-buffered driver)
        self.host_syncs += 1
        pre = ({r.req_id: len(r.tokens) for r in flight.reqs.values()}
               if self.journal is not None else None)
        for slot in flight.emit_first:
            req = flight.reqs[slot]
            if req.pending_first:     # not already emitted by an earlier
                req.tokens.append(int(first[slot]))   # overlapping harvest
                req.pending_first = False
        for k in range(take.shape[0]):
            for slot, req in flight.reqs.items():
                t = int(take[k, slot])
                if t <= 0 or req.done:
                    continue
                req.tokens.extend(int(x) for x in toks[k, slot, :t])
                req.rounds += 1
                prop = int(proposed[k, slot])
                req.proposed += prop
                req.accepted += int(accepted[k, slot])
                req.numerics_flags += int(nonfinite[k, slot])
                # host mirror of the device acceptance window + ladder
                # bookkeeping (preemption victim ranking and telemetry);
                # AR-floor rounds propose nothing and leave the window be
                req.observe_acceptance(prop, int(accepted[k, slot]))
                r = int(rung[k, slot])
                if r > req.rung:
                    req.demotions += 1
                elif r < req.rung:
                    req.promotions += 1
                req.rung = r
                if r == RUNG_AR:
                    req.ar_rounds += 1
                elif r == RUNG_INT8:
                    req.int8_rounds += 1
        if pre is not None:
            # WAL the harvested token deltas *before* any retire below
            # writes its finish record — replay folds them in order
            for req in flight.reqs.values():
                delta = req.tokens[pre[req.req_id]:]
                if delta:
                    self._log("tokens", req=req.req_id, toks=delta)
        for slot, req in flight.reqs.items():
            if not req.done:
                req.megasteps += 1
            if not req.done and bool(done[slot]):
                self._retire(slot)
        self._harvests += 1
        if self.journal is not None and self.checkpoint_every \
                and self._harvests % self.checkpoint_every == 0:
            self._checkpoint()

    def run(self, key=None) -> List[Request]:
        """Drive until every submitted request has finished; returns, in
        submission order, every request retired since the last `run` (so
        requests that finished in manual `step` calls are included).

        Under the megastep driver this is the double-buffered loop:
        megastep ``i+1`` is dispatched on the carried device state *before*
        megastep ``i`` is harvested, so the one `device_get` per megastep
        overlaps the next megastep's compute and the scheduler re-enters
        only between megasteps (admission chunks, retirement)."""
        if key is None:
            key = jax.random.PRNGKey(0)
        if not self._use_megastep:
            while self.scheduler.has_work:
                key = self.step(key)
        else:
            with _mesh_scope(self.mesh):
                while self.scheduler.has_work or self._inflight is not None:
                    prev, self._inflight = self._inflight, None
                    if self._needs_lifecycle(self._tick_stall()):
                        # drain the pipeline so request bookkeeping is
                        # current, then sweep cancels/deadlines/overflow —
                        # the steady state never takes this branch and
                        # keeps the dispatch-before-harvest overlap
                        if prev is not None:
                            self._harvest(prev)
                            prev = None
                        self._lifecycle()
                    elif self.fault is not None \
                            and hasattr(self.fault, "tick"):
                        # drain-free fault schedules (draft mangling only)
                        # still tick every iteration — arming a slot's
                        # corruption switch touches nothing the in-flight
                        # megastep reads, so the overlap survives
                        self.fault.tick(self)
                    key = self._dispatch(key)
                    if prev is not None:
                        self._harvest(prev)
        done, self._retired = self._retired, []
        return sorted(done, key=lambda r: r.req_id)

    # ---- crash recovery ----------------------------------------------
    def recover(self) -> List[Request]:
        """Rebuild the queue after a crash from the write-ahead journal
        (serving/journal.py): every non-terminal request is re-queued
        under its original id — bit-exact *resumable* when a checkpoint
        persisted its snapshot to the disk tier and the record verifies
        against the journaled stream, *replayed from its prompt* otherwise
        (greedy decoding is deterministic, so the replayed tokens are
        identical either way).  Call on a fresh engine constructed with
        the crashed run's ``journal_dir``, then `run()` to completion."""
        if self.journal is None:
            raise ValueError("recover() requires an engine constructed "
                             "with journal_dir")
        events, truncated = J.read_events(self.journal.root)
        # a torn tail is detected (and excised) when the Journal reopens
        # the log, before this read — surface it from there too
        truncated = truncated or self.journal.dropped_tail
        if truncated:
            self._log("torn_tail", dropped=truncated)
        recs = J.replay(events)
        recovered: List[Request] = []
        for rec in recs.values():          # dict order == submit order
            if rec.done:
                continue
            req = Request(req_id=rec.req_id,
                          prompt=np.asarray(rec.prompt, np.int32),
                          max_new_tokens=rec.max_new_tokens,
                          priority=rec.priority, deadline_s=rec.deadline_s,
                          submit_t=time.perf_counter())
            mode = "replay"
            if rec.swapped_out and self._recoverable(rec):
                req.resume = True
                req.tokens = [int(t) for t in rec.tokens]
                req.preemptions = 1
                mode = "resume"
            elif self.host_tier is not None:
                # a stale/failed snapshot must not shadow the replay
                self.host_tier.discard(rec.req_id)
            self.scheduler.pending.append(req)
            self._log("recover", req=req.req_id, mode=mode)
            recovered.append(req)
        if recs:
            self.scheduler._next_id = max(self.scheduler._next_id,
                                          max(recs) + 1)
        return recovered

    def _recoverable(self, rec: "J.RequestRecord") -> bool:
        """Adopt a persisted disk snapshot only when it fully verifies
        (every plane CRC — a full read, recovery is off the hot path) AND
        its stream position matches the journaled token count; anything
        less falls back to replay-from-prompt, which always completes."""
        if self.host_tier is None or self.host_tier.disk is None:
            return False
        disk = self.host_tier.disk
        if rec.req_id not in disk:
            return False
        try:
            snap = disk.load(rec.req_id, pop=False)
        except HostTierError:
            return False       # corrupt record; the load discarded it
        # invariant: pos counts committed KV positions = prompt + generated
        # minus the carried last token (its KV lands with the next round)
        if snap.pos != len(rec.prompt) + len(rec.tokens) - 1:
            disk.discard(rec.req_id)
            return False
        return True

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int,
                 key=None) -> List[GenerationResult]:
        """Convenience API mirroring `Engine.generate` for ragged prompts."""
        reqs = [self.submit(p, max_new_tokens) for p in prompts]
        self.run(key)
        out = []
        for r in reqs:
            stats = GenStats(proposed=r.proposed, accepted=r.accepted,
                             rounds=r.rounds, generated=r.generated,
                             prefill_s=r.prefill_s,
                             decode_s=max(r.finish_t - r.admit_t
                                          - r.prefill_s, 0.0),
                             numerics_flags=r.numerics_flags,
                             offloads=r.offloads, restores=r.restores,
                             swap_bytes=r.swap_bytes,
                             prefetch_hits=r.prefetch_hits,
                             prefetch_misses=r.prefetch_misses,
                             resume_block_s=r.resume_block_s,
                             restarts=r.restarts,
                             demotions=r.demotions, promotions=r.promotions,
                             int8_rounds=r.int8_rounds,
                             ar_rounds=r.ar_rounds, final_rung=r.rung)
            out.append(GenerationResult(
                tokens=np.asarray(r.tokens, np.int64)[None, :], stats=stats))
        return out


def make_engine(model, params, policy: str, **kw) -> Engine:
    defaults = {"quantspec": dict(gamma=4),
                "fp": dict(gamma=0),
                "streaming": dict(gamma=1, quantize_weights=False),
                "snapkv": dict(gamma=1, quantize_weights=False)}[policy]
    defaults.update(kw)
    return Engine(model, params, policy=policy, **defaults)
