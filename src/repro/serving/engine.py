"""Serving engines: static-batch and continuous-batching request generation
with QuantSpec, autoregressive FP, and sparse-KV self-speculative baselines
(StreamingLLM / SnapKV).

`Engine` (static batch) jits one `spec_round` (draft γ → verify → commit)
over a fixed ``[B, S]`` prompt batch and drives it in a Python loop;
prefill is jitted separately per prompt length.

`ContinuousEngine` serves ragged multi-request traffic over the **paged**
hierarchical cache (core/paged_kv_cache.py): requests are admitted into
slots and retired between spec rounds, each slot progresses at its own
stream position with per-sequence accept/rollback, and KV blocks come from
a shared pool. Admission prefills through the existing dense batch-1 path
and adopts the result into pool blocks (`adopt_hier`).

Policies (static engine)
------------------------
quantspec : hierarchical INT4/INT8 shared cache, INT4 draft weights (paper)
fp        : plain FP cache, no speculation (AR baseline)
streaming : FP target cache + StreamingLLM sink+window draft cache
snapkv    : FP target cache + SnapKV prefill-selected draft cache

For the baselines the draft weights stay full precision (matching the
MagicDec-style sparse-KV baselines of the paper, whose draft cost savings
come from the sparse cache only). The continuous engine always runs the
paged quantspec cache; set ``gamma=0`` for its AR baseline.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paged_kv_cache as PC
from repro.core.spec_decode import (ar_step, paged_ar_step, paged_spec_round,
                                    spec_round)
from repro.core.weight_quant import quantize_tree
from repro.models.stack import AttnState, StackModel
from repro.serving.sampling import sample_token
from repro.serving.scheduler import Request, Scheduler


@dataclasses.dataclass
class GenStats:
    proposed: int = 0
    accepted: int = 0
    rounds: int = 0
    generated: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.proposed, 1)

    @property
    def tokens_per_round(self) -> float:
        return self.generated / max(self.rounds, 1)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # [B, n_generated(, K)]
    stats: GenStats


class Engine:
    def __init__(self, model: StackModel, params, *, policy: str = "quantspec",
                 gamma: int = 4, greedy: bool = False,
                 temperature: float = 1.0,
                 quantize_weights: Optional[bool] = None,
                 max_seq: int = 4096, ctx_kw: Optional[dict] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.policy = policy
        self.gamma = gamma
        self.greedy = greedy
        self.temperature = temperature
        self.ctx_kw = ctx_kw or {}
        self.max_seq = max_seq
        if policy == "quantspec" and gamma + 1 > self.cfg.group_size:
            # one verify pass appends gamma+1 tokens; maybe_flush frees at
            # most G buffer slots, so the append must fit one group
            raise ValueError(f"gamma+1 = {gamma + 1} exceeds the quant "
                             f"group size {self.cfg.group_size}")
        if quantize_weights is None:
            quantize_weights = policy == "quantspec"
        self.draft_params = (quantize_tree(
            params, group=self.cfg.weight_quant_group)
            if quantize_weights else params)

        self._round = jax.jit(
            partial(spec_round, model, gamma=gamma, policy=policy,
                    greedy=greedy, temperature=temperature,
                    ctx_kw=self.ctx_kw),
            static_argnames=())
        self._ar = jax.jit(
            partial(ar_step, model, policy=policy, greedy=greedy,
                    temperature=temperature,
                    kv_mode="target" if policy == "quantspec" else "fp",
                    ctx_kw=self.ctx_kw))
        self._prefill_jit = jax.jit(self._prefill,
                                    static_argnames=("batch",))

    # ------------------------------------------------------------------
    def _prefill(self, prompt, memory, batch):
        state = self.model.init_serve_state(
            batch, max_seq=self.max_seq, policy=self.policy,
            ctx_kw=self.ctx_kw)
        logits, state = self.model.prefill(
            self.params, prompt, state, policy=self.policy, memory=memory,
            ctx_kw=self.ctx_kw)
        return logits, state

    def generate(self, prompt: jnp.ndarray, max_new_tokens: int,
                 key=None, memory=None, speculative: Optional[bool] = None
                 ) -> GenerationResult:
        """prompt [B, S] (or [B, S, K] for codebooks)."""
        if key is None:
            key = jax.random.PRNGKey(0)
        if speculative is None:
            speculative = self.policy != "fp"
        B = prompt.shape[0]
        stats = GenStats()

        t0 = time.perf_counter()
        logits, state = jax.block_until_ready(
            self._prefill_jit(prompt, memory, batch=B))
        stats.prefill_s = time.perf_counter() - t0

        key, k0 = jax.random.split(key)
        last = sample_token(logits[:, -1] / self.temperature, k0, self.greedy)
        last = last[:, None]
        out = [np.asarray(last)]
        stream_pos = prompt.shape[1]
        generated = 1

        t1 = time.perf_counter()
        while generated < max_new_tokens:
            key, kr = jax.random.split(key)
            if speculative:
                res = self._round(self.params, self.draft_params, state,
                                  last, stream_pos, kr)
                state, last = res.state, res.last_token
                n_new = int(res.n_new)
                toks = np.asarray(res.tokens)[:, :n_new]
                stats.rounds += 1
                stats.proposed += self.gamma
                stats.accepted += n_new - 1  # lockstep-committed drafts
                stream_pos += n_new
            else:
                state, last = self._ar(self.params, state, last,
                                       stream_pos, kr)
                toks = np.asarray(last)
                n_new = 1
                stream_pos += 1
                stats.rounds += 1
            out.append(toks)
            generated += n_new
        jax.block_until_ready(last)
        stats.decode_s = time.perf_counter() - t1
        stats.generated = generated

        tokens = np.concatenate(out, axis=1)[:, :max_new_tokens]
        return GenerationResult(tokens=tokens, stats=stats)


class ContinuousEngine:
    """Continuous-batching engine over the paged hierarchical cache.

    ``max_slots`` requests decode concurrently; waiting requests are
    admitted the moment a slot frees *and* the block pool can hold their
    worst-case footprint. One jitted `paged_spec_round` serves every round
    regardless of which requests occupy which slots (shapes are static in
    [slots, pool]); admission/retirement mutate only the page table.

    Greedy decoding is schedule-invariant: each request's output tokens are
    identical to a batch-1 run of the static engine on the same prompt
    (verified in tests/test_paged_engine.py and benchmarks/paged_serving.py).
    """

    def __init__(self, model: StackModel, params, *, gamma: int = 4,
                 greedy: bool = False, temperature: float = 1.0,
                 quantize_weights: bool = True, max_slots: int = 4,
                 max_seq: int = 4096, pool_blocks: Optional[int] = None,
                 ctx_kw: Optional[dict] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.gamma = gamma
        self.greedy = greedy
        self.temperature = temperature
        self.max_slots = max_slots
        self.max_seq = max_seq
        G = self.cfg.group_size
        if gamma + 1 > G:
            # plan_step flushes at most one block per step, so a verify
            # append of gamma+1 tokens must fit one group
            raise ValueError(f"gamma+1 = {gamma + 1} exceeds the quant "
                             f"group size {G}; the FP buffer would overflow")
        self.nbmax = max(1, -(-max_seq // G))
        self.pool_blocks = pool_blocks or max_slots * self.nbmax
        self.ctx_kw = ctx_kw or {}
        self.draft_params = (quantize_tree(
            params, group=self.cfg.weight_quant_group)
            if quantize_weights else params)

        self.state = model.init_serve_state(
            max_slots, max_seq=max_seq, policy="paged",
            ctx_kw={**self.ctx_kw, "pool_blocks": self.pool_blocks})
        self.table = PC.init_table(max_slots, self.nbmax, self.pool_blocks)
        self.last = jnp.zeros((max_slots, 1), jnp.int32)
        self.scheduler = Scheduler(max_slots, self.pool_blocks, G)
        self._retired: List[Request] = []   # finished, not yet run()-claimed

        self._round = jax.jit(partial(
            paged_spec_round, model, gamma=gamma, greedy=greedy,
            temperature=temperature, ctx_kw=self.ctx_kw or None))
        self._ar = jax.jit(partial(
            paged_ar_step, model, greedy=greedy, temperature=temperature,
            ctx_kw=self.ctx_kw or None))
        self._prefill_jit = jax.jit(self._dense_prefill)

    # ------------------------------------------------------------------
    def _dense_prefill(self, prompt):
        """Batch-1 prefill through the existing dense quantspec path."""
        state = self.model.init_serve_state(
            1, max_seq=self.max_seq, policy="quantspec", ctx_kw=self.ctx_kw)
        logits, state = self.model.prefill(
            self.params, prompt, state, policy="quantspec",
            ctx_kw=self.ctx_kw)
        return logits, state

    # ------------------------------------------------------------------
    @staticmethod
    def _walk_attn(pst, dst, fn):
        """Apply ``fn(paged_mixer, dense_mixer, stacked)`` over every layer
        of (paged state, dense prefill state) in parallel, returning the
        updated paged state."""
        new = {"head": [], "tail": [], "blocks": None}
        for k in ("head", "tail"):
            for (pm, pl), (dm, _) in zip(pst[k], dst[k]):
                new[k].append((fn(pm, dm, False), pl))
        new["blocks"] = tuple(
            (fn(pm, dm, True), pl)
            for (pm, pl), (dm, _) in zip(pst["blocks"], dst["blocks"]))
        return new

    def _first_attn_cache(self, dense_state):
        for k in ("head", "tail"):
            for mix, _ in dense_state[k]:
                if isinstance(mix, AttnState):
                    return mix.primary, False
        for mix, _ in dense_state["blocks"]:
            if isinstance(mix, AttnState):
                return mix.primary, True
        raise ValueError("no attention layer in state")

    def _adopt(self, slot: int, dense_state, prompt_len: int):
        """Move a dense batch-1 prefill into pool blocks + slot buffers."""
        hier, stacked = self._first_attn_cache(dense_state)
        n = int(hier.blocks[0] if stacked else hier.blocks)
        buf_len = int(hier.buf_len[0] if stacked else hier.buf_len)
        self.table, ids = PC.alloc_blocks(self.table, slot, n)

        def adopt_mixer(pm, dm, layer_stacked):
            if not isinstance(pm, AttnState):
                return pm
            if layer_stacked:
                pool = jax.vmap(
                    lambda p, h: PC.adopt_hier(p, slot, ids, h))(
                        pm.primary, dm.primary)
            else:
                pool = PC.adopt_hier(pm.primary, slot, ids, dm.primary)
            return AttnState(pool, None)

        self.state = self._walk_attn(self.state, dense_state, adopt_mixer)
        self.table = PC.admit_slot(self.table, slot, prompt_len, buf_len)

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> Request:
        prompt = np.asarray(prompt, np.int32)
        total = prompt.shape[0] + max_new_tokens
        if total > self.max_seq:
            raise ValueError(
                f"prompt+generation = {total} tokens exceeds the engine's "
                f"max_seq {self.max_seq} (block tables hold "
                f"{self.nbmax} blocks/request)")
        return self.scheduler.submit(prompt, max_new_tokens)

    def _admit_ready(self, key):
        while True:
            req = self.scheduler.next_admission()
            if req is None:
                return key
            t0 = time.perf_counter()
            logits, dense = jax.block_until_ready(
                self._prefill_jit(jnp.asarray(req.prompt)[None]))
            key, k0 = jax.random.split(key)
            first = sample_token(logits[:, -1] / self.temperature, k0,
                                 self.greedy)
            self._adopt(req.slot, dense, req.prompt_len)
            self.last = self.last.at[req.slot, 0].set(first[0])
            if req.max_new_tokens > 0:   # match the static engine's [:, :0]
                req.tokens.append(int(first[0]))
            req.prefill_s = time.perf_counter() - t0
            req.admit_t = t0
            if req.generated >= req.max_new_tokens:
                self._retire(req.slot)

    def _retire(self, slot: int):
        self.table = PC.free_slot(self.table, slot)
        req = self.scheduler.retire(slot)
        req.finish_t = time.perf_counter()
        self._retired.append(req)

    # ------------------------------------------------------------------
    def step(self, key):
        """One engine iteration: admit, one spec round, harvest, retire."""
        key = self._admit_ready(key)
        if not self.scheduler.active:
            return key
        key, kr = jax.random.split(key)
        if self.gamma > 0:
            res = self._round(self.params, self.draft_params, self.state,
                              self.table, self.last, kr)
            self.state, self.table, self.last = (res.state, res.table,
                                                 res.last_token)
            n_new = np.asarray(res.n_new)
            toks = np.asarray(res.tokens)
        else:
            self.state, self.table, self.last = self._ar(
                self.params, self.state, self.table, self.last, kr)
            n_new = np.ones((self.max_slots,), np.int64)
            toks = np.asarray(self.last)

        for slot, req in list(self.scheduler.active.items()):
            take = min(int(n_new[slot]),
                       req.max_new_tokens - req.generated)
            req.tokens.extend(int(t) for t in toks[slot, :take])
            req.rounds += 1
            req.proposed += self.gamma
            req.accepted += int(n_new[slot]) - 1
            if req.generated >= req.max_new_tokens:
                self._retire(slot)
        return key

    def run(self, key=None) -> List[Request]:
        """Drive until every submitted request has finished; returns, in
        submission order, every request retired since the last `run` (so
        requests that finished in manual `step` calls are included)."""
        if key is None:
            key = jax.random.PRNGKey(0)
        while self.scheduler.has_work:
            key = self.step(key)
        done, self._retired = self._retired, []
        return sorted(done, key=lambda r: r.req_id)

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int,
                 key=None) -> List[GenerationResult]:
        """Convenience API mirroring `Engine.generate` for ragged prompts."""
        reqs = [self.submit(p, max_new_tokens) for p in prompts]
        self.run(key)
        out = []
        for r in reqs:
            stats = GenStats(proposed=r.proposed, accepted=r.accepted,
                             rounds=r.rounds, generated=r.generated,
                             prefill_s=r.prefill_s,
                             decode_s=max(r.finish_t - r.admit_t
                                          - r.prefill_s, 0.0))
            out.append(GenerationResult(
                tokens=np.asarray(r.tokens, np.int64)[None, :], stats=stats))
        return out


def make_engine(model, params, policy: str, **kw) -> Engine:
    defaults = {"quantspec": dict(gamma=4),
                "fp": dict(gamma=0),
                "streaming": dict(gamma=1, quantize_weights=False),
                "snapkv": dict(gamma=1, quantize_weights=False)}[policy]
    defaults.update(kw)
    return Engine(model, params, policy=policy, **defaults)
