"""Continuous-batching scheduler: request queue + slot/block accounting.

Slot state is split host/device:

* The :class:`Scheduler` is pure host-side bookkeeping — it decides *which*
  request enters *which* slot and when a slot retires; all array work (the
  chunked prefill, the jitted megastep) stays in the engine. Separating the
  two keeps admission policy swappable (FCFS here) without touching jitted
  code.
* :class:`SlotState` is the **device-resident** half of a request's
  lifecycle: per-slot generated counts, token budgets, and the done mask
  (budget reached or EOS sampled). It rides through the fused decode
  megastep (`core.spec_decode.paged_megastep`) so accept/rollback, budget
  clamping, and termination masking all happen on the accelerator — the
  host only learns about finished requests at the next packed readback,
  and never has to sync mid-megastep.

Admission is capacity-safe: a request is only admitted when the block pool
can hold its **worst-case** footprint (every token of prompt + generation
quantized), so the free stack can never underflow mid-decode, no matter
how the ragged flush schedules interleave.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, NamedTuple, Optional

import numpy as np


class SlotState(NamedTuple):
    """Device-resident per-slot request state carried through the megastep.

    ``generated`` counts tokens the request has produced **including** the
    first token sampled from the prefill logits (which the host may not
    have seen yet — see ``Request.pending_first``); ``budget`` is the
    request's ``max_new_tokens``; ``done`` marks slots whose budget is
    exhausted or that sampled EOS — the megastep freezes them (page-table
    deactivation, zeroed takes) instead of syncing to the host."""

    generated: "np.ndarray"   # i32 [R]
    budget: "np.ndarray"      # i32 [R]
    done: "np.ndarray"        # bool [R]


def init_slot_state(num_slots: int):
    """All-idle :class:`SlotState` (jnp arrays; imported lazily so the
    scheduler module itself stays importable without jax)."""
    import jax.numpy as jnp

    return SlotState(generated=jnp.zeros((num_slots,), jnp.int32),
                     budget=jnp.zeros((num_slots,), jnp.int32),
                     done=jnp.zeros((num_slots,), bool))


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle state."""

    req_id: int
    prompt: np.ndarray                  # [S] i32
    max_new_tokens: int
    # -- runtime ------------------------------------------------------------
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    proposed: int = 0
    accepted: int = 0
    rounds: int = 0
    prefill_s: float = 0.0
    # chunked-admission progress (decode-interleaved prefill): prompt tokens
    # admitted so far, chunks executed, and the chunk-bucket the transient
    # fp scratch was sized to
    prefill_pos: int = 0
    prefill_chunks: int = 0
    prefill_bucket: int = 0
    # megastep driver: the first token was sampled *on device* at prefill
    # finalize and has not reached the host yet — it arrives with the next
    # megastep's packed readback (engine._harvest)
    pending_first: bool = False
    # prefix caching: pool blocks the engine expects to *alias* from the
    # prefix index instead of popping (set just before admission), and the
    # reservation actually charged at admission (released verbatim at
    # retirement, so a later hint change can never unbalance the pool
    # accounting)
    shared_hint: int = 0
    reserved: Optional[int] = None
    admit_t: float = 0.0
    finish_t: float = 0.0
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def generated(self) -> int:
        return len(self.tokens)


class Scheduler:
    """FCFS continuous-batching scheduler over ``num_slots`` request slots
    and a pool of ``pool_blocks`` KV blocks (block size ``group``)."""

    def __init__(self, num_slots: int, pool_blocks: int, group: int):
        self.num_slots = num_slots
        self.pool_blocks = pool_blocks
        self.group = group
        self.pending: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.free_slots = list(range(num_slots))
        self.reserved_blocks = 0
        # pool blocks held by the prefix index (refcount-retained, off the
        # free stack but owned by no request); the engine keeps this in sync
        # with insertions/evictions so admission stays capacity-safe:
        #   reserved_blocks + extra_reserved <= pool_blocks
        # (a block both indexed and aliased is counted once here and
        # *discounted* from its aliasing request via `shared_hint` —
        # conservative double-count never admits past the pool)
        self.extra_reserved = 0
        self._next_id = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> Request:
        req = Request(req_id=self._next_id, prompt=np.asarray(prompt),
                      max_new_tokens=max_new_tokens)
        bound = self.block_bound(req)
        if bound > self.pool_blocks:
            # would never be admissible — with FCFS it would livelock the
            # queue, so reject at submission time
            raise ValueError(
                f"request needs up to {bound} KV blocks but the pool has "
                f"{self.pool_blocks}; shorten the request or grow the pool")
        self._next_id += 1
        self.pending.append(req)
        return req

    def block_bound(self, req: Request) -> int:
        """Worst-case pool blocks the request can ever *newly* allocate:
        every token of prompt + generation quantized, minus the blocks the
        prefix index will alias into its row (``shared_hint`` — those are
        already charged under ``extra_reserved``, and aliasing never pops
        the free stack)."""
        total = req.prompt_len + req.max_new_tokens
        return max(-(-total // self.group) - req.shared_hint, 0)

    def set_shared_hint(self, req: Request, blocks: int) -> None:
        """Expected aliased (index-owned) blocks for ``req`` — set by the
        engine right before trying admission, from the current index match.
        Only meaningful for pending requests (admitted requests already
        froze their reservation in ``req.reserved``)."""
        req.shared_hint = int(blocks)

    def next_admission(self) -> Optional[Request]:
        """Pop the next admissible request, assigning it a slot, or None if
        the head of the queue doesn't fit yet (FCFS — no overtaking)."""
        if not self.pending or not self.free_slots:
            return None
        req = self.pending[0]
        bound = self.block_bound(req)
        if self.reserved_blocks + bound + self.extra_reserved \
                > self.pool_blocks:
            return None
        self.pending.popleft()
        req.slot = self.free_slots.pop(0)
        self.active[req.slot] = req
        req.reserved = bound
        self.reserved_blocks += bound
        return req

    def retire(self, slot: int) -> Request:
        req = self.active.pop(slot)
        req.done = True
        self.free_slots.append(slot)
        self.free_slots.sort()
        self.reserved_blocks -= (req.reserved if req.reserved is not None
                                 else self.block_bound(req))
        return req

    @property
    def has_work(self) -> bool:
        return bool(self.pending or self.active)
