"""Continuous-batching scheduler: request queue + slot/block accounting.

Slot state is split host/device:

* The :class:`Scheduler` is pure host-side bookkeeping — it decides *which*
  request enters *which* slot and when a slot retires; all array work (the
  chunked prefill, the jitted megastep) stays in the engine. Separating the
  two keeps admission policy swappable (FCFS here) without touching jitted
  code.
* :class:`SlotState` is the **device-resident** half of a request's
  lifecycle: per-slot generated counts, token budgets, and the done mask
  (budget reached or EOS sampled). It rides through the fused decode
  megastep (`core.spec_decode.paged_megastep`) so accept/rollback, budget
  clamping, and termination masking all happen on the accelerator — the
  host only learns about finished requests at the next packed readback,
  and never has to sync mid-megastep.

Admission is capacity-safe: a request is only admitted when the block pool
can hold its **worst-case** footprint (every token of prompt + generation
quantized), so the free stack can never underflow mid-decode, no matter
how the ragged flush schedules interleave.

Request lifecycle (PR 7): every request ends in exactly one terminal
status — ``ok | rejected | cancelled | failed | timed_out`` — instead of
exceptions escaping the serve loop.  ``submit`` rejects (bounded queue,
impossible reservations, oversized prompts) by *returning* the request
with ``status="rejected"`` and a reason; the legacy raise survives behind
``strict=True`` for tests.  Preemption support: when the engine runs in
``overflow="preempt"`` mode, :meth:`Scheduler.preempt` evicts a running
slot back to the queue *front* as a resumable request (its KV snapshot
lives in the host tier — core/host_tier.py) and
:meth:`preemption_victim` picks who goes: lowest priority first, then the
lowest rolling acceptance (a collapsed speculator yields the least
throughput per block held), then the youngest admission — never a slot
that hasn't decoded a megastep since it was (re)admitted; that guarantee
is what bounds preemption ping-pong to round-robin time-slicing with
forward progress.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, NamedTuple, Optional

import numpy as np

#: terminal request statuses (``Request.done`` is True iff one of these)
TERMINAL = ("ok", "rejected", "cancelled", "failed", "timed_out")


class SlotState(NamedTuple):
    """Device-resident per-slot request state carried through the megastep.

    ``generated`` counts tokens the request has produced **including** the
    first token sampled from the prefill logits (which the host may not
    have seen yet — see ``Request.pending_first``); ``budget`` is the
    request's ``max_new_tokens``; ``done`` marks slots whose budget is
    exhausted or that sampled EOS — the megastep freezes them (page-table
    deactivation, zeroed takes) instead of syncing to the host.

    The trailing four fields are the precision governor's per-slot state
    (core/spec_decode.py `GovernorConfig`): the degradation-ladder rung
    (0 = INT4 full γ, 1 = INT4 reduced γ, 2 = INT8 draft read, 3 = AR
    floor), the rolling acceptance window (proposed/accepted counters),
    and the probe-round countdown for rung-3 re-escalation. They ride the
    megastep carry so ladder transitions are pure on-device masking —
    never a recompile, never a host sync."""

    generated: "np.ndarray"   # i32 [R]
    budget: "np.ndarray"      # i32 [R]
    done: "np.ndarray"        # bool [R]
    rung: "np.ndarray"        # i32 [R] — degradation-ladder position
    win_prop: "np.ndarray"    # i32 [R] — rolling window: tokens proposed
    win_acc: "np.ndarray"     # i32 [R] — rolling window: tokens accepted
    probe: "np.ndarray"       # i32 [R] — rounds until next AR-floor probe


def init_slot_state(num_slots: int):
    """All-idle :class:`SlotState` (jnp arrays; imported lazily so the
    scheduler module itself stays importable without jax)."""
    import jax.numpy as jnp

    def z():
        return jnp.zeros((num_slots,), jnp.int32)

    return SlotState(generated=z(), budget=z(),
                     done=jnp.zeros((num_slots,), bool),
                     rung=z(), win_prop=z(), win_acc=z(), probe=z())


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle state."""

    req_id: int
    prompt: np.ndarray                  # [S] i32
    max_new_tokens: int
    # -- lifecycle ----------------------------------------------------------
    # "queued" → "running" → a terminal status from TERMINAL; ``reason``
    # explains non-ok endings ("queue full", "reservation exceeds pool",
    # "deadline exceeded", transfer/corruption details, ...)
    status: str = "queued"
    reason: str = ""
    priority: int = 0                   # higher = preempted later
    deadline_s: Optional[float] = None  # wall-clock budget from submit()
    submit_t: float = 0.0
    cancel_requested: bool = False
    # preempt/resume: a resumable request re-enters the queue front with its
    # KV snapshot in the host tier; on admission it skips prefill entirely
    resume: bool = False
    preemptions: int = 0
    admit_seq: int = -1                 # monotonic admission counter
    megasteps: int = 0                  # harvests since (re)admission
    # swap telemetry (host/disk tier — core/host_tier.py, core/disk_tier.py):
    # offload/restore counts, bytes moved through the tiers, whether each
    # resume found its snapshot prefetched (hit) or had to block on the
    # restore (miss), blocking seconds spent in resume on the engine hot
    # path, and restarts (snapshot capacity-evicted → replayed from prompt)
    offloads: int = 0
    restores: int = 0
    swap_bytes: int = 0
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    resume_block_s: float = 0.0
    restarts: int = 0
    numerics_flags: int = 0             # non-finite logit rows (sampling
                                        # fell back to greedy-over-finite)
    # host mirror of the device rolling acceptance window (updated at each
    # harvest, decayed past `win_limit`): feeds acceptance-informed
    # preemption victim selection and the governor telemetry in GenStats
    win_prop: int = 0
    win_acc: int = 0
    win_limit: int = 64
    rung: int = 0                       # last harvested governor rung
    demotions: int = 0                  # ladder transitions seen so far
    promotions: int = 0
    ar_rounds: int = 0                  # rounds spent on the AR floor
    int8_rounds: int = 0                # rounds spent at the INT8 rung
    # -- runtime ------------------------------------------------------------
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    proposed: int = 0
    accepted: int = 0
    rounds: int = 0
    prefill_s: float = 0.0
    # chunked-admission progress (decode-interleaved prefill): prompt tokens
    # admitted so far, chunks executed, and the chunk-bucket the transient
    # fp scratch was sized to
    prefill_pos: int = 0
    prefill_chunks: int = 0
    prefill_bucket: int = 0
    # megastep driver: the first token was sampled *on device* at prefill
    # finalize and has not reached the host yet — it arrives with the next
    # megastep's packed readback (engine._harvest)
    pending_first: bool = False
    # prefix caching: pool blocks the engine expects to *alias* from the
    # prefix index instead of popping (set just before admission), and the
    # reservation actually charged at admission (released verbatim at
    # retirement, so a later hint change can never unbalance the pool
    # accounting)
    shared_hint: int = 0
    reserved: Optional[int] = None
    admit_t: float = 0.0
    finish_t: float = 0.0
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def rolling_acceptance(self) -> float:
        """Windowed acceptance rate; optimistic 1.0 before any proposals
        (a fresh request must not look like a collapse victim)."""
        if self.win_prop <= 0:
            return 1.0
        return self.win_acc / self.win_prop

    def observe_acceptance(self, proposed: int, accepted: int) -> None:
        """Fold one harvested round into the host window mirror; once the
        window exceeds ``win_limit`` both counters halve, so old evidence
        decays instead of pinning the rate forever."""
        self.win_prop += int(proposed)
        self.win_acc += int(accepted)
        if self.win_prop > self.win_limit:
            self.win_prop //= 2
            self.win_acc //= 2

    @property
    def generated(self) -> int:
        return len(self.tokens)

    def deadline_exceeded(self, now: Optional[float] = None) -> bool:
        if self.deadline_s is None:
            return False
        return (time.perf_counter() if now is None else now) \
            - self.submit_t > self.deadline_s

    def finish(self, status: str, reason: str = "") -> "Request":
        """Mark terminal (idempotent: the first terminal status wins)."""
        assert status in TERMINAL, status
        if not self.done:
            self.status = status
            self.reason = reason
            self.done = True
            self.finish_t = time.perf_counter()
        return self


class Scheduler:
    """FCFS continuous-batching scheduler over ``num_slots`` request slots
    and a pool of ``pool_blocks`` KV blocks (block size ``group``).

    ``max_pending`` bounds the queue (admission backpressure: submissions
    past it come back ``rejected: queue full`` instead of growing host
    memory without bound).  ``strict=True`` restores the legacy behavior of
    raising ``ValueError`` on impossible submissions — useful in tests; a
    serve loop wants the non-raising default."""

    def __init__(self, num_slots: int, pool_blocks: int, group: int,
                 max_pending: Optional[int] = None, strict: bool = False):
        self.num_slots = num_slots
        self.pool_blocks = pool_blocks
        self.group = group
        self.max_pending = max_pending
        self.strict = strict
        self.pending: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.free_slots = list(range(num_slots))
        self.reserved_blocks = 0
        # pool blocks held by the prefix index (refcount-retained, off the
        # free stack but owned by no request); the engine keeps this in sync
        # with insertions/evictions so admission stays capacity-safe:
        #   reserved_blocks + extra_reserved <= pool_blocks
        # (a block both indexed and aliased is counted once here and
        # *discounted* from its aliasing request via `shared_hint` —
        # conservative double-count never admits past the pool)
        self.extra_reserved = 0
        self._next_id = 0
        self._admit_seq = 0

    # ------------------------------------------------------------------
    def _reject(self, req: Request, reason: str) -> Request:
        if self.strict:
            raise ValueError(reason)
        return req.finish("rejected", reason)

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               priority: int = 0,
               deadline_s: Optional[float] = None) -> Request:
        req = Request(req_id=self._next_id, prompt=np.asarray(prompt),
                      max_new_tokens=max_new_tokens, priority=priority,
                      deadline_s=deadline_s, submit_t=time.perf_counter())
        self._next_id += 1
        bound = self.block_bound(req)
        if bound > self.pool_blocks:
            # would never be admissible — with FCFS it would livelock the
            # queue, so reject at submission time
            return self._reject(
                req,
                f"request needs up to {bound} KV blocks but the pool has "
                f"{self.pool_blocks}; shorten the request or grow the pool")
        if self.max_pending is not None \
                and len(self.pending) >= self.max_pending:
            return self._reject(
                req, f"queue full ({self.max_pending} pending)")
        self.pending.append(req)
        return req

    def block_bound(self, req: Request) -> int:
        """Worst-case pool blocks the request can ever *newly* allocate:
        every token of prompt + generation quantized, minus the blocks the
        prefix index will alias into its row (``shared_hint`` — those are
        already charged under ``extra_reserved``, and aliasing never pops
        the free stack)."""
        total = req.prompt_len + req.max_new_tokens
        return max(-(-total // self.group) - req.shared_hint, 0)

    def set_shared_hint(self, req: Request, blocks: int) -> None:
        """Expected aliased (index-owned) blocks for ``req`` — set by the
        engine right before trying admission, from the current index match.
        Only meaningful for pending requests (admitted requests already
        froze their reservation in ``req.reserved``)."""
        req.shared_hint = int(blocks)

    def next_admission(self) -> Optional[Request]:
        """Pop the next admissible request, assigning it a slot, or None if
        the head of the queue doesn't fit yet (FCFS — no overtaking)."""
        if not self.pending or not self.free_slots:
            return None
        req = self.pending[0]
        bound = self.block_bound(req)
        if self.reserved_blocks + bound + self.extra_reserved \
                > self.pool_blocks:
            return None
        self.pending.popleft()
        req.slot = self.free_slots.pop(0)
        self.active[req.slot] = req
        req.reserved = bound
        self.reserved_blocks += bound
        req.status = "running"
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        req.megasteps = 0
        return req

    def head_blocked(self) -> bool:
        """True when a queue head exists but cannot be admitted right now
        (no free slot, or the worst-case reservation doesn't fit)."""
        if not self.pending:
            return False
        if not self.free_slots:
            return True
        head = self.pending[0]
        return self.reserved_blocks + self.block_bound(head) \
            + self.extra_reserved > self.pool_blocks

    def retire(self, slot: int, status: str = "ok",
               reason: str = "") -> Request:
        req = self.active.pop(slot)
        req.finish(status, reason)
        req.slot = None
        self.free_slots.append(slot)
        self.free_slots.sort()
        self.reserved_blocks -= (req.reserved if req.reserved is not None
                                 else self.block_bound(req))
        req.reserved = None
        return req

    # ---- preemption ---------------------------------------------------
    def preemption_victim(self, exclude=()) -> Optional[int]:
        """Slot to preempt for the blocked queue head, or None.

        Lowest priority first; among equal priorities the slot with the
        lowest rolling acceptance goes first (a collapsed speculator is
        producing the fewest tokens per unit of pool held, so evicting it
        costs the least throughput — the ROADMAP's acceptance-informed
        victim selection), with the youngest admission breaking remaining
        ties. Only slots that have decoded at least one megastep since
        (re)admission are eligible, so every preemption cycle nets forward
        progress (bounded round-robin time-slicing instead of livelock)."""
        cands = [(req.priority, req.rolling_acceptance, -req.admit_seq, slot)
                 for slot, req in self.active.items()
                 if slot not in exclude and req.megasteps >= 1]
        return min(cands)[3] if cands else None

    def preempt(self, slot: int) -> Request:
        """Evict a running slot back to the queue *front* as resumable:
        its reservation is released (the engine returns the actual blocks
        via `release_slot` after snapshotting them to the host tier) and it
        re-reserves the full un-discounted bound at resume — the snapshot
        restores into freshly popped private blocks, never aliases."""
        req = self.active.pop(slot)
        self.free_slots.append(slot)
        self.free_slots.sort()
        self.reserved_blocks -= (req.reserved if req.reserved is not None
                                 else self.block_bound(req))
        req.reserved = None
        req.slot = None
        req.resume = True
        req.shared_hint = 0
        req.preemptions += 1
        req.status = "queued"
        self.pending.appendleft(req)
        return req

    def drop_pending(self, req: Request, status: str,
                     reason: str = "") -> Request:
        """Remove a queued request (cancel / deadline / watchdog)."""
        try:
            self.pending.remove(req)
        except ValueError:
            pass
        return req.finish(status, reason)

    @property
    def has_work(self) -> bool:
        return bool(self.pending or self.active)
