"""Write-ahead journal + checkpoints for crash-safe serving.

The continuous engine (serving/engine.py) keeps all request lifecycle
state in process memory; a SIGKILL mid-wave loses the queue, the token
streams harvested so far, and every host-tier snapshot.  This module
makes that state durable enough to *replay*:

* :class:`Journal` — an append-only JSONL write-ahead log.  The engine
  appends one record per lifecycle event **before** acting on it:
  ``submit`` (with the full prompt, so recovery needs no other input),
  ``admit``, ``preempt`` (with the complete token list at preemption —
  the ground truth a bit-exact resume continues from), ``tokens``
  (per-harvest deltas), ``restart`` (snapshot lost → replay from the
  prompt), ``resume``, ``recover`` and ``finish`` (terminal status +
  reason).  Each line is ``crc32(payload) payload`` — on read, the
  first line whose CRC or JSON fails marks a torn tail from the crash
  and everything after it is ignored (`truncated` counts them).

* :meth:`Journal.checkpoint` — atomically (temp file + ``os.replace``)
  writes ``checkpoint.json`` next to the log.  The engine checkpoints
  every N harvests: it copies live host-tier snapshots to the disk tier
  (``HostTier.persist`` — copy, not evict) and records the journal
  sequence number + persisted ids.  The checkpoint is an *optimization
  marker*, not a correctness requirement: the journal alone suffices to
  rebuild the queue, so a kill between a journal append and the next
  checkpoint loses nothing — at worst a request whose snapshot never
  reached disk replays from its prompt (greedy decoding is
  deterministic, so the replayed tokens are identical).

* :func:`replay` — folds an event list into per-request
  :class:`RequestRecord`\\ s: the pure bookkeeping half of
  ``ContinuousEngine.recover`` (unit-testable without JAX).

Directory layout (``journal_dir`` passed to the engine / ``--journal``)::

    journal_dir/
      journal.jsonl      append-only WAL (this module)
      checkpoint.json    latest checkpoint marker (atomic replace)
      kv/                disk-tier snapshot files (core/disk_tier.py)

See docs/serving.md §Crash recovery for the operator runbook.
"""

from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Any, Dict, List, Optional, Tuple

_JOURNAL = "journal.jsonl"
_CHECKPOINT = "checkpoint.json"

#: statuses that end a request's lifecycle (mirrors serving/scheduler.py)
TERMINAL = ("ok", "rejected", "cancelled", "failed", "timed_out")


def _enc(obj: dict) -> bytes:
    payload = json.dumps(obj, separators=(",", ":")).encode()
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    return b"%08x %s\n" % (crc, payload)


class Journal:
    """Append-only, CRC-framed JSONL log under ``root`` (see module
    docstring).  Opening is append-mode: recovery continues the same
    log, so a second crash replays the union of both runs' events."""

    def __init__(self, root: str, *, fsync: bool = False):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.path = os.path.join(root, _JOURNAL)
        self.fsync = fsync
        events, self.dropped_tail = read_events(root)
        self.seq = len(events)
        if self.dropped_tail:
            # rewrite the log without the torn tail before appending: new
            # events written after a garbage line would be unreachable
            # (read_events stops at the first bad line), so a second crash
            # would silently lose this whole run's journal
            tmp = self.path + ".tmp"
            with open(tmp, "wb") as f:
                for e in events:
                    f.write(_enc(e))
                f.flush()
                if fsync:
                    os.fsync(f.fileno())
            os.replace(tmp, self.path)
        self._f = open(self.path, "ab")

    def append(self, ev: str, **fields: Any) -> int:
        """Durably append one event; returns its sequence number."""
        rec = {"seq": self.seq, "ev": ev}
        rec.update(fields)
        self._f.write(_enc(rec))
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.seq += 1
        return self.seq - 1

    def checkpoint(self, meta: dict) -> None:
        """Atomically replace ``checkpoint.json`` with ``meta`` (+ the
        current journal sequence number)."""
        meta = dict(meta)
        meta.setdefault("seq", self.seq)
        tmp = os.path.join(self.root, _CHECKPOINT + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.root, _CHECKPOINT))

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(root: str) -> Tuple[List[dict], int]:
    """Read the journal under ``root``.  Returns ``(events, truncated)``
    where ``truncated`` is the number of trailing lines dropped at the
    first CRC/JSON failure (the torn tail left by a crash mid-append)."""
    path = os.path.join(root, _JOURNAL)
    if not os.path.exists(path):
        return [], 0
    with open(path, "rb") as f:
        lines = f.read().split(b"\n")
    events: List[dict] = []
    for i, line in enumerate(lines):
        if not line:
            continue
        ok = False
        if len(line) > 9 and line[8:9] == b" ":
            payload = line[9:]
            try:
                if int(line[:8], 16) == (zlib.crc32(payload) & 0xFFFFFFFF):
                    events.append(json.loads(payload))
                    ok = True
            except (ValueError, json.JSONDecodeError):
                ok = False
        if not ok:
            # torn tail: drop this and everything after it — later lines
            # may depend on the lost event, so replay stops here
            return events, sum(1 for l in lines[i:] if l)
    return events, 0


def read_checkpoint(root: str) -> Optional[dict]:
    path = os.path.join(root, _CHECKPOINT)
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


@dataclasses.dataclass
class RequestRecord:
    """Folded lifecycle state of one journaled request."""

    req_id: int
    prompt: List[int]
    max_new_tokens: int = 64
    priority: int = 0
    deadline_s: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    status: str = "queued"         # last known status; TERMINAL ⇒ done
    reason: str = ""
    swapped_out: bool = False      # last event left it preempted-to-tier

    @property
    def done(self) -> bool:
        return self.status in TERMINAL


def replay(events: List[dict]) -> Dict[int, RequestRecord]:
    """Fold journal events into per-request records, in submit order.

    ``preempt`` events carry the authoritative token list at preemption
    (they overwrite any ``tokens`` deltas, which is also what makes the
    fold idempotent across recover-of-a-recover); ``restart`` clears the
    stream because the engine replays from the prompt."""
    recs: Dict[int, RequestRecord] = {}
    for e in events:
        ev = e.get("ev")
        rid = e.get("req")
        if ev == "submit":
            recs[rid] = RequestRecord(
                req_id=rid, prompt=list(e.get("prompt", [])),
                max_new_tokens=e.get("max_new", 64),
                priority=e.get("priority", 0),
                deadline_s=e.get("deadline_s"))
            continue
        rec = recs.get(rid)
        if rec is None:
            continue               # event for a request whose submit was torn
        if ev == "tokens":
            rec.tokens.extend(e.get("toks", []))
        elif ev == "preempt":
            rec.tokens = list(e.get("tokens", []))
            rec.swapped_out = True
            rec.status = "queued"
        elif ev in ("admit", "resume"):
            rec.swapped_out = False
            rec.status = "running"
        elif ev == "restart":
            rec.tokens = []
            rec.swapped_out = False
        elif ev == "recover":
            # a previous recovery re-queued it; mode "replay" restarts
            if e.get("mode") == "replay":
                rec.tokens = []
                rec.swapped_out = False
            rec.status = "queued"
        elif ev == "finish":
            rec.status = e.get("status", "ok")
            rec.reason = e.get("reason", "")
            rec.swapped_out = False
    return recs
