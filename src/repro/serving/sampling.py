"""Token sampling."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits: jnp.ndarray, key, greedy: bool = False):
    """logits [B, V] or [B, K, V] -> [B] or [B, K]."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def top_p_filter(logits: jnp.ndarray, top_p: float) -> jnp.ndarray:
    """Nucleus filtering: mask logits outside the top-p mass."""
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    return jnp.where(logits < cutoff, -1e30, logits)
