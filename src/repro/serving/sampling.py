"""Token sampling: greedy/categorical plus exact nucleus (top-p) filtering.

Top-p filtering is applied to *logits* (post-temperature), and in the
speculative rounds it is applied to **both** the draft proposal
distribution q and the target verification distribution p — speculative
sampling then remains exact with respect to the top-p-filtered target
distribution (the accept/reject ratio p/q is computed on the same
filtered, renormalized supports).

Numerics guard: every sampling entry point tolerates non-finite logits
(NaN/Inf from an overflowed matmul or a corrupted cache block).  Poisoned
rows never reach ``jax.random.categorical`` — non-finite entries are masked
to ``-1e30`` and a flagged row falls back to greedy-over-finite — so one
bad request degrades to deterministic output instead of sampling garbage
token ids (or NaN-propagating into every slot's trajectory).  The per-row
flags feed the request-level ``numerics_flags`` counters in ``GenStats``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def sanitize_logits(logits: jnp.ndarray):
    """Mask non-finite logits; returns ``(safe_logits, bad_row)``.

    ``bad_row`` flags rows (leading dims of the vocab axis) containing any
    non-finite entry.  Finite entries keep their values; non-finite ones
    become ``-1e30``.  A row with *no* finite entry becomes uniform zeros
    so downstream softmax/argmax stay well-defined (argmax → token 0)."""
    finite = jnp.isfinite(logits)
    bad_row = ~jnp.all(finite, axis=-1)
    safe = jnp.where(finite, logits, _NEG_INF)
    all_bad = ~jnp.any(finite, axis=-1)
    return jnp.where(all_bad[..., None], jnp.zeros_like(logits), safe), bad_row


def top_p_filter(logits: jnp.ndarray, top_p: float) -> jnp.ndarray:
    """Nucleus filtering: mask logits outside the top-p mass.

    The kept set is the smallest prefix of the probability-sorted vocab
    whose cumulative mass reaches ``top_p`` (the top-1 entry is always
    kept). Membership is decided by *sorted rank*, not by comparing against
    the cutoff logit value — a value comparison (``logits < cutoff``) leaks
    every vocab entry that *ties* the cutoff logit into the kept set.

    Non-finite logits are sanitized first (NaN sorts unpredictably and a
    single NaN poisons the whole cumulative mass).
    """
    logits, _ = sanitize_logits(logits)
    order = jnp.argsort(logits, axis=-1)[..., ::-1]          # descending
    sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep entry i iff the mass strictly before it is < top_p; ties at the
    # cutoff value are kept only up to the nucleus rank
    keep_sorted = (cum - probs) < top_p
    inv = jnp.argsort(order, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    return jnp.where(keep, logits, -1e30)


def maybe_top_p(logits: jnp.ndarray, top_p: Optional[float]) -> jnp.ndarray:
    """Apply :func:`top_p_filter` when a restrictive top_p is set."""
    if top_p is None or top_p >= 1.0:
        return logits
    return top_p_filter(logits, top_p)


def sample_token(logits: jnp.ndarray, key, greedy: bool = False,
                 top_p: Optional[float] = None, return_flags: bool = False):
    """logits [B, V] or [B, K, V] -> [B] or [B, K].

    Rows carrying non-finite logits fall back to greedy-over-finite (the
    sanitized argmax) instead of sampling from a poisoned distribution;
    ``return_flags=True`` additionally returns the per-row flag mask so the
    engines can count numerics incidents per request."""
    safe, bad = sanitize_logits(logits)
    fallback = jnp.argmax(safe, axis=-1).astype(jnp.int32)
    if greedy:
        tok = fallback
    else:
        sampled = jax.random.categorical(key, maybe_top_p(safe, top_p),
                                         axis=-1).astype(jnp.int32)
        tok = jnp.where(bad, fallback, sampled)
    return (tok, bad) if return_flags else tok
