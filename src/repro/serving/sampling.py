"""Token sampling: greedy/categorical plus exact nucleus (top-p) filtering.

Top-p filtering is applied to *logits* (post-temperature), and in the
speculative rounds it is applied to **both** the draft proposal
distribution q and the target verification distribution p — speculative
sampling then remains exact with respect to the top-p-filtered target
distribution (the accept/reject ratio p/q is computed on the same
filtered, renormalized supports).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def top_p_filter(logits: jnp.ndarray, top_p: float) -> jnp.ndarray:
    """Nucleus filtering: mask logits outside the top-p mass.

    The kept set is the smallest prefix of the probability-sorted vocab
    whose cumulative mass reaches ``top_p`` (the top-1 entry is always
    kept). Membership is decided by *sorted rank*, not by comparing against
    the cutoff logit value — a value comparison (``logits < cutoff``) leaks
    every vocab entry that *ties* the cutoff logit into the kept set.
    """
    order = jnp.argsort(logits, axis=-1)[..., ::-1]          # descending
    sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep entry i iff the mass strictly before it is < top_p; ties at the
    # cutoff value are kept only up to the nucleus rank
    keep_sorted = (cum - probs) < top_p
    inv = jnp.argsort(order, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    return jnp.where(keep, logits, -1e30)


def maybe_top_p(logits: jnp.ndarray, top_p: Optional[float]) -> jnp.ndarray:
    """Apply :func:`top_p_filter` when a restrictive top_p is set."""
    if top_p is None or top_p >= 1.0:
        return logits
    return top_p_filter(logits, top_p)


def sample_token(logits: jnp.ndarray, key, greedy: bool = False,
                 top_p: Optional[float] = None):
    """logits [B, V] or [B, K, V] -> [B] or [B, K]."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, maybe_top_p(logits, top_p),
                                  axis=-1).astype(jnp.int32)
