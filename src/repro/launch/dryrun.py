"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

The XLA_FLAGS line below forces 512 host platform devices BEFORE any jax
initialization — only this entry point sees them; tests/benches see 1 CPU.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json
import re
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.distributed import specs as SP
from repro.distributed.sharding import axis_rules
from repro.launch.mesh import make_production_mesh
from repro.models.stack import StackModel
from repro.training.optimizer import AdamW, AdamWState
from repro.training.train_step import make_train_step

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, long=True),
    # continuous-batching serving state: one paged_spec_round over
    # `batch` slots sharing a block pool (pool kv-heads → model, FP-buffer
    # slots → data, table replicated) — pure-full-attention archs only
    "paged_32k": dict(kind="paged", seq=32768, batch=64),
}


def paged_eligible(cfg) -> bool:
    """The paged engine needs a pure full-attention, single-codebook stack."""
    from repro.models.config import ATTN_FULL
    return (cfg.num_codebooks == 0 and
            all(s.mixer == ATTN_FULL for s in cfg.layers))

DRYRUN_ARCHS = [a for a in ARCHS if a not in ("tiny-lm", "llama2-7b-32k")]

# pure full-attention archs run long_500k in streaming (sink+window) mode —
# the sub-quadratic variant (DESIGN.md §4); natives run their real caches.
LONG_NATIVE = {"gemma3-27b", "rwkv6-1.6b", "jamba-v0.1-52b"}
STREAM_WINDOW = 8192

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by op kind."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            token = f" {c}("
            if token in line or f" {c}-start(" in line:
                rhs_head = line.split(token)[0] if token in line \
                    else line.split(f" {c}-start(")[0]
                # result shape(s) appear between '=' and the op name
                seg = rhs_head.split("=")[-1]
                out[c] += _shape_bytes(seg)
                out["count"] += 1
                break
    return out


# ---------------------------------------------------------------------------
# per-shape step builders
# ---------------------------------------------------------------------------

def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _token_struct(cfg, batch, seq, mesh, *, long=False):
    shape = (batch, seq, cfg.num_codebooks) if cfg.num_codebooks \
        else (batch, seq)
    spec = jax.sharding.PartitionSpec(
        None if long else _batch_axes(mesh) or None)
    return jax.ShapeDtypeStruct(
        shape, jnp.int32, sharding=jax.sharding.NamedSharding(mesh, spec))


def _memory_struct(cfg, batch, mesh, long=False):
    if not cfg.num_image_tokens:
        return None
    spec = jax.sharding.PartitionSpec(
        None if long else _batch_axes(mesh) or None)
    return jax.ShapeDtypeStruct(
        (batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16,
        sharding=jax.sharding.NamedSharding(mesh, spec))


def build_step(arch: str, shape_name: str, mesh, n_repeats=None,
               cfg_opts=None):
    """Returns (jitted_fn, example_shaped_args, cfg).

    n_repeats override builds the cost *probe*: a 2-super-block variant
    compiled fully unrolled, whose cost delta vs the full (scan, unroll=1)
    program isolates one super-block's FLOPs/bytes/collectives exactly —
    XLA's cost_analysis counts a while body once, so the full program's
    costs are reconstructed as  full + (n-1)·(probe2 - full).
    """
    info = SHAPES[shape_name]
    cfg_opts = dict(cfg_opts or {})
    unroll_override = cfg_opts.pop("scan_unroll", None)
    cfg = get_config(arch).replace(dtype="bfloat16", **cfg_opts)
    if n_repeats is not None:
        cfg = cfg.replace(n_repeats=n_repeats)
        model = StackModel(cfg, remat=True, scan_unroll=n_repeats)
    else:
        model = StackModel(cfg, remat=True,
                           scan_unroll=unroll_override or 1)
    long = info.get("long", False)

    params_sh = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mode = "train" if info["kind"] == "train" else "serve"
    p_specs = SP.param_specs(params_sh, mesh, mode)
    params_in = SP.apply_sharding_to_shapes(params_sh, p_specs)

    if info["kind"] == "train":
        opt = AdamW()
        opt_sh = jax.eval_shape(opt.init, params_sh)
        o_specs = SP.param_specs(opt_sh.m, mesh, "train")
        opt_in = AdamWState(
            step=jax.ShapeDtypeStruct(
                (), jnp.int32,
                sharding=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())),
            m=SP.apply_sharding_to_shapes(opt_sh.m, o_specs),
            v=SP.apply_sharding_to_shapes(opt_sh.v, o_specs))
        batch = {"tokens": _token_struct(cfg, info["batch"], info["seq"], mesh)}
        mem = _memory_struct(cfg, info["batch"], mesh)
        if mem is not None:
            batch["memory"] = mem
        step = make_train_step(model, opt)
        fn = jax.jit(step)
        return fn, (params_in, opt_in, batch), cfg

    if info["kind"] == "paged":
        # continuous-engine state: paged pool + shared table + quantized
        # draft params, compiled as one sharded paged_spec_round
        from repro.core import paged_kv_cache as PCC
        from repro.core.spec_decode import paged_spec_round
        from repro.core.weight_quant import quantize_tree

        G = cfg.group_size
        slots = info["batch"]
        nbmax = -(-info["seq"] // G)
        pool_blocks = slots * nbmax
        state_sh = jax.eval_shape(
            partial(model.init_serve_state, slots, info["seq"],
                    policy="paged", ctx_kw={"pool_blocks": pool_blocks},
                    dtype=jnp.bfloat16))
        state_in = SP.apply_sharding_to_shapes(
            state_sh, SP.state_specs(state_sh, mesh))
        table_sh = jax.eval_shape(
            partial(PCC.init_table, slots, nbmax, pool_blocks))
        table_in = SP.apply_sharding_to_shapes(
            table_sh, SP.table_specs(table_sh, mesh))
        draft_sh = jax.eval_shape(
            partial(quantize_tree, group=cfg.weight_quant_group), params_sh)
        draft_in = SP.apply_sharding_to_shapes(
            draft_sh, SP.param_specs(draft_sh, mesh, "serve"))
        repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        last = jax.ShapeDtypeStruct((slots, 1), jnp.int32, sharding=repl)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=repl)

        fn = jax.jit(partial(paged_spec_round, model, gamma=4, greedy=True))
        return fn, (params_in, draft_in, state_in, table_in, last, key), cfg

    policy = "quantspec"
    ctx_kw = {}
    if long and arch not in LONG_NATIVE and not cfg.is_attention_free:
        policy = "streaming_only"
        ctx_kw = dict(draft_window=STREAM_WINDOW)

    # round the cache capacity so the block axis shards cleanly (16-way)
    G = cfg.group_size
    max_seq = -(-(info["seq"] + 64) // (G * 16)) * (G * 16)
    state_sh = jax.eval_shape(
        partial(model.init_serve_state, info["batch"], max_seq,
                policy=policy, ctx_kw=ctx_kw or None, dtype=jnp.bfloat16))
    s_specs = SP.state_specs(state_sh, mesh, long_ctx=long)
    state_in = SP.apply_sharding_to_shapes(state_sh, s_specs)

    if info["kind"] == "prefill":
        tokens = _token_struct(cfg, info["batch"], info["seq"], mesh)
        mem = _memory_struct(cfg, info["batch"], mesh)

        def prefill_step(params, tokens, state, memory=None):
            return model.prefill(params, tokens, state, policy=policy,
                                 memory=memory, ctx_kw=ctx_kw or None)

        fn = jax.jit(prefill_step)
        args = (params_in, tokens, state_in) + ((mem,) if mem is not None else ())
        return fn, args, cfg

    # decode: ONE new token against a seq_len cache
    tokens = _token_struct(cfg, info["batch"], 1, mesh, long=long)
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=jax.sharding.NamedSharding(
                                   mesh, jax.sharding.PartitionSpec()))

    def serve_step(params, tokens, state, stream_pos):
        logits, new_state, _ = model.decode(
            params, tokens, state, stream_pos, kv_mode="target",
            policy=policy, ctx_kw=ctx_kw or None)
        return logits, new_state

    fn = jax.jit(serve_step)
    return fn, (params_in, tokens, state_in, pos), cfg


def _analyse(compiled, skip_hlo: bool) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per program
        cost = cost[0] if cost else {}
    coll = {} if skip_hlo else collective_bytes(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0) or 0.0,
        "bytes_accessed": cost.get("bytes accessed", 0.0) or 0.0,
        "transcendentals": cost.get("transcendentals", 0.0) or 0.0,
        "collectives": coll,
    }


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            skip_hlo: bool = False, cfg_opts=None, tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    mode = "train" if SHAPES[shape_name]["kind"] == "train" else (
        "long" if SHAPES[shape_name].get("long") else "serve")
    rules_mode = "train" if mode == "train" else (
        "long" if mode == "long" else "serve")
    with mesh, axis_rules(mesh, rules_mode):
        # 1) the real program (full depth, scan unroll=1)
        fn, args, cfg = build_step(arch, shape_name, mesh, cfg_opts=cfg_opts)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        full = _analyse(compiled, skip_hlo)

        # 2) cost probe: n_repeats=0 → the constant part C (embed, head,
        # unembed, unscanned head/tail layers). XLA counts the while body
        # once, so   total = C + n_repeats · (full − C).
        probe = None
        n = cfg.n_repeats
        fully_unrolled = (cfg_opts or {}).get("scan_unroll", 1) >= n
        if n > 1 and not fully_unrolled:
            fn0, args0, _ = build_step(arch, shape_name, mesh, n_repeats=0,
                                       cfg_opts=cfg_opts)
            probe = _analyse(fn0.lower(*args0).compile(), skip_hlo)

    def corrected(key):
        if probe is None:
            return full[key]
        c = min(probe[key], full[key])
        return c + n * (full[key] - c)

    coll_corr = dict(full["collectives"])
    if probe is not None:
        for k in coll_corr:
            c = min(probe["collectives"].get(k, 0),
                    full["collectives"].get(k, 0))
            coll_corr[k] = c + n * (full["collectives"].get(k, 0) - c)

    mem = compiled.memory_analysis()
    mem_d = {attr: getattr(mem, attr, None)
             for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                          "output_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes")}

    res = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "flops": corrected("flops"),
        "bytes_accessed": corrected("bytes_accessed"),
        "collectives": coll_corr,
        "raw_full": full, "raw_probe2": probe,
        "n_repeats": n,
        "memory": mem_d,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
        "cfg_opts": cfg_opts or {},
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
          f"flops={res['flops']:.3e} bytes={res['bytes_accessed']:.3e} "
          f"coll={sum(v for k, v in coll_corr.items() if k != 'count'):.3e} "
          f"compile={t_compile:.0f}s", flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=DRYRUN_ARCHS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-hlo", action="store_true",
                    help="skip collective parsing (faster)")
    ap.add_argument("--opt", action="append", default=[],
                    help="config override key=value (perf iterations), "
                         "e.g. --opt hier_attn_impl=blocked")
    ap.add_argument("--tag", default="", help="output filename suffix")
    args = ap.parse_args()

    cfg_opts = {}
    for kv in args.opt:
        k, v = kv.split("=", 1)
        cfg_opts[k] = int(v) if v.isdigit() else v

    archs = DRYRUN_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            if SHAPES[shape]["kind"] == "paged" and \
                    not paged_eligible(get_config(arch)):
                print(f"[dryrun] skip {arch} × {shape}: paged engine needs "
                      f"a pure full-attention stack", flush=True)
                continue
            for mp in meshes:
                try:
                    run_one(arch, shape, mp, args.out, args.skip_hlo,
                            cfg_opts=cfg_opts or None, tag=args.tag)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures.append((arch, shape, mp, repr(e)[:500]))
                    print(f"[dryrun] FAIL {arch} × {shape} × mp={mp}: "
                          f"{e!r}"[:600], flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
