"""Serving launcher: batched-request generation with a chosen cache policy.

    PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b-32k --smoke \
        --policy quantspec --gamma 4 --prompt-len 256 --max-new 64

`--engine continuous` switches to the paged-cache continuous-batching
engine (ragged prompt lengths, admission/retirement between spec rounds):

    PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm \
        --engine continuous --slots 2 --batch 4 --max-new 32 --greedy

`--mesh` places and runs the engine tensor/data-parallel: target + draft
params are sharded per `param_specs("serve")`, the (paged) cache per
`state_specs`, and the jitted rounds run SPMD over the mesh.  `host<N>`
forces N host-platform CPU devices so the sharded path is runnable
anywhere:

    PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm --smoke \
        --engine continuous --mesh host8 --greedy
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b-32k")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="quantspec",
                    choices=["quantspec", "fp", "streaming", "snapkv"])
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--greedy", action="store_true")
    ap.add_argument("--top-p", type=float, default=None,
                    help="nucleus sampling: filter BOTH the draft q and "
                         "target p distributions (speculative sampling "
                         "stays exact w.r.t. the filtered target)")
    ap.add_argument("--engine", choices=["static", "continuous"],
                    default="static")
    ap.add_argument("--slots", type=int, default=2,
                    help="concurrent request slots (continuous engine)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prefill chunk/bucket size in tokens: the static "
                         "engine pads prompts to this grid (one compile "
                         "per bucket); the continuous engine admits one "
                         "chunk per iteration between spec rounds")
    ap.add_argument("--rounds-per-step", type=int, default=4,
                    help="spec rounds fused into one jitted decode "
                         "megastep (device-resident budget/EOS masking, "
                         "one device→host readback per megastep); 0 = "
                         "legacy one-round-per-dispatch loop")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a request at this token (device-side EOS "
                         "detection; continuous engine megasteps only)")
    ap.add_argument("--mesh", default="local",
                    help="local | single | multi | host<N> | host<D>x<M> — "
                         "host meshes force host-platform CPU devices so "
                         "sharded serving runs on any machine")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cache quantized prompt blocks across requests "
                         "(radix prefix index): repeated system prompts / "
                         "multi-turn resubmissions alias pool blocks and "
                         "prefill only the uncached suffix; greedy outputs "
                         "are unchanged (quantspec policy)")
    ap.add_argument("--overflow", choices=["preempt", "wait", "reject"],
                    default="preempt",
                    help="what to do when the queue head cannot be "
                         "admitted: preempt a running slot to the host KV "
                         "tier and resume it later (graceful degradation, "
                         "bit-exact), wait FCFS (legacy), or reject the "
                         "head (continuous engine)")
    ap.add_argument("--preempt-patience", type=int, default=16,
                    help="blocked-head iterations tolerated before a "
                         "preemption is considered (overflow=preempt)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="bound the request queue: submissions past this "
                         "come back status=rejected (queue full) instead "
                         "of growing host memory")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline; overrunning "
                         "requests end status=timed_out at the next "
                         "megastep harvest boundary")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="KV pool size in blocks (default: slots * "
                         "ceil(max_seq/group) — never oversubscribed); "
                         "set lower to exercise the overflow policy")
    ap.add_argument("--journal", default=None, metavar="DIR",
                    help="write-ahead journal + checkpoint directory "
                         "(crash-safe serving; also roots the disk KV "
                         "tier at DIR/kv unless --disk-dir is given)")
    ap.add_argument("--recover", action="store_true",
                    help="replay the --journal directory of a crashed run "
                         "instead of submitting a fresh wave: non-terminal "
                         "requests are re-queued (bit-exact resume from "
                         "checkpointed snapshots where possible, replay "
                         "from the prompt otherwise) and driven to "
                         "completion")
    ap.add_argument("--disk-dir", default=None,
                    help="disk KV tier root: LRU host-tier snapshots past "
                         "--host-capacity-bytes spill to per-request files "
                         "here (device → host → disk hierarchy)")
    ap.add_argument("--host-capacity-bytes", type=int, default=None,
                    help="bound host-tier RAM; offloads past it spill LRU "
                         "snapshots to the disk tier")
    ap.add_argument("--disk-capacity-bytes", type=int, default=None,
                    help="bound the disk tier; past its high watermark LRU "
                         "records are evicted (the engine then replays "
                         "those requests from their prompts)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable speculative swap-in prefetch (restore "
                         "dispatches at admission time, the PR 7 baseline)")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    help="megastep harvests between engine checkpoints "
                         "(journaled runs: host-tier snapshots persist to "
                         "the disk tier at each checkpoint)")
    ap.add_argument("--governor", action="store_true",
                    help="acceptance-aware precision governor: each slot "
                         "watches a rolling acceptance window and walks a "
                         "degradation ladder — shrink gamma, escalate the "
                         "draft KV read INT4->INT8, fall back to plain AR "
                         "target decode — with hysteresis and probe rounds "
                         "that re-escalate on recovery (continuous engine "
                         "megasteps only; greedy outputs are unchanged)")
    ap.add_argument("--accept-window", type=int, default=32,
                    help="proposed tokens per governor window: the ladder "
                         "is only evaluated once a slot has this much "
                         "evidence (larger = fewer spurious demotions "
                         "under binomial acceptance noise)")
    ap.add_argument("--accept-floor", type=float, default=0.5,
                    help="windowed acceptance below this demotes the slot "
                         "one rung")
    ap.add_argument("--accept-ceiling", type=float, default=0.8,
                    help="windowed acceptance above this promotes the slot "
                         "one rung (must exceed --accept-floor: the gap is "
                         "the ladder's hysteresis band)")
    ap.add_argument("--probe-every", type=int, default=8,
                    help="AR-floor rounds between speculative probe rounds "
                         "(a probe re-escalates the slot if its acceptance "
                         "has recovered past the ceiling)")
    ap.add_argument("--gamma-lo", type=int, default=0,
                    help="reduced draft length for the shrunk-gamma rung; "
                         "0 = max(1, gamma // 2)")
    args = ap.parse_args()
    if args.recover and not args.journal:
        raise SystemExit("--recover requires --journal DIR")
    if args.governor and args.engine != "continuous":
        raise SystemExit("--governor needs --engine continuous (the ladder "
                         "state lives in the paged megastep's per-slot "
                         "SlotState)")

    # resolve the mesh FIRST: host<N> meshes must append the forced-device
    # XLA flag before anything initializes the jax backends
    from repro.launch.mesh import resolve_mesh
    mesh = resolve_mesh(args.mesh)

    import jax
    import numpy as np

    from repro.configs import ARCHS, get_config
    from repro.data.pipeline import SyntheticCorpus
    from repro.distributed.sharding import axis_rules
    from repro.models.stack import StackModel
    from repro.serving.engine import (ContinuousEngine, Engine, GenStats,
                                      GenerationResult)

    if args.arch not in ARCHS:
        raise SystemExit(f"unknown --arch {args.arch!r}; choose from "
                         f"{', '.join(ARCHS)}")
    cfg = get_config(args.arch, smoke=args.smoke)
    model = StackModel(cfg)
    # a 1×1 "local" mesh keeps the legacy unsharded engine path; any real
    # mesh is handed to the engine, which places params/cache onto it
    engine_mesh = mesh if mesh.devices.size > 1 else None

    with mesh, axis_rules(mesh, "serve"):
        params = model.init(jax.random.PRNGKey(0))
        corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
        prompt = corpus.sample(jax.random.PRNGKey(1), args.batch,
                               args.prompt_len)
        if cfg.num_codebooks:
            prompt = jax.numpy.stack([prompt] * cfg.num_codebooks, axis=-1)
        memory = None
        if cfg.num_image_tokens:
            memory = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.num_image_tokens, cfg.d_model)) * 0.02

        max_seq = args.prompt_len + args.max_new + 2 * cfg.group_size + 8
        chunk_kw = {}
        if args.prefill_chunk:
            chunk_kw["prefill_chunk"] = args.prefill_chunk
        if engine_mesh is not None:
            print(f"mesh {dict(engine_mesh.shape)}: params/cache sharded "
                  f"per serve specs")
        if args.engine == "continuous":
            if args.eos_id is not None and \
                    (args.rounds_per_step < 1 or args.gamma < 1):
                raise SystemExit("--eos-id needs the megastep driver: "
                                 "--rounds-per-step >= 1 and --gamma >= 1 "
                                 "(EOS detection is device-resident)")
            eng = ContinuousEngine(model, params, gamma=args.gamma,
                                   greedy=args.greedy, top_p=args.top_p,
                                   max_slots=args.slots, max_seq=max_seq,
                                   rounds_per_step=args.rounds_per_step,
                                   eos_id=args.eos_id, mesh=engine_mesh,
                                   prefix_cache=args.prefix_cache,
                                   overflow=args.overflow,
                                   preempt_patience=args.preempt_patience,
                                   max_pending=args.max_pending,
                                   pool_blocks=args.pool_blocks,
                                   journal_dir=args.journal,
                                   disk_dir=args.disk_dir,
                                   host_capacity_bytes=args.host_capacity_bytes,
                                   disk_capacity_bytes=args.disk_capacity_bytes,
                                   prefetch=not args.no_prefetch,
                                   checkpoint_every=args.checkpoint_every,
                                   governor=args.governor,
                                   accept_window=args.accept_window,
                                   accept_floor=args.accept_floor,
                                   accept_ceiling=args.accept_ceiling,
                                   probe_every=args.probe_every,
                                   gamma_lo=args.gamma_lo,
                                   **chunk_kw)
            if args.recover:
                reqs = eng.recover()
                print(f"recover: {len(reqs)} non-terminal request(s) "
                      f"re-queued from {args.journal} "
                      f"({sum(1 for r in reqs if r.resume)} resumable)")
            else:
                # ragged prompts: vary lengths so requests join/retire
                # mid-stream
                prompts = [np.asarray(prompt[i, : args.prompt_len - 7 * i])
                           for i in range(args.batch)]
                reqs = [eng.submit(p, args.max_new,
                                   deadline_s=args.deadline_s)
                        for p in prompts]
            eng.run(jax.random.PRNGKey(7))
            if any(r.status != "ok" for r in reqs):
                for r in reqs:
                    if r.status != "ok":
                        print(f"req {r.req_id}: {r.status} ({r.reason})")
            if eng.preempts or eng.resumes or eng.restarts:
                tier = eng.host_tier
                print(f"overload: {eng.preempts} preemptions, "
                      f"{eng.resumes} resumes ({eng.prefetch_hits} "
                      f"prefetched, {eng.prefetch_misses} blocking, "
                      f"{eng.resume_block_s * 1e3:.1f}ms blocked), "
                      f"{eng.restarts} replays, "
                      f"{tier.bytes_offloaded} bytes via host tier "
                      f"({tier.retries} transfer retries)")
                if tier.spills or tier.disk_restores:
                    print(f"disk tier: {tier.spills} spills "
                          f"({tier.spill_bytes} bytes), "
                          f"{tier.disk_restores} disk restores, "
                          f"{eng.disk_tier.stats}")
            if eng.journal is not None:
                print(f"journal: {eng.journal.seq} events, "
                      f"{eng.checkpoints} checkpoints -> {args.journal}")
                if args.recover:
                    for r in reqs:
                        print(f"recovered req {r.req_id}: {r.status}, "
                              f"{r.generated} tokens "
                              f"{np.asarray(r.tokens)[:16].tolist()}")
                    return
            results = [GenerationResult(
                tokens=np.asarray(r.tokens, np.int64)[None, :],
                stats=GenStats(proposed=r.proposed, accepted=r.accepted,
                               rounds=r.rounds, generated=r.generated,
                               prefill_s=r.prefill_s,
                               decode_s=max(r.finish_t - r.admit_t
                                            - r.prefill_s, 0.0),
                               numerics_flags=r.numerics_flags,
                               offloads=r.offloads, restores=r.restores,
                               swap_bytes=r.swap_bytes,
                               prefetch_hits=r.prefetch_hits,
                               prefetch_misses=r.prefetch_misses,
                               resume_block_s=r.resume_block_s,
                               restarts=r.restarts,
                               demotions=r.demotions,
                               promotions=r.promotions,
                               int8_rounds=r.int8_rounds,
                               ar_rounds=r.ar_rounds,
                               final_rung=r.rung))
                for r in reqs if r.status == "ok"]
            if args.prefix_cache:
                # second wave of identical prompts: admissions now come out
                # of the prefix index (chunks cover only the fp tail)
                results = eng.generate(prompts, args.max_new,
                                       key=jax.random.PRNGKey(7))
            for i, res in enumerate(results):
                s = res.stats
                swap = ""
                if s.offloads or s.restores or s.restarts:
                    swap = (f", swaps {s.offloads}/{s.restores} "
                            f"({s.swap_bytes}B, {s.prefetch_hits} "
                            f"prefetched, {s.resume_block_s * 1e3:.1f}ms "
                            f"blocked)")
                gov = ""
                if args.governor and (s.demotions or s.promotions):
                    gov = (f", ladder {s.demotions}v/{s.promotions}^ "
                           f"({s.int8_rounds} int8 + {s.ar_rounds} ar "
                           f"rounds, final rung {s.final_rung})")
                print(f"req {i}: {s.generated} tokens in {s.rounds} rounds, "
                      f"acceptance {s.acceptance_rate:.1%}, "
                      f"prefill {s.prefill_s:.2f}s decode "
                      f"{s.decode_s:.2f}s{swap}{gov}")
            if args.prefix_cache:
                print("prefix cache:", eng.prefix.stats,
                      f"harvest syncs {eng.cache_syncs}")
            print("first request tokens:", results[0].tokens[0][:32].tolist())
            return
        if args.eos_id is not None:
            raise SystemExit("--eos-id needs --engine continuous (EOS "
                             "detection lives in the paged megastep's "
                             "per-slot state)")
        if args.prefix_cache and args.batch != 1:
            raise SystemExit("--prefix-cache on the static engine is the "
                             "batch-1 dense oracle path: use --batch 1 (or "
                             "--engine continuous for batched serving)")
        eng = Engine(model, params, policy=args.policy, gamma=args.gamma,
                     greedy=args.greedy, top_p=args.top_p, max_seq=max_seq,
                     rounds_per_step=args.rounds_per_step, mesh=engine_mesh,
                     prefix_cache=args.prefix_cache, **chunk_kw)
        res = eng.generate(prompt, args.max_new, key=jax.random.PRNGKey(7),
                           memory=memory)
        s = res.stats
        print(f"generated {s.generated} tokens in {s.rounds} rounds "
              f"(prefill {s.prefill_s:.2f}s, decode {s.decode_s:.2f}s)")
        if s.proposed:
            print(f"acceptance {s.acceptance_rate:.1%}, "
                  f"tokens/round {s.tokens_per_round:.2f}")
        print("first request tokens:", res.tokens[0][:32].tolist())


if __name__ == "__main__":
    main()
