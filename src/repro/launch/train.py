"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tiny-lm --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-27b --smoke

Runs the sharded train step under the local mesh (1 device) or, on real
hardware, the production mesh (--mesh single|multi). The same step function
the dry-run lowers for 256/512 chips.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS, get_config
from repro.data.pipeline import SyntheticCorpus
from repro.distributed.sharding import axis_rules
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.stack import StackModel
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="tiny-lm")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", choices=["local", "single", "multi"],
                    default="local")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = StackModel(cfg, remat=True)
    mesh = (make_local_mesh() if args.mesh == "local" else
            make_production_mesh(multi_pod=args.mesh == "multi"))

    with mesh, axis_rules(mesh, "train"):
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                    total_steps=args.steps)
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(model, opt))
        corpus = SyntheticCorpus(cfg.vocab_size, seed=0, bigram_temp=0.3)
        it = corpus.batches(args.batch, args.seq,
                            codebooks=cfg.num_codebooks)
        t0 = time.time()
        for i in range(args.steps):
            batch = next(it)
            if cfg.num_image_tokens:
                batch["memory"] = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(3), i),
                    (args.batch, cfg.num_image_tokens, cfg.d_model)) * 0.02
            params, opt_state, m = step_fn(params, opt_state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(m['loss']):.4f} "
                      f"ppl={float(m['ppl']):.2f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt_state, step=args.steps)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
