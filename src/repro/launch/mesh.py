"""Production meshes.

Single pod : (16, 16)      axes ("data", "model")  — 256 × TPU v5e
Multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") — 512 chips
Host       : (D, M)        axes ("data", "model") — forced host-platform
             CPU devices (`--mesh host<N>` / `host<D>x<M>`), so sharded
             serving runs end-to-end on a laptop or in CI.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and `make_host_mesh` appends the same flag itself *before* its first device
query.
"""

from __future__ import annotations

import os
import re

import jax
import numpy as np

# TPU v5e hardware constants (roofline)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link

_HOST_FLAG = "--xla_force_host_platform_device_count"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    have = jax.device_count()
    if have < need:
        name = "multi" if multi_pod else "single"
        raise ValueError(
            f"--mesh {name} needs a {'×'.join(map(str, shape))} mesh = "
            f"{need} devices, but only {have} "
            f"{'is' if have == 1 else 'are'} visible. Launch with "
            f"XLA_FLAGS={_HOST_FLAG}={need} to force host-platform devices "
            f"(dry-run style), or use --mesh host<N> for a runnable "
            f"CPU mesh sized to this machine.")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names — lets the same sharded
    step functions run on a laptop/CI CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_host_mesh(data: int, model: int):
    """(data, model) mesh over forced host-platform CPU devices.

    Appends ``--xla_force_host_platform_device_count`` to XLA_FLAGS and
    pins ``JAX_PLATFORMS=cpu`` (the flag only grows the *host* platform, so
    on an accelerator machine the default backend would still be the 1-GPU/
    TPU one) before the first device query — it only works if jax has not
    initialized its backends yet (call it before any other jax API that
    touches devices; `resolve_mesh` runs first thing in the serve
    launcher). If jax is already initialized with fewer devices, fails with
    instructions instead of an opaque mesh-construction error."""
    need = data * model
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    cur = re.search(re.escape(_HOST_FLAG) + r"=(\d+)", flags)
    if cur is None:
        os.environ["XLA_FLAGS"] = f"{flags} {_HOST_FLAG}={need}".strip()
    elif int(cur.group(1)) < need:
        # raise a preexisting smaller count (only effective pre-init)
        os.environ["XLA_FLAGS"] = flags.replace(
            cur.group(0), f"{_HOST_FLAG}={need}")
    devices = jax.devices()
    if len(devices) < need:
        raise ValueError(
            f"--mesh host{data}x{model} needs {need} devices but jax sees "
            f"{len(devices)} — jax initialized before the host-device flag "
            f"could take effect. Set JAX_PLATFORMS=cpu and "
            f"XLA_FLAGS={_HOST_FLAG}={need} in the environment before "
            f"launching (or create the mesh before any other jax call).")
    arr = np.asarray(devices[:need]).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def resolve_mesh(spec: str):
    """``--mesh`` argument → mesh.

    local       1×1 mesh with production axis names (no real sharding)
    single      16×16 ("data", "model") — validates 256 devices up front
    multi       2×16×16 ("pod", "data", "model") — validates 512 devices
    host<N>     N forced host-platform CPU devices as (N/2, 2); N odd → (1, N)
    host<D>x<M> explicit (data, model) host-platform mesh
    """
    if spec == "local":
        return make_local_mesh()
    if spec in ("single", "multi"):
        return make_production_mesh(multi_pod=spec == "multi")
    m = re.fullmatch(r"host(\d+)(?:x(\d+))?", spec)
    if m:
        if m.group(2):
            data, model = int(m.group(1)), int(m.group(2))
        else:
            n = int(m.group(1))
            if n % 2 == 0 and n > 1:
                data, model = n // 2, 2
            else:           # odd N: pure tensor parallelism, (1, N)
                data, model = 1, n
        return make_host_mesh(data, model)
    raise ValueError(
        f"unknown --mesh {spec!r}: expected local | single | multi | "
        f"host<N> | host<D>x<M>")
