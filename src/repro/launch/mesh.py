"""Production meshes.

Single pod : (16, 16)      axes ("data", "model")  — 256 × TPU v5e
Multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names — lets the same sharded
    step functions run on a laptop/CI CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))
