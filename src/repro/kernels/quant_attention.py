"""Pallas TPU kernel: flash-decoding attention over the hierarchical
quantized KV region (QuantSpec §5.2.1, adapted to TPU).

Grid = (B·H_kv, NB): the KV-block axis is innermost, so each (batch, head)
streams its quantized blocks through VMEM once, carrying the online-softmax
state (m, l, acc) in VMEM scratch across grid steps — the TPU analogue of
FlashDecoding's split-K loop.

Per grid step the kernel loads the *packed* planes:
    draft  mode: upper plane only  — 4 bits/element off HBM
    target mode: upper + lower     — 8 bits/element
and dequantizes in-register after the VMEM copy; the MXU sees fp32 tiles of
[G, D] with G = quant group (128) and D = head_dim (128) — both
hardware-aligned. This is where the paper's 2.88×/1.51× bandwidth win
comes from: bytes moved per KV element drop 4×/2× vs fp16.

The recent-token FP buffer (≤ 2G tokens) is handled outside the kernel as
one extra flash chunk and merged via log-sum-exp (App. E of the paper).

Two variants share the kernel body math:
  * `quant_region_attention` — contiguous per-request regions ([B·H, NB, …]).
  * `paged_quant_region_attention` — a global block pool addressed through a
    scalar-prefetched per-sequence block table (paged-attention layout); the
    BlockSpec index maps dereference the table so each grid step DMAs the
    owning pool block directly, with per-sequence valid-block counts.

Validated in interpret mode against kernels/ref.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_init(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def _flash_block_update(q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
                        vu_ref, vl_ref, vs_ref, vz_ref,
                        m_scr, l_scr, acc_scr, *, mode: str, ix: tuple):
    """Dequantize one KV block and fold it into the online-softmax state.

    Shared by the contiguous and paged kernels; ``ix`` is the ref index of
    the current block's data (the paged specs carry one fewer leading
    block axis)."""
    q = q_ref[0].astype(jnp.float32)                  # [gT, D]
    D = q.shape[-1]

    def dequant(u_ref, l_ref, s_ref, z_ref):
        qu = u_ref[ix]
        hi = (qu >> 4).astype(jnp.float32)
        lo = (qu & 0xF).astype(jnp.float32)
        quf = jnp.concatenate([hi, lo], axis=-1)      # [G, D]
        s = s_ref[ix].astype(jnp.float32)
        z = z_ref[ix].astype(jnp.float32)
        if mode == "draft":
            return quf * s + z
        ql = l_ref[ix]
        lhi = (ql >> 4).astype(jnp.float32)
        llo = (ql & 0xF).astype(jnp.float32)
        qlf = jnp.concatenate([lhi, llo], axis=-1) - 8.0
        return (16.0 * quf + qlf) * (s / 16.0) + z

    k = dequant(ku_ref, kl_ref, ks_ref, kz_ref)       # [G, D]
    v = dequant(vu_ref, vl_ref, vs_ref, vz_ref)       # [G, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s / math.sqrt(D)                               # [gT, G]

    m_prev = m_scr[...]                                # [gT, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # [gT, G]
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _flash_finalize(out_ref, lse_ref, m_scr, l_scr, acc_scr):
    l = l_scr[...]
    out_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(out_ref.dtype)
    lse = jnp.where(l > 0, m_scr[...] + jnp.log(jnp.maximum(l, 1e-30)),
                    -jnp.inf)
    lse_ref[0] = lse[:, 0]


def _kernel(blocks_ref,                      # scalar prefetch: [1] i32
            q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
            vu_ref, vl_ref, vs_ref, vz_ref,
            out_ref, lse_ref,
            m_scr, l_scr, acc_scr,
            *, mode: str, nb_total: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        _flash_init(m_scr, l_scr, acc_scr)

    @pl.when(nb < blocks_ref[0])
    def _process():
        _flash_block_update(q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
                            vu_ref, vl_ref, vs_ref, vz_ref,
                            m_scr, l_scr, acc_scr, mode=mode, ix=(0, 0))

    @pl.when(nb == nb_total - 1)
    def _finalize():
        _flash_finalize(out_ref, lse_ref, m_scr, l_scr, acc_scr)


def _paged_kernel(blocks_ref,                 # scalar prefetch: [R] i32
                  bt_ref,                     # scalar prefetch: [R, NBmax] i32
                  q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
                  vu_ref, vl_ref, vs_ref, vz_ref,
                  out_ref, lse_ref,
                  m_scr, l_scr, acc_scr,
                  *, mode: str, nb_total: int, nh: int):
    """Block-table flash decoding: grid (R·H, NBmax). Same per-block math
    as `_kernel` (shared `_flash_block_update`), but the KV operands arrive
    through a scalar-prefetched block table (see the index maps in
    `paged_quant_region_attention`) and the per-sequence valid-block count
    comes from ``blocks_ref[r]``. ``bt_ref`` is consumed by the index maps
    only."""
    del bt_ref
    i = pl.program_id(0)
    nb = pl.program_id(1)
    r = i // nh

    @pl.when(nb == 0)
    def _init():
        _flash_init(m_scr, l_scr, acc_scr)

    @pl.when(nb < blocks_ref[r])
    def _process():
        _flash_block_update(q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
                            vu_ref, vl_ref, vs_ref, vz_ref,
                            m_scr, l_scr, acc_scr, mode=mode, ix=(0,))

    @pl.when(nb == nb_total - 1)
    def _finalize():
        _flash_finalize(out_ref, lse_ref, m_scr, l_scr, acc_scr)


def paged_quant_region_attention(q, k_upper, k_lower, k_scale, k_zero,
                                 v_upper, v_lower, v_scale, v_zero,
                                 block_table, blocks, nh: int, mode: str, *,
                                 interpret: bool = True):
    """Flash decoding over a **paged** quantized region.

    q ``[R*H, gT, D]``; pool planes flattened per (block, head):
    ``k/v_upper/lower [(P+1)*H, G, D//2]``, ``k_scale/zero [(P+1)*H, 1, D]``,
    ``v_scale/zero [(P+1)*H, G, 1]`` (row ``p*H + h`` = head ``h`` of pool
    block ``p``). ``block_table [R, NBmax]`` and ``blocks [R]`` are
    scalar-prefetched: the BlockSpec index maps dereference the table, so
    each grid step DMAs exactly the pool block the sequence owns — the
    gather never materializes. Columns ≥ ``blocks[r]`` stream the (valid)
    pool block their table padding points at but are masked out of the
    online softmax. Returns ``(out [R*H, gT, D], lse [R*H, gT])``.
    """
    RH, gT, D = q.shape
    NBmax = block_table.shape[1]
    G = k_upper.shape[1]
    Dp = D // 2

    ks = jnp.broadcast_to(k_scale, (k_upper.shape[0], 1, D))
    kz = jnp.broadcast_to(k_zero, (k_upper.shape[0], 1, D))
    vs = jnp.broadcast_to(v_scale, (k_upper.shape[0], G, 1))
    vz = jnp.broadcast_to(v_zero, (k_upper.shape[0], G, 1))

    grid = (RH, NBmax)
    # index maps receive the two scalar-prefetch refs after the grid indices
    def page(i, j, blk, bt):
        return (bt[i // nh, j] * nh + i % nh, 0, 0)

    qspec = pl.BlockSpec((1, gT, D), lambda i, j, blk, bt: (i, 0, 0))
    pspec = pl.BlockSpec((1, G, Dp), page)
    ksspec = pl.BlockSpec((1, 1, D), page)
    vsspec = pl.BlockSpec((1, G, 1), page)

    out, lse = pl.pallas_call(
        functools.partial(_paged_kernel, mode=mode, nb_total=NBmax, nh=nh),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[qspec, pspec, pspec, ksspec, ksspec,
                      pspec, pspec, vsspec, vsspec],
            out_specs=[
                pl.BlockSpec((1, gT, D), lambda i, j, blk, bt: (i, 0, 0)),
                pl.BlockSpec((1, gT), lambda i, j, blk, bt: (i, 0))],
            scratch_shapes=[pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, D), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((RH, gT, D), q.dtype),
                   jax.ShapeDtypeStruct((RH, gT), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(blocks, jnp.int32), jnp.asarray(block_table, jnp.int32),
      q, k_upper, k_lower, ks, kz, v_upper, v_lower, vs, vz)
    return out, lse


def quant_region_attention(q, k_upper, k_lower, k_scale, k_zero,
                           v_upper, v_lower, v_scale, v_zero,
                           blocks, mode: str, *, interpret: bool = True):
    """q [BH, gT, D]; packed planes [BH, NB, G, D//2];
    k_scale/zero [BH, NB, 1, D]; v_scale/zero [BH, NB, G, 1].
    Returns (out [BH, gT, D], lse [BH, gT])."""
    BH, gT, D = q.shape
    NB, G = k_upper.shape[1], k_upper.shape[2]
    Dp = D // 2

    # broadcast scale layouts the kernel expects: [BH, NB, G|1, D]
    ks = jnp.broadcast_to(k_scale, (BH, NB, 1, D))
    kz = jnp.broadcast_to(k_zero, (BH, NB, 1, D))
    vs = jnp.broadcast_to(v_scale, (BH, NB, G, 1))
    vz = jnp.broadcast_to(v_zero, (BH, NB, G, 1))

    grid = (BH, NB)
    # index maps take a trailing ref arg for the scalar-prefetch operand
    qspec = pl.BlockSpec((1, gT, D), lambda i, j, s: (i, 0, 0))
    pspec = pl.BlockSpec((1, 1, G, Dp), lambda i, j, s: (i, j, 0, 0))
    ksspec = pl.BlockSpec((1, 1, 1, D), lambda i, j, s: (i, j, 0, 0))
    vsspec = pl.BlockSpec((1, 1, G, 1), lambda i, j, s: (i, j, 0, 0))

    out, lse = pl.pallas_call(
        functools.partial(_kernel, mode=mode, nb_total=NB),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[qspec, pspec, pspec, ksspec, ksspec,
                      pspec, pspec, vsspec, vsspec],
            out_specs=[pl.BlockSpec((1, gT, D), lambda i, j, s: (i, 0, 0)),
                       pl.BlockSpec((1, gT), lambda i, j, s: (i, 0))],
            scratch_shapes=[pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, D), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((BH, gT, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, gT), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(blocks, jnp.int32).reshape(1), q,
      k_upper, k_lower, ks, kz, v_upper, v_lower, vs, vz)
    return out, lse
